"""Train a ~100M-param LM for a few hundred steps, end to end:
compressed data pipeline -> sharded-capable train step -> compressed
async checkpoints -> resume.

This drives the same launcher as production (`repro.launch.train`) with a
custom mid-size config (bigger than the smoke `reduced()` configs, small
enough for CPU).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main  # noqa: E402


def run(steps: int = 300, workdir: str = "/tmp/repro_train_lm"):
    # rwkv6 reduced is the fastest per-step family on CPU; the driver's
    # --reduced flag shrinks structure, keeping every subsystem in play.
    return train_main([
        "--arch", "rwkv6-1.6b", "--reduced",
        "--steps", str(steps),
        "--batch", "8", "--seq-len", "128",
        "--ckpt-every", "100", "--log-every", "20",
        "--workdir", workdir,
    ])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    a = ap.parse_args()
    raise SystemExit(run(a.steps, a.workdir))
