"""Quickstart: the paper's technique end to end in ~60 lines.

1. Generate a NanoAOD-like event tree (the paper's test file).
2. Write it column-wise into compressed baskets under two codec profiles
   (the paper's production vs analysis operating points).
3. Read it back with parallel decompression; verify integrity.
4. Show the Fig. 6 effect: preconditioners rescue LZ4 on offset arrays.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile
import time

import numpy as np

from repro.core import CompressionConfig, compress
from repro.core.bfile import BasketFile
from repro.data import write_event_file


def main():
    with tempfile.TemporaryDirectory() as td:
        print("== the paper's 2000-event artificial tree ==")
        for profile in ("production", "analysis"):
            path = os.path.join(td, f"events-{profile}.bskt")
            t0 = time.perf_counter()
            write_event_file(path, n_events=2000, seed=0, profile=profile)
            dt = time.perf_counter() - t0
            f = BasketFile(path)
            t1 = time.perf_counter()
            for name in f.branch_names():
                f.read_branch(name, workers=4)
            dt_r = time.perf_counter() - t1
            print(f"  {profile:10s}: ratio={f.compression_ratio():5.2f}x "
                  f"write={dt*1e3:6.1f}ms read(4 workers)={dt_r*1e3:6.1f}ms "
                  f"({f.compressed_bytes()/1024:.0f} KiB on disk)")

        print("\n== Fig. 6: why LZ4 needs a preconditioner ==")
        rng = np.random.default_rng(0)
        offsets = (0x01000000 + np.cumsum(rng.integers(1, 5, 50_000))) \
            .astype(">u4").tobytes()
        for label, cfg in [
            ("lz4 plain", CompressionConfig("lz4", 1)),
            ("lz4 + shuffle", CompressionConfig("lz4", 1, "shuffle4")),
            ("lz4 + delta+shuffle", CompressionConfig("lz4", 1, "delta4+shuffle4")),
            ("zlib-6 (reference)", CompressionConfig("zlib", 6)),
        ]:
            ratio = len(offsets) / len(compress(offsets, cfg))
            print(f"  {label:22s} ratio={ratio:6.2f}x")


if __name__ == "__main__":
    main()
