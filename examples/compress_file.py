"""CLI: compress any file into a BasketFile and back — codec/level/
preconditioner selectable, with stats.  The ROOT `hadd`-style utility of
this framework.

Run:
  PYTHONPATH=src python examples/compress_file.py INPUT [--algo zstd]
      [--level 5] [--precond bitshuffle4] [--out out.bskt] [--verify]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, "src")

import numpy as np                                  # noqa: E402

from repro.core import CompressionConfig            # noqa: E402
from repro.core.bfile import BasketFile, BasketWriter  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("input")
    ap.add_argument("--algo", default="zstd")
    ap.add_argument("--level", type=int, default=5)
    ap.add_argument("--precond", default="none")
    ap.add_argument("--out", default="")
    ap.add_argument("--verify", action="store_true")
    args = ap.parse_args()

    data = open(args.input, "rb").read()
    out = args.out or args.input + ".bskt"
    cfg = CompressionConfig(args.algo, args.level, args.precond)
    t0 = time.perf_counter()
    with BasketWriter(out) as w:
        w.write_branch("data", np.frombuffer(data, np.uint8), cfg)
    dt = time.perf_counter() - t0
    f = BasketFile(out)
    print(f"{args.input}: {len(data)} -> {f.compressed_bytes()} bytes "
          f"({f.compression_ratio():.2f}x) in {dt*1e3:.0f}ms "
          f"[{args.algo}-{args.level}+{args.precond}]")
    if args.verify:
        t1 = time.perf_counter()
        back = f.read_branch("data", workers=4)
        dt_r = time.perf_counter() - t1
        assert back.tobytes() == data, "roundtrip mismatch!"
        print(f"verified OK (decompress {dt_r*1e3:.0f}ms, "
              f"{len(data)/dt_r/1e6:.0f} MB/s)")


if __name__ == "__main__":
    main()
