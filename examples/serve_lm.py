"""Serve batched requests against a trained checkpoint (continuous
batching) — the paper's decompression-speed-bound "analysis" side.

Trains briefly if no checkpoint exists, then restores and serves.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys
import time

sys.path.insert(0, "src")

import jax                                   # noqa: E402
import jax.numpy as jnp                      # noqa: E402
import numpy as np                           # noqa: E402

from repro.checkpoint import CheckpointManager      # noqa: E402
from repro.configs import get_config, reduced       # noqa: E402
from repro.launch.train import main as train_main   # noqa: E402
from repro.models import Model                      # noqa: E402
from repro.serve import ServeEngine                 # noqa: E402
from repro.train import init_train_state            # noqa: E402

WORKDIR = "/tmp/repro_serve_lm"


def main():
    cfg = reduced(get_config("qwen3-8b"))
    model = Model(cfg)
    mgr = CheckpointManager(os.path.join(WORKDIR, "ckpt"))
    if mgr.latest_step() is None:
        print("no checkpoint — training 60 quick steps first...")
        train_main(["--arch", "qwen3-8b", "--reduced", "--steps", "60",
                    "--batch", "4", "--seq-len", "64", "--ckpt-every", "60",
                    "--workdir", WORKDIR])
    state = init_train_state(model, jax.random.key(0))
    tmpl = {"params": state.params, "opt": state.opt, "step": state.step,
            "err": state.err}
    tree, meta = mgr.restore(template=tmpl)
    print(f"restored step {int(np.asarray(tree['step']))} "
          f"(cursor: {meta.get('data_cursor')})")
    params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if hasattr(p, "dtype") and p.dtype == jnp.float32 else p,
        tree["params"])

    eng = ServeEngine(model, params, batch_slots=4, max_len=96, eos_id=-1,
                      temperature=0.7, seed=1)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for i in range(12):
        eng.submit(rng.integers(2, cfg.vocab, 8), max_new=12)
    out = eng.run()
    dt = time.monotonic() - t0
    tok = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests / {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s)")
    for rid in sorted(out)[:3]:
        print(f"  req {rid}: {out[rid].tolist()}")


if __name__ == "__main__":
    main()
