"""Cores-vs-throughput scaling per codec — the companion papers' headline
figure (*Increasing Parallelism in the ROOT I/O Subsystem*, arXiv:1804.03326
Fig. 3-style): basket-granular task parallelism lifts every codec's wall-
clock compression AND decompression throughput until the machine runs out
of cores.

For each codec we write the paper's artificial-tree-like float column
through ``BasketWriter(workers=N)`` and read it back with
``read_branch(workers=N)``, N in ``workers_list``; the ``speedup`` column
is vs N=1.  C-backed codecs scale on the thread pool (GIL released);
pure-Python codecs go through the engine's process pool, so they scale
too — at higher per-task overhead (visible as a lower speedup intercept).
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import CompressionConfig
from repro.core.bfile import BasketFile, BasketWriter
from repro.core.codec import HAVE_ZSTD, is_pure_python
from repro.io import CompressionEngine, PrefetchReader

from .common import emit

_LEVEL = {"zlib": 6, "lzma": 2, "zstd": 3, "lz4": 1, "repro-deflate": 1}


def _payload(algo: str) -> np.ndarray:
    rng = np.random.default_rng(11)
    n_bytes = (2 << 20) if is_pure_python(algo) else (16 << 20)
    # low-entropy physics-like floats: compressible under bitshuffle
    return (rng.standard_normal(n_bytes // 4) * 0.001).astype(np.float32)


def run(out_csv: str | None = None,
        codecs=("zlib", "lzma", "zstd", "lz4"),
        workers_list=(1, 2, 4, 8)) -> list[dict]:
    rows = []
    with tempfile.TemporaryDirectory() as td:
        for algo in codecs:
            arr = _payload(algo)
            nbytes = arr.nbytes
            cfg = CompressionConfig(algo, _LEVEL.get(algo, 3), "bitshuffle4")
            base_w = base_r = None
            for workers in workers_list:
                path = os.path.join(td, f"{algo}_{workers}.bskt")
                # steady-state: pool pre-forked, shared by writer and reader;
                # process decompression opted in (pool amortized over the scan)
                with CompressionEngine(workers, unpack_processes=True) as eng:
                    eng.warmup(algo)
                    t0 = time.perf_counter()
                    with BasketWriter(path, engine=eng) as w:
                        w.write_branch("x", arr, cfg, 256 * 1024)
                    dt_w = time.perf_counter() - t0
                    reader = PrefetchReader(BasketFile(path), "x",
                                            ahead=4, engine=eng)
                    t0 = time.perf_counter()
                    reader.read_all()
                    dt_r = time.perf_counter() - t0
                    reader.close()
                base_w = base_w or dt_w
                base_r = base_r or dt_r
                rows.append({
                    "bench": "fig_parallel", "algo": algo,
                    "pure_python": int(is_pure_python(algo)),
                    "workers": workers,
                    "comp_MBps": round(nbytes / dt_w / 1e6, 1),
                    "decomp_MBps": round(nbytes / dt_r / 1e6, 1),
                    "comp_speedup": round(base_w / dt_w, 2),
                    "decomp_speedup": round(base_r / dt_r, 2),
                })
    if not HAVE_ZSTD:
        print("# note: zstandard not installed; 'zstd' is the pure-Python "
              "large-window fallback (process-pool scaling regime)")
    emit(rows, out_csv)
    return rows


if __name__ == "__main__":
    run("artifacts/bench/fig_parallel.csv")
