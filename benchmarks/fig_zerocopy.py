"""Zero-copy data plane benchmark: PR-3 plane vs the PR-2 copying plane.

Three stages, each an old-vs-new A/B on the same machine in the same
process:

* **read** — one ≥64 MiB branch.  Legacy path: per-basket
  ``read_basket_raw`` (fresh ``bytes`` each) + ``join_baskets``
  concatenation.  New path: ``read_branch`` scattering every basket into
  the one destination allocation via ``unpack_basket_into``.  Measured in
  GB/s and peak *extra* traced allocation (tracemalloc) relative to the
  branch size.

* **shm** — the process-pool transport, two rows.  ``transport``: raw
  round-trip of 1 MiB baskets through a forkserver pool, pickled-pipe vs
  slab-pool (the isolated mechanism — what the engine's transport swap
  actually replaces).  ``lz4-unpack``: the same swap end-to-end under a
  real pure-Python codec decode (``unpack_processes=True``) — reported for
  honesty: today's from-scratch codecs are codec-bound, so the end-to-end
  delta is small and grows as the cores get faster.

* **ckpt** — end-to-end ``save_pytree`` + ``load_pytree`` of a ≥64 MiB
  survey-style state.  Legacy emulation reproduces the PR-2 data plane:
  whole-tree host materialization (what ``device_get`` does on a real
  accelerator), per-basket ``tobytes()`` chunks, join-based reads.  New
  path: streamed staging + scatter reads.  The ``off`` profile isolates
  the copy plane (the paper's memory-bandwidth argument); the
  ``checkpoint`` profile shows the realistic codec-bound mix.

``--check`` is the CI perf-smoke gate: the zero-copy read must beat the
copying read on the 64 MiB branch with peak extra allocation < 1.25× the
branch size, and the data-plane checkpoint round-trip must be ≥ 1.5×
faster than the legacy emulation with ≥ 1.5× lower save peak.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time
import tracemalloc

import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.checkpoint.manager import _flatten_with_paths
from repro.core.basket import split_array
from repro.core.bfile import BasketFile, BasketWriter, write_arrays
from repro.core.codec import CompressionConfig
from repro.core.policy import choose
from repro.io.engine import CompressionEngine

from .common import emit

MB = 1 << 20


def _peak(fn):
    """Peak traced bytes (tracemalloc) for one call — run separately from
    the timing reps so tracing overhead can't skew the A/B wall clocks."""
    tracemalloc.start()
    tracemalloc.reset_peak()
    out = fn()
    _cur, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return peak, out


def _best(fn, reps):
    """Best-of-reps wall seconds (no tracing)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _gbps(nbytes: int, seconds: float) -> float:
    return round(nbytes / seconds / 1e9, 3)


# -- legacy (PR-2) data plane, reproduced locally for the A/B ---------------

def _read_branch_legacy(f: BasketFile, name: str, workers: int = 0):
    from concurrent.futures import ThreadPoolExecutor
    entry = f.branches[name]
    n = len(entry["baskets"])
    if workers and n > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            chunks = list(ex.map(lambda i: f.read_basket_raw(name, i), range(n)))
    else:
        chunks = [f.read_basket_raw(name, i) for i in range(n)]
    buf = b"".join(chunks)
    return np.frombuffer(buf, dtype=np.dtype(entry["dtype"])) \
        .reshape(tuple(entry["shape"])).copy()


def _save_legacy(path: str, tree, profile: str, workers: int = 0) -> None:
    """PR-2 save: materialize every tensor on host first (the device_get
    semantics on a real accelerator), then per-basket bytes copies."""
    host = {n: np.array(v, copy=True)
            for n, v in _flatten_with_paths(tree).items() if v is not None}

    def byte_chunks(arr):
        for s, c, view in split_array(arr, 1 << 20):
            yield s, c, bytes(view)     # the per-basket tobytes() copy

    with BasketWriter(path, workers=workers) as w:
        for name, arr in host.items():
            w.write_branch_chunks(name, dtype=arr.dtype.str, shape=arr.shape,
                                  chunks=byte_chunks(arr),
                                  cfg=choose(name, arr, profile))
        w.write_blob("__meta__", json.dumps({"bf16": []}).encode())


def _load_legacy(path: str, workers: int = 0) -> dict:
    with BasketFile(path) as f:
        return {n: _read_branch_legacy(f, n, workers)
                for n in f.branch_names() if n != "__meta__"}


# -- workloads ---------------------------------------------------------------

def _branch_data(size: int) -> np.ndarray:
    rng = np.random.default_rng(17)
    return np.cumsum(rng.integers(1, 9, size // 8)).astype(np.int64)


def _survey_state(total_bytes: int) -> dict:
    rng = np.random.default_rng(23)
    nf = (total_bytes * 3 // 4) // 4
    ni = (total_bytes // 4) // 8
    return {
        "params": {"w": rng.standard_normal(nf // 2).astype(np.float32).reshape(-1, 256),
                   "b": rng.standard_normal(nf // 2).astype(np.float32)},
        "opt": {"off": np.cumsum(rng.integers(1, 9, ni)).astype(np.int64)},
        "step": np.int64(1234),
    }


def _bench_dir():
    """tmpfs when available: the copy plane must not hide behind a slow
    filesystem (CI runners and this container both mount /dev/shm)."""
    for d in ("/dev/shm", None):
        if d is None or (os.path.isdir(d) and os.access(d, os.W_OK)):
            return tempfile.TemporaryDirectory(dir=d, prefix="fig_zerocopy_")


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    rows: list[dict] = []
    reps = 3 if quick else 5
    branch_mb = 64
    state_mb = 64      # the acceptance point: >= 64 MiB survey state

    with _bench_dir() as td:
        # ---- checkpoint end-to-end --------------------------------------
        # first, before any process-pool stage churns the machine: this is
        # the acceptance-gate measurement
        state = _survey_state(state_mb * MB)
        total = sum(v.nbytes for v in
                    _flatten_with_paths(state).values() if v is not None)
        for profile, workers in [("off", 4), ("checkpoint", 4)]:
            pl = os.path.join(td, f"l_{profile}.bskt")
            pn = os.path.join(td, f"n_{profile}.bskt")
            save_l = lambda: _save_legacy(pl, state, profile, workers)
            save_n = lambda: save_pytree(pn, state, profile, workers=workers,
                                         staging="stream")
            load_l = lambda: _load_legacy(pl, workers)
            load_n = lambda: load_pytree(pn, workers=workers)
            t_sl, t_sn = _best(save_l, reps), _best(save_n, reps)
            t_ll, t_ln = _best(load_l, reps), _best(load_n, reps)
            peak_sl, _ = _peak(save_l)
            peak_sn, _ = _peak(save_n)
            flat_n = load_n()
            np.testing.assert_array_equal(flat_n[0]["params.w"],
                                          state["params"]["w"])
            assert open(pl, "rb").read() == open(pn, "rb").read(), \
                "legacy and streamed containers must be byte-identical"
            rows.append({
                "bench": "fig_zerocopy", "stage": "ckpt",
                "case": f"{profile}-w{workers}", "bytes": total,
                "old_GBps": _gbps(2 * total, t_sl + t_ll),
                "new_GBps": _gbps(2 * total, t_sn + t_ln),
                "speedup": round((t_sl + t_ll) / (t_sn + t_ln), 2),
                "old_peak_x": round(peak_sl / total, 2),
                "new_peak_x": round(peak_sn / total, 2),
            })
        del state, flat_n

        # ---- read plane -------------------------------------------------
        arr = _branch_data(branch_mb * MB)
        for algo, level, precond, workers in [
                ("none", 0, "none", 0),
                ("none", 0, "none", 4),
                ("zlib", 1, "delta8", 4)]:
            p = os.path.join(td, f"r_{algo}_{workers}.bskt")
            write_arrays(p, {"x": arr},
                         lambda n, a: CompressionConfig(algo, level, precond),
                         target_basket_bytes=MB, workers=0)
            with BasketFile(p, workers=workers) as f:
                f.read_branch("x")      # warm the fd/page cache
                t_old = _best(lambda: _read_branch_legacy(f, "x", workers), reps)
                t_new = _best(lambda: f.read_branch("x"), reps)
                peak_old, _ = _peak(lambda: _read_branch_legacy(f, "x", workers))
                peak_new, _ = _peak(lambda: f.read_branch("x"))
            rows.append({
                "bench": "fig_zerocopy", "stage": "read",
                "case": f"{algo}+{precond}-w{workers}", "bytes": arr.nbytes,
                "old_GBps": _gbps(arr.nbytes, t_old),
                "new_GBps": _gbps(arr.nbytes, t_new),
                "speedup": round(t_old / t_new, 2),
                "old_peak_x": round(peak_old / arr.nbytes, 2),
                "new_peak_x": round(peak_new / arr.nbytes, 2),
            })

        # ---- shm transport: isolated mechanism --------------------------
        from repro.io import shmem
        if shmem.available():
            n_bufs = 32 if quick else 64
            payload = _branch_data(MB).tobytes()
            # the engine's guarded spawn (hidden __main__, forkserver) —
            # a bare ProcessPoolExecutor here would re-import the whole
            # bench suite per worker and break for stdin scripts
            eng = CompressionEngine(4)
            pool = eng._pool_for("lz4")     # the process pool
            for f in [pool.submit(shmem.roundtrip_pickle, b"x")
                      for _ in range(4)]:
                f.result()                  # warm the workers

            def rt_pickle():
                for f in [pool.submit(shmem.roundtrip_pickle, payload)
                          for _ in range(n_bufs)]:
                    assert len(f.result()) == len(payload)

            slabs = shmem.SlabPool()

            def rt_shm():
                futs = []
                for _ in range(n_bufs):
                    slab = slabs.acquire(len(payload))
                    slab.fill(payload)
                    futs.append((slab, pool.submit(
                        shmem.roundtrip_slab, slab.name, len(payload))))
                for slab, f in futs:
                    assert f.result() == len(payload)
                    slabs.release(slab)
            t_p = _best(rt_pickle, reps)
            t_s = _best(rt_shm, reps)
            slabs.close()
            eng.close()
            rows.append({
                "bench": "fig_zerocopy", "stage": "shm",
                "case": "transport-1MiB-w4", "bytes": n_bufs * MB,
                "old_GBps": _gbps(n_bufs * MB, t_p),
                "new_GBps": _gbps(n_bufs * MB, t_s),
                "speedup": round(t_p / t_s, 2),
                "old_peak_x": "", "new_peak_x": "",
            })

        # ---- shm transport end-to-end (decode side, codec-bound) --------
        from repro.io.prefetch import PrefetchReader
        shm_mb = 16
        shm_arr = _branch_data(shm_mb * MB)
        sp = os.path.join(td, "shm.bskt")
        write_arrays(sp, {"x": shm_arr},
                     lambda n, a: CompressionConfig("lz4", 1, "delta8"),
                     target_basket_bytes=MB, workers=0)
        times = {}
        for tag, shm in (("pickle", False), ("shm", "auto")):
            with CompressionEngine(4, shm=shm, unpack_processes=True) as eng:
                eng.warmup("lz4")
                with BasketFile(sp) as f:
                    reader = PrefetchReader(f, "x", engine=eng, ahead=8)

                    def scan():
                        np.testing.assert_array_equal(reader.read_all()[:8],
                                                      shm_arr[:8])
                    times[tag] = _best(scan, reps)
                    reader.close()
        rows.append({
            "bench": "fig_zerocopy", "stage": "shm",
            "case": "lz4-unpack-w4", "bytes": shm_arr.nbytes,
            "old_GBps": _gbps(shm_arr.nbytes, times["pickle"]),
            "new_GBps": _gbps(shm_arr.nbytes, times["shm"]),
            "speedup": round(times["pickle"] / times["shm"], 2),
            "old_peak_x": "", "new_peak_x": "",
        })

    emit(rows, out_csv)
    return rows


def check(rows: list[dict]) -> int:
    """CI perf-smoke gate (see module docstring)."""
    ok = True

    def fail(msg):
        nonlocal ok
        print(f"FAIL: {msg}", file=sys.stderr)
        ok = False

    read = [r for r in rows if r["stage"] == "read"
            and r["case"].startswith("none")]
    if not read:
        fail("no copy-bound read rows")
    for r in read:
        if r["speedup"] <= 1.0:
            fail(f"zero-copy read not faster ({r['speedup']}x) on {r['case']}")
        if r["new_peak_x"] >= 1.25:
            fail(f"read peak extra allocation {r['new_peak_x']}x >= 1.25x "
                 f"branch size on {r['case']}")
    ck = [r for r in rows if r["stage"] == "ckpt" and r["case"].startswith("off")]
    if not ck:
        fail("no data-plane ckpt row")
    for r in ck:
        if r["speedup"] < 1.5:
            fail(f"ckpt round-trip speedup {r['speedup']}x < 1.5x ({r['case']})")
        if r["old_peak_x"] < 1.5 * r["new_peak_x"]:
            fail(f"save peak not reduced >=1.5x: old {r['old_peak_x']}x vs "
                 f"new {r['new_peak_x']}x ({r['case']})")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller states, fewer repeats")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the zero-copy plane beats the "
                         "copying plane (CI perf-smoke)")
    ap.add_argument("--out", default="artifacts/bench/fig_zerocopy.csv")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    return check(rows) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
