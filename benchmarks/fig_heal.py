"""Self-healing storage benchmark: parity overhead + heal soak.

Three stages (DESIGN.md §15):

* **write** — the same corpus written without and with a ``parity=k``
  sidecar.  Gates: the *container* bytes are identical (the sidecar never
  touches the format), sidecar bytes ≤ 1/k + 2% of the container, and the
  parity write wall stays within 5% of the plain write (best-of-N).

* **heal** — deterministic on-disk rot (:func:`repro.fault.rot_container`
  with ``every = k + 1``, so every stripe keeps k - 1 intact members),
  then a plain ``BasketFile(heal="auto")`` read.  Gates: byte identity,
  every damaged basket healed in place, and a post-heal scrub reports the
  container clean.

* **soak** — two replica servers, *both* on rotted storage: distinct
  stripes damaged on each, plus one double-damaged stripe on A that
  single parity cannot heal locally.  Clients read every branch through
  an :class:`EndpointPool`.  Gates: zero client-visible errors, byte
  identity, ``repair.healed`` > 0; then anti-entropy
  (:func:`repro.repair.repair_replica`) pulls A's unhealable baskets from
  B and a final scrub of both replicas reports **zero** remaining
  corruption.
"""

from __future__ import annotations

import os
import sys
import tempfile
import threading
import time

import numpy as np

from repro import obs
from repro.core.bfile import BasketFile, write_arrays
from repro.core.codec import CompressionConfig
from repro.fault import rot_container
from repro.io import fdcache
from repro.remote import BasketServer, EndpointPool, RemoteBasketFile
from repro.repair import repair_replica, scrub_container

from .common import emit

MB = 1 << 20
K = 4                         # parity stripe width under test


def _bench_dir():
    for d in ("/dev/shm", None):
        if d is None or (os.path.isdir(d) and os.access(d, os.W_OK)):
            return tempfile.TemporaryDirectory(dir=d, prefix="fig_heal_")


def _corpus(quick: bool) -> dict[str, np.ndarray]:
    """``algo=none`` keeps payloads raw, so one garbled byte is exactly one
    checksum failure and parity reconstruction is the only repair path."""
    rows = 80_000 if quick else 500_000
    rng = np.random.default_rng(17)
    return {
        "energy": np.cumsum(rng.integers(1, 9, rows)).astype(np.int64),
        "pid": rng.integers(0, 100, rows).astype(np.int32),
        "t0": rng.standard_normal(rows).astype(np.float32),
    }


def _write(path: str, arrays, parity: int = 0, algo: str = "none") -> None:
    cfg = CompressionConfig(algo, 1 if algo != "none" else 0)
    write_arrays(path, arrays, cfg_for=lambda n, a: cfg,
                 target_basket_bytes=32 * 1024, parity=parity)


def _row(stage, case, value, unit, wall=""):
    return {"bench": "fig_heal", "stage": stage, "case": case,
            "wall_s": wall, "value": value, "unit": unit}


def _write_rows(td, quick: bool) -> list[dict]:
    """Parity cost against a *production-shaped* write: zlib-1 compressed
    (the paper's baseline codec) — the XOR + sidecar work must disappear
    inside the compression wall, and the sidecar bytes inside 1/k + 2%
    of the compressed container."""
    rng = np.random.default_rng(29)
    rows = 200_000 if quick else 500_000
    arrays = {
        "energy": np.cumsum(rng.integers(1, 9, rows)).astype(np.int64),
        "pid": rng.integers(0, 100, rows).astype(np.int32),
        "t0": rng.standard_normal(rows).astype(np.float32),
    }
    plain, par = os.path.join(td, "plain.bskt"), os.path.join(td, "par.bskt")
    reps = 3 if quick else 5
    t_plain = t_par = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _write(plain, arrays, parity=0, algo="zlib")
        t_plain = min(t_plain, time.perf_counter() - t0)
        t0 = time.perf_counter()
        _write(par, arrays, parity=K, algo="zlib")
        t_par = min(t_par, time.perf_counter() - t0)
    with open(plain, "rb") as f, open(par, "rb") as g:
        identical = f.read() == g.read()
    csize = os.path.getsize(par)
    ssize = os.path.getsize(par + ".parity")
    return [
        _row("write", "container.bytes", csize, "B", round(t_plain, 4)),
        _row("write", "container.identical",
             "ok" if identical else "DIFFERENT", ""),
        _row("write", "sidecar.bytes", ssize, "B"),
        _row("write", "sidecar.overhead",
             round(ssize / csize * 100, 2), "%"),
        _row("write", "wall.plain", round(t_plain, 4), "s"),
        _row("write", "wall.parity", round(t_par, 4), "s"),
        _row("write", "wall.overhead",
             round((t_par / t_plain - 1) * 100, 2), "%"),
    ]


def _heal_rows(td, arrays, quick: bool) -> list[dict]:
    p = os.path.join(td, "heal.bskt")
    _write(p, arrays, parity=K)
    damaged = rot_container(p, seed=7, every=K + 1)
    fdcache.invalidate(p)
    t0 = time.perf_counter()
    mismatches = 0
    with BasketFile(p, heal="auto") as bf:
        for name, want in arrays.items():
            got = bf.read_branch(name)
            if not (got == want).all():
                mismatches += 1
        stats = dict(bf.heal_stats)
    wall = time.perf_counter() - t0
    rep = scrub_container(p, heal=True, resume=False)
    return [
        _row("heal", "rotted", len(damaged), "baskets", round(wall, 4)),
        _row("heal", "healed", stats["healed"], "baskets"),
        _row("heal", "heal_failed", stats["failed"], "baskets"),
        _row("heal", "mismatches", mismatches, "branches"),
        _row("heal", "post_scrub.corrupt", rep["corrupt"], "baskets"),
        _row("heal", "post_scrub.completed",
             "ok" if rep["completed"] else "INCOMPLETE", ""),
    ]


def _soak_rows(td, arrays, quick: bool) -> list[dict]:
    ra, rb = os.path.join(td, "ra"), os.path.join(td, "rb")
    pa, pb = os.path.join(ra, "soak.bskt"), os.path.join(rb, "soak.bskt")
    _write(pa, arrays, parity=K)
    # replica B: identical content, its own (identical) parity write
    _write(pb, arrays, parity=K)
    # distinct stripes rotted on each replica (every = K + 1 keeps each
    # stripe single-damaged = locally healable), plus one double-damaged
    # stripe on A — global baskets 0 and 1 share stripe 0, so A cannot
    # heal them from parity and must pull from B (anti-entropy)
    dmg_a = rot_container(pa, seed=1, every=K + 1, phase=3)
    dmg_b = rot_container(pb, seed=2, every=K + 1, phase=1)
    dbl = rot_container(pa, seed=9, every=1, max_baskets=2)
    for p in (pa, pb):
        fdcache.invalidate(p)
    healed0 = int(obs.snapshot().get("counters", {}).get("repair.healed", 0))

    threads_n = 4 if quick else 8
    reps = 4 if quick else 8
    errors: list = []
    mismatches: list = []
    t0 = time.perf_counter()
    with BasketServer(ra, workers=0, heal="auto",
                      scrub_mbps=64) as srv_a, \
            BasketServer(rb, workers=0, heal="auto",
                         scrub_mbps=64) as srv_b:
        srv_a.start(), srv_b.start()

        def worker(wid: int):
            try:
                pool = EndpointPool([(srv_a.host, srv_a.port),
                                     (srv_b.host, srv_b.port)],
                                    cooldown=0.1)
                for _ in range(reps):
                    with RemoteBasketFile(
                            path="soak.bskt", endpoints=pool, wire=None,
                            timeout=2.0, retries=8, backoff=0.02) as rf:
                        for name, want in arrays.items():
                            got = rf.read_branch(name)
                            if not (got == want).all():
                                mismatches.append((wid, name))
            except Exception as e:
                errors.append((wid, repr(e)))

        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(threads_n)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=300)

        # anti-entropy: converge A's double-damaged stripe from B, and B
        # from A, then prove both replicas clean on disk
        rec_a = repair_replica(pa, "soak.bskt",
                               [(srv_b.host, srv_b.port)])
        rec_b = repair_replica(pb, "soak.bskt",
                               [(srv_a.host, srv_a.port)])
    wall = time.perf_counter() - t0
    healed = int(obs.snapshot().get("counters", {}).get(
        "repair.healed", 0)) - healed0
    scrub_a = scrub_container(pa, heal=True, resume=False)
    scrub_b = scrub_container(pb, heal=True, resume=False)
    rows = [
        _row("soak", "clients", threads_n, "threads", round(wall, 3)),
        _row("soak", "reads", threads_n * reps * len(arrays),
             "branch reads"),
        _row("soak", "rotted", len(dmg_a) + len(dmg_b) + len(dbl),
             "baskets"),
        _row("soak", "errors", len(errors), "errors"),
        _row("soak", "mismatches", len(mismatches), "reads"),
        _row("soak", "repair.healed", healed, "baskets"),
        _row("soak", "reconcile.converged",
             "ok" if rec_a["converged"] and rec_b["converged"]
             else "DIVERGED", ""),
        _row("soak", "reconcile.pulled",
             rec_a["pulled"] + rec_b["pulled"], "baskets"),
        _row("soak", "post_scrub.corrupt",
             scrub_a["corrupt"] + scrub_b["corrupt"], "baskets"),
    ]
    for wid, err in errors[:3]:
        print(f"soak error (worker {wid}): {err}", file=sys.stderr)
    return rows


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    with _bench_dir() as td:
        arrays = _corpus(quick)
        rows = _write_rows(td, quick)
        rows += _heal_rows(td, arrays, quick)
        rows += _soak_rows(td, arrays, quick)
    emit(rows, out_csv)
    return rows


def check(rows: list[dict]) -> int:
    """CI self-healing gate (see module docstring)."""
    ok = True

    def fail(msg):
        nonlocal ok
        print(f"FAIL: {msg}", file=sys.stderr)
        ok = False

    by = {(r["stage"], r["case"]): r for r in rows}

    def val(stage, case):
        r = by.get((stage, case))
        return None if r is None else r["value"]

    if val("write", "container.identical") != "ok":
        fail("parity write changed the container bytes")
    ov = val("write", "sidecar.overhead")
    if ov is None or float(ov) > 100.0 / K + 2.0:
        fail(f"parity sidecar overhead {ov}% exceeds 1/k + 2%")
    wv = val("write", "wall.overhead")
    if wv is None or float(wv) > 5.0:
        fail(f"parity write wall overhead {wv}% exceeds 5%")
    def zero(stage, case):
        v = val(stage, case)
        return v is not None and int(v) == 0

    if val("heal", "rotted") is None or int(val("heal", "rotted")) < 1:
        fail("heal stage injected no damage — proves nothing")
    if val("heal", "healed") != val("heal", "rotted"):
        fail(f"healed {val('heal', 'healed')} of "
             f"{val('heal', 'rotted')} rotted baskets")
    for case in ("heal_failed", "mismatches", "post_scrub.corrupt"):
        if not zero("heal", case):
            fail(f"heal stage {case} = {val('heal', case)}")
    if not zero("soak", "errors"):
        fail(f"soak had client-visible errors: {val('soak', 'errors')}")
    if not zero("soak", "mismatches"):
        fail("soak returned wrong bytes")
    if val("soak", "repair.healed") is None or \
            int(val("soak", "repair.healed")) < 1:
        fail("soak never healed a basket in place")
    if val("soak", "reconcile.converged") != "ok":
        fail("anti-entropy did not converge the replicas")
    if not zero("soak", "post_scrub.corrupt"):
        fail(f"post-soak scrub still finds "
             f"{val('soak', 'post_scrub.corrupt')} corrupt baskets")
    if ok:
        print("fig_heal check: all gates passed")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpus, fewer clients/reps")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every rotted basket healed, "
                         "the soak stayed error-free and byte-identical, "
                         "and the post-soak scrub found zero corruption "
                         "(CI gate)")
    ap.add_argument("--out", default="artifacts/bench/fig_heal.csv")
    ap.add_argument("--json", default="",
                    help="also write the rows as a BENCH-style perf "
                         "trajectory JSON (cross-PR comparison)")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    if args.json:
        from .common import write_json
        write_json(args.json, {"fig_heal": rows})
    return check(rows) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
