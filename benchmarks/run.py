"""Benchmark harness entry point: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig6,...]

Outputs CSV per benchmark (stdout + artifacts/bench/*.csv).
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (fig2_survey, fig3_decompression, fig45_cfzlib, fig6_precond,
               fig_dict, fig_parallel, pipeline_tput, roofline)

BENCHES = {
    "fig2": fig2_survey,
    "fig3": fig3_decompression,
    "fig45": fig45_cfzlib,
    "fig6": fig6_precond,
    "fig_dict": fig_dict,
    "fig_parallel": fig_parallel,
    "pipeline": pipeline_tput,
    "roofline": roofline,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args(argv)
    names = [n for n in args.only.split(",") if n] or list(BENCHES)
    rc = 0
    for name in names:
        mod = BENCHES[name]
        print(f"\n===== {name} =====", flush=True)
        t0 = time.monotonic()
        try:
            mod.run(f"artifacts/bench/{name}.csv")
        except Exception as e:  # keep the harness going; report at the end
            print(f"BENCH {name} FAILED: {e!r}")
            import traceback
            traceback.print_exc()
            rc = 1
        print(f"===== {name} done in {time.monotonic()-t0:.1f}s =====")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
