"""Benchmark harness entry point: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig6,...]
                                            [--json artifacts/bench/BENCH.json]

Outputs CSV per benchmark (stdout + artifacts/bench/*.csv).  ``--json``
additionally writes one machine-readable perf-trajectory file with every
row from every benchmark that ran — future PRs diff their numbers against
it (e.g. ``artifacts/bench/BENCH_pr2.json`` carries this PR's codec-core
speedups).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

from . import (fig2_survey, fig3_decompression, fig45_cfzlib, fig6_precond,
               fig_dict, fig_entropy, fig_fault, fig_heal, fig_obs,
               fig_obs2, fig_parallel, fig_profile, fig_remote, fig_tune,
               fig_zerocopy, pipeline_tput, roofline)

BENCHES = {
    "fig2": fig2_survey,
    "fig3": fig3_decompression,
    "fig45": fig45_cfzlib,
    "fig6": fig6_precond,
    "fig_dict": fig_dict,
    "fig_entropy": fig_entropy,
    "fig_fault": fig_fault,
    "fig_heal": fig_heal,
    "fig_obs": fig_obs,
    "fig_obs2": fig_obs2,
    "fig_parallel": fig_parallel,
    "fig_profile": fig_profile,
    "fig_remote": fig_remote,
    "fig_tune": fig_tune,
    "fig_zerocopy": fig_zerocopy,
    "pipeline": pipeline_tput,
    "roofline": roofline,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(BENCHES))
    ap.add_argument("--json", default="",
                    help="write all rows from all benches to this JSON file "
                         "(perf trajectory for cross-PR comparison)")
    args = ap.parse_args(argv)
    names = [n for n in args.only.split(",") if n] or list(BENCHES)
    rc = 0
    collected: dict[str, list[dict]] = {}
    for name in names:
        mod = BENCHES[name]
        print(f"\n===== {name} =====", flush=True)
        t0 = time.monotonic()
        try:
            rows = mod.run(f"artifacts/bench/{name}.csv")
            collected[name] = rows or []
        except Exception as e:  # keep the harness going; report at the end
            print(f"BENCH {name} FAILED: {e!r}")
            import traceback
            traceback.print_exc()
            rc = 1
        print(f"===== {name} done in {time.monotonic()-t0:.1f}s =====")
    if args.json:
        payload = {
            "schema": 1,
            "unix_time": time.time(),
            "platform": platform.platform(),
            "python": sys.version.split()[0],
            "benches": collected,
        }
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote {args.json} ({sum(len(v) for v in collected.values())} rows)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
