"""Remote basket service benchmark: vectorization, coalescing, cache, wire.

A loopback ``BasketServer`` serves two containers; every comparison is an
A/B measured in the same process and the same phase (paired baselines, so
machine-speed drift between phases cancels):

* **readv** — one ~N MiB branch read three ways through the same server:

  - ``naive``      one basket per round-trip (``read_basket_raw`` loop) —
                   the no-vectorization client every request-latency paper
                   starts from;
  - ``coalesced``  vectored ``read_branch`` (64-basket requests the server
                   coalesces into large sequential preads);
  - ``coalesced+cache`` the same client re-reading through a warm
                   :class:`~repro.remote.TieredCache`.

  Reported as MB/s plus the server's round-trip/pread counts — the
  mechanism (fewer round-trips, fewer syscalls) next to the effect.

* **wire** — an archive-tier (lzma) container read with the plain wire vs
  the transcoded wire (read-bound objective): end-to-end wall, wire bytes,
  and the isolated client *decode* throughput of the fetched payloads —
  the axis the transcode trades wire bytes for.

``--check`` is the CI perf-smoke gate: coalesced+cached remote reads must
beat naive per-basket requests by ≥ 2x, and transcoded-wire client decode
throughput must beat archive-wire decode under the read-bound objective.
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

import numpy as np

from repro.core.basket import BasketMeta, unpack_basket
from repro.core.bfile import write_arrays
from repro.core.codec import CompressionConfig
from repro.remote import BasketServer, RemoteBasketFile, TieredCache

from .common import emit

MB = 1 << 20


def _best(fn, reps):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _mbps(nbytes: int, seconds: float) -> float:
    return round(nbytes / seconds / 1e6, 1)


def _bench_dir():
    for d in ("/dev/shm", None):
        if d is None or (os.path.isdir(d) and os.access(d, os.W_OK)):
            return tempfile.TemporaryDirectory(dir=d, prefix="fig_remote_")


def _hot_data(size: int) -> np.ndarray:
    rng = np.random.default_rng(11)
    return np.cumsum(rng.integers(1, 9, size // 8)).astype(np.int64)


def _decode_all(pairs) -> int:
    total = 0
    for payload, meta_json in pairs:
        meta = BasketMeta.from_json(meta_json)
        total += len(unpack_basket(payload, meta, None))
    return total


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    rows: list[dict] = []
    reps = 3 if quick else 5
    hot_mb = 8 if quick else 32
    arch_mb = 2 if quick else 8
    basket = 64 * 1024

    with _bench_dir() as td:
        # zlib-1: C-backed in every environment, so the readv A/B measures
        # the *request plane* (round-trips, coalescing, cache) and not the
        # decode throughput of whatever zstd backend this host has
        hot = _hot_data(hot_mb * MB)
        write_arrays(os.path.join(td, "hot.bskt"), {"x": hot},
                     cfg_for=lambda n, a: CompressionConfig("zlib", 1, "delta8"),
                     target_basket_bytes=basket)
        arch = _hot_data(arch_mb * MB)
        write_arrays(os.path.join(td, "archive.bskt"), {"y": arch},
                     cfg_for=lambda n, a: CompressionConfig("lzma", 2, "shuffle"),
                     target_basket_bytes=basket)

        with BasketServer(td, workers=4) as srv:
            srv.start()

            # ---- readv: naive vs coalesced vs coalesced+cache ----------
            url = srv.url("hot.bskt")
            n_baskets = None

            def stats_delta(fn):
                before = dict(srv.stats)
                fn()
                return {k: srv.stats[k] - before[k] for k in before}

            with RemoteBasketFile(url, wire=None) as rf:
                n_baskets = len(rf.branches["x"]["baskets"])

                def naive():
                    for i in range(n_baskets):
                        rf.read_basket_raw("x", i)
                t_naive = _best(naive, reps)
                d_naive = stats_delta(naive)

            with RemoteBasketFile(url, wire=None, batch_baskets=64) as rf:
                def coalesced():
                    np.testing.assert_array_equal(rf.read_branch("x")[:4],
                                                  hot[:4])
                t_coal = _best(coalesced, reps)
                d_coal = stats_delta(coalesced)

            cache = TieredCache(mem_bytes=4 * hot_mb * MB)
            with RemoteBasketFile(url, wire=None, batch_baskets=64,
                                  cache=cache) as rf:
                rf.read_branch("x")            # warm both tiers
                def cached():
                    np.testing.assert_array_equal(rf.read_branch("x")[:4],
                                                  hot[:4])
                t_cache = _best(cached, reps)
                d_cache = stats_delta(cached)
            cache.close()

            for case, t, d in [("naive-b1", t_naive, d_naive),
                               ("coalesced-b64", t_coal, d_coal),
                               ("coalesced+cache", t_cache, d_cache)]:
                rows.append({
                    "bench": "fig_remote", "stage": "readv", "case": case,
                    "bytes": hot.nbytes, "baskets": n_baskets,
                    "MBps": _mbps(hot.nbytes, t),
                    "speedup_vs_naive": round(t_naive / t, 2),
                    "round_trips": d["requests"], "preads": d["preads"],
                    "decode_MBps": "", "wire_bytes": "", "wire_algos": "",
                })

            # ---- wire: archive vs transcoded (read-bound objective) ----
            aurl = srv.url("archive.bskt")
            for case, wire in [("archive-lzma", None), ("transcoded", "auto")]:
                with RemoteBasketFile(aurl, wire=wire,
                                      objective="max_read_tput",
                                      batch_baskets=64) as rf:
                    nb = len(rf.branches["y"]["baskets"])

                    def e2e():
                        np.testing.assert_array_equal(rf.read_branch("y")[:4],
                                                      arch[:4])
                    t_e2e = _best(e2e, reps)
                    pairs = rf.fetch_wire("y", range(nb))
                    wire_bytes = sum(len(p) for p, _m in pairs)
                    algos = sorted({m["algo"] for _p, m in pairs})
                    t_dec = _best(lambda: _decode_all(pairs), reps)
                rows.append({
                    "bench": "fig_remote", "stage": "wire", "case": case,
                    "bytes": arch.nbytes, "baskets": nb,
                    "MBps": _mbps(arch.nbytes, t_e2e),
                    "speedup_vs_naive": "", "round_trips": "", "preads": "",
                    "decode_MBps": _mbps(arch.nbytes, t_dec),
                    "wire_bytes": wire_bytes,
                    "wire_algos": "+".join(algos),
                })

    emit(rows, out_csv)
    return rows


def check(rows: list[dict]) -> int:
    """CI perf-smoke gate (see module docstring)."""
    ok = True

    def fail(msg):
        nonlocal ok
        print(f"FAIL: {msg}", file=sys.stderr)
        ok = False

    readv = {r["case"]: r for r in rows if r["stage"] == "readv"}
    if "naive-b1" not in readv or "coalesced+cache" not in readv:
        fail("missing readv rows")
    else:
        s = readv["coalesced+cache"]["speedup_vs_naive"]
        if s < 2.0:
            fail(f"coalesced+cached remote read only {s}x vs naive (< 2x)")
        if readv["coalesced-b64"]["round_trips"] >= \
                readv["naive-b1"]["round_trips"]:
            fail("vectored read did not reduce round-trips")
    wire = {r["case"]: r for r in rows if r["stage"] == "wire"}
    if "archive-lzma" not in wire or "transcoded" not in wire:
        fail("missing wire rows")
    else:
        if wire["transcoded"]["decode_MBps"] <= wire["archive-lzma"]["decode_MBps"]:
            fail(f"transcoded-wire decode {wire['transcoded']['decode_MBps']} "
                 f"MB/s not faster than archive wire "
                 f"{wire['archive-lzma']['decode_MBps']} MB/s")
        if wire["transcoded"]["wire_algos"] == "lzma":
            fail("read-bound objective did not transcode the archive wire")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpora, fewer repeats")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless coalesced+cached beats naive "
                         ">=2x and the transcoded wire decodes faster "
                         "(CI perf-smoke)")
    ap.add_argument("--out", default="artifacts/bench/fig_remote.csv")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    return check(rows) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
