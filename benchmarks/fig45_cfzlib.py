"""Figures 4-5: the CF-ZLIB mechanisms, measured tier by tier.

Fig 4 (compression speed, ref-zlib vs CF patch set): reproduced two ways —
 (a) checksum tiers: adler32 naive loop vs vectorized (_mm_sad_epu8
     analogue) vs C library; crc32 bitwise vs table vs slice-by-8 vs C
     (Fig 5's "with/without hardware crc32" contrast);
 (b) match-hashing: our from-scratch deflate with reference TRIPLET
     hashing vs CF QUADRUPLET hashing at the paper's fast levels (1-5).
"""

from __future__ import annotations

import numpy as np

from repro.core import checksum as cs
from repro.core import repro_deflate as rdef

from .common import emit, paper_tree_bytes, time_fn


def run(out_csv: str | None = None) -> list[dict]:
    rng = np.random.default_rng(3)
    data = bytes(rng.integers(0, 256, 1 << 20, dtype=np.uint8))   # 1 MiB
    n = len(data)
    rows = []

    adler_tiers = [
        ("adler32_naive", cs.adler32_naive, data[: n // 64], n // 64),
        ("adler32_vector", cs.adler32_vector, data, n),
        ("adler32_c", cs.adler32_hw, data, n),
    ]
    for name, fn, payload, nb in adler_tiers:
        dt = time_fn(fn, payload, repeat=3, min_time=0.02)
        rows.append({"bench": "fig4_checksum", "tier": name,
                     "MBps": round(nb / dt / 1e6, 2)})

    crc_tiers = [
        ("crc32_bitwise", cs.crc32_naive, data[: n // 256], n // 256),
        ("crc32_table", cs.crc32_table, data[: n // 64], n // 64),
        ("crc32_slice8", cs.crc32_slice8, data[: n // 4], n // 4),
        ("crc32_c", cs.crc32_hw, data, n),
    ]
    for name, fn, payload, nb in crc_tiers:
        dt = time_fn(fn, payload, repeat=3, min_time=0.02)
        rows.append({"bench": "fig5_crc", "tier": name,
                     "MBps": round(nb / dt / 1e6, 2)})

    # (b) triplet vs quadruplet hashing in our deflate, fast levels
    tree = paper_tree_bytes()
    sample = b"".join(list(tree.values())[:6])[: 1 << 18]
    for level in (1, 3, 5):
        for mode in ("ref", "cf"):
            dt = time_fn(lambda: rdef.compress(sample, level=level, mode=mode),
                         repeat=1, min_time=0.0)
            out = rdef.compress(sample, level=level, mode=mode)
            rows.append({"bench": "fig4_hashing", "tier": f"{mode}-l{level}",
                         "MBps": round(len(sample) / dt / 1e6, 3),
                         "ratio": round(len(sample) / len(out), 3)})
    emit(rows, out_csv)
    return rows


if __name__ == "__main__":
    run("artifacts/bench/fig45.csv")
