"""Dictionary sizing (the paper §2.3/§3 open question): compression ratio
of small event records vs trained dictionary size, for zstd AND the
cross-codec reuse (zlib with the same zstd-trained dictionary)."""

from __future__ import annotations

import numpy as np

from repro.core import CompressionConfig, compress, train_dictionary

from .common import emit


def _small_records(n=400, rng=None):
    rng = rng or np.random.default_rng(11)
    recs = []
    for i in range(n):
        njet = int(rng.poisson(5))
        rec = (b'{"run":362104,"event":%d,"jets":[' % (i * 7)
               + b",".join(b'{"pt":%d.%02d,"eta":%d}'
                           % (20 + int(rng.exponential(30)), rng.integers(99),
                              rng.integers(-4, 5)) for _ in range(njet))
               + b"]}")
        recs.append(rec)
    return recs


def run(out_csv: str | None = None) -> list[dict]:
    recs = _small_records()
    train, test = recs[:300], recs[300:]
    total = sum(len(r) for r in test)
    rows = []
    base = sum(len(compress(r, CompressionConfig("zstd", 5))) for r in test)
    rows.append({"bench": "fig_dict", "algo": "zstd", "dict_bytes": 0,
                 "ratio": round(total / base, 3)})
    for size in (512, 2048, 8192, 32768):
        d = train_dictionary(train, size=size)
        for algo in ("zstd", "zlib"):
            cfg = CompressionConfig(algo, 5, dictionary=d)
            comp = sum(len(compress(r, cfg)) for r in test)
            rows.append({"bench": "fig_dict", "algo": algo,
                         "dict_bytes": len(d),
                         "ratio": round(total / comp, 3)})
    emit(rows, out_csv)
    return rows


if __name__ == "__main__":
    run("artifacts/bench/fig_dict.csv")
