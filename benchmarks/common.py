"""Shared benchmark utilities: the paper's test tree, timing, CSV output."""

from __future__ import annotations

import time

import numpy as np

from repro.configs.paper_io import PAPER_IO
from repro.data.events import make_events

__all__ = ["paper_tree_bytes", "time_fn", "emit", "write_json", "EVENTS"]

EVENTS = None


def paper_tree_bytes() -> dict[str, bytes]:
    """The paper's §2 artificial tree, serialized column-wise (Fig. 1)."""
    global EVENTS
    if EVENTS is None:
        EVENTS = make_events(PAPER_IO.n_events, PAPER_IO.seed)
    return {name: np.ascontiguousarray(arr).tobytes()
            for name, arr in EVENTS.items()}


def time_fn(fn, *args, repeat: int = 3, min_time: float = 0.05) -> float:
    """Best-of-repeat wall seconds; auto-loops tiny calls."""
    best = float("inf")
    for _ in range(repeat):
        n = 0
        t0 = time.perf_counter()
        while True:
            fn(*args)
            n += 1
            dt = time.perf_counter() - t0
            if dt >= min_time:
                break
        best = min(best, dt / n)
    return best


def write_json(path: str, benches: dict[str, list[dict]]) -> None:
    """Write a BENCH-style perf-trajectory file (same schema as
    ``benchmarks.run --json``) from one or more benches' rows — the
    per-figure ``--json`` flag for single-bench trajectory artifacts."""
    import json
    import os
    import platform
    import sys

    payload = {
        "schema": 1,
        "unix_time": time.time(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "benches": benches,
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {path} "
          f"({sum(len(v) for v in benches.values())} rows)")


def emit(rows: list[dict], path: str | None = None) -> None:
    """Print rows as CSV (and optionally save)."""
    if not rows:
        return
    cols = list(rows[0])
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(str(r.get(c, "")) for c in cols))
    text = "\n".join(lines)
    print(text)
    if path:
        import os
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(text + "\n")
