"""Chaos soak + hedging benchmark for the failure-hardened remote tier.

Three stages (DESIGN.md §14):

* **kinds** — each injectable fault kind (garble / drop / delay / reset /
  short), one at a time through a seeded :class:`repro.fault.ChaosProxy`
  with ``max_fires=1``, against a single client.  Deterministic: the
  fault *must* fire and the read *must* still return the right bytes.

* **soak** — the mixed run: two byte-identical replicas, replica A
  behind a chaos proxy (probabilistic garble/drop/delay/reset) *and* on
  rotting local storage (every pread garbled via the fdcache fault
  hook), plus a dead endpoint in every client's pool.  N client threads
  read every branch repeatedly.  Gates: **zero client-visible errors**
  and **byte identity** against a fault-free local read — the torn-wire /
  corrupt-disk noise must be fully absorbed by retry, failover, and
  cross-replica quarantine.

* **hedge** — replica A's proxy stalls half of its READV responses by
  100 ms; clients hold endpoints [stalled-A, clean-B].  The same read
  sequence runs with ``hedge=None`` and ``hedge=0.02``.  Gate: hedged
  p99 < unhedged p99 — the hedge escapes the stall instead of waiting
  it out.
"""

from __future__ import annotations

import os
import shutil
import socket
import sys
import tempfile
import threading
import time

import numpy as np

from repro.core.bfile import write_arrays
from repro.core.codec import CompressionConfig
from repro.fault import ChaosProxy, FaultPlan, FaultRule, pread_fault_hook
from repro.io import fdcache
from repro.remote import BasketServer, EndpointPool, RemoteBasketFile

from .common import emit

MB = 1 << 20


def _bench_dir():
    for d in ("/dev/shm", None):
        if d is None or (os.path.isdir(d) and os.access(d, os.W_OK)):
            return tempfile.TemporaryDirectory(dir=d, prefix="fig_fault_")


def _make_corpus(td: str, quick: bool) -> dict[str, np.ndarray]:
    """Two byte-identical replica directories under ``td``.  ``algo=none``
    keeps payloads raw so a garbled byte is exactly one checksum failure
    (the corrupt-quarantine path), never a codec-dependent decode error."""
    rows = 60_000 if quick else 400_000
    rng = np.random.default_rng(5)
    arrays = {
        "energy": np.cumsum(rng.integers(1, 9, rows)).astype(np.int64),
        "pid": rng.integers(0, 100, rows).astype(np.int32),
    }
    os.makedirs(os.path.join(td, "ra"))
    os.makedirs(os.path.join(td, "rb"))
    write_arrays(os.path.join(td, "ra", "soak.bskt"), arrays,
                 cfg_for=lambda n, a: CompressionConfig("none", 0),
                 target_basket_bytes=32 * 1024)
    shutil.copyfile(os.path.join(td, "ra", "soak.bskt"),
                    os.path.join(td, "rb", "soak.bskt"))
    return arrays


def _row(stage, case, value, unit, wall=""):
    return {"bench": "fig_fault", "stage": stage, "case": case,
            "wall_s": wall, "value": value, "unit": unit}


def _kind_rows(srv, arrays, quick: bool) -> list[dict]:
    """One deterministic firing per fault kind, read still correct."""
    rows = []
    for kind in ("garble", "drop", "delay", "reset", "short"):
        plan = FaultPlan([FaultRule(kind, direction="c2s" if kind == "reset"
                                    else "s2c", verb="readv", max_fires=1,
                                    delay_s=0.1)], seed=11)
        with ChaosProxy(srv.host, srv.port, plan) as px:
            t0 = time.perf_counter()
            with RemoteBasketFile(host=px.host, port=px.port,
                                  path="soak.bskt", wire=None,
                                  timeout=1.0, retries=4,
                                  backoff=0.01) as rf:
                got = rf.read_branch("energy")
            dt = time.perf_counter() - t0
        ok = bool((got == arrays["energy"]).all())
        fired = plan.counts().get(kind, 0)
        rows.append(_row("kinds", f"{kind}.fired", fired, "faults",
                         round(dt, 3)))
        rows.append(_row("kinds", f"{kind}.bytes",
                         "ok" if ok else "MISMATCH", ""))
    return rows


def _soak_rows(td, srv_a, srv_b, arrays, quick: bool) -> list[dict]:
    threads_n = 4 if quick else 8
    reps = 6 if quick else 10
    plan = FaultPlan([
        FaultRule("garble", p=0.25, direction="s2c", verb="readv"),
        FaultRule("drop", p=0.08, direction="s2c", verb="readv"),
        FaultRule("delay", p=0.35, delay_s=0.03, direction="s2c",
                  verb="readv"),
        FaultRule("reset", p=0.15, direction="c2s", verb="readv"),
    ], seed=23)
    # a dead-but-fast endpoint: bound then closed, connects are refused
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    dead = s.getsockname()[1]
    s.close()
    errors: list = []
    mismatches: list = []

    def worker(wid: int, px):
        try:
            # short cooldown: the default 2s bench would park the chaotic
            # replica for most of this soak after its first failure.  One
            # client session per rep — connections are sticky, so a
            # long-lived client settles on the clean replica after its
            # first failover and the chaos stops being exercised.
            pool = EndpointPool([("127.0.0.1", dead),
                                 (px.host, px.port),
                                 (srv_b.host, srv_b.port)], cooldown=0.1)
            for _ in range(reps):
                with RemoteBasketFile(
                        path="soak.bskt", endpoints=pool,
                        wire=None, timeout=1.0, retries=8, backoff=0.02,
                        busy_retries=20) as rf:
                    for name, want in arrays.items():
                        got = rf.read_branch(name)
                        if not (got == want).all():
                            mismatches.append((wid, name))
        except Exception as e:
            errors.append((wid, repr(e)))

    # replica A: chaotic wire AND rotting disk.  Rot every 3rd pread (not
    # all of them): a fully-rotten A would push every client to B after
    # one quarantine round and the wire faults would never fire.
    hook = pread_fault_hook(match=os.path.join(td, "ra"), kind="garble",
                            every=3)
    prev_hook = fdcache.set_fault_hook(hook)
    t0 = time.perf_counter()
    try:
        with ChaosProxy(srv_a.host, srv_a.port, plan) as px:
            ts = [threading.Thread(target=worker, args=(i, px))
                  for i in range(threads_n)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(timeout=300)
    finally:
        fdcache.set_fault_hook(prev_hook)
    wall = time.perf_counter() - t0
    counts = plan.counts()
    rows = [_row("soak", "clients", threads_n, "threads", round(wall, 3)),
            _row("soak", "reads",
                 threads_n * reps * len(arrays), "branch reads"),
            _row("soak", "errors", len(errors), "errors"),
            _row("soak", "mismatches", len(mismatches), "reads")]
    for kind in ("garble", "drop", "delay", "reset"):
        rows.append(_row("soak", f"injected.{kind}",
                         counts.get(kind, 0), "faults"))
    rows.append(_row("soak", "injected.diskrot", hook.fired, "preads"))
    for wid, err in errors[:3]:
        print(f"soak error (worker {wid}): {err}", file=sys.stderr)
    return rows


def _hedge_rows(srv_a, srv_b, arrays, quick: bool) -> list[dict]:
    reads = 12 if quick else 40
    rows = []
    p99s = {}
    for case, hedge in [("unhedged", None), ("hedged", 0.02)]:
        # a fresh proxy + same-seed plan per case: both arms see the same
        # stall pattern (100ms on half the READV responses)
        plan = FaultPlan([FaultRule("delay", p=0.5, delay_s=0.1,
                                    direction="s2c", verb="readv")],
                         seed=31)
        with ChaosProxy(srv_a.host, srv_a.port, plan) as px:
            lat = []
            with RemoteBasketFile(
                    path="soak.bskt",
                    endpoints=[(px.host, px.port),
                               (srv_b.host, srv_b.port)],
                    wire=None, timeout=5.0, retries=4, backoff=0.01,
                    hedge=hedge) as rf:
                for _ in range(reads):
                    t0 = time.perf_counter()
                    got = rf.read_branch("pid")
                    lat.append(time.perf_counter() - t0)
                    assert (got == arrays["pid"]).all()
        lat.sort()
        p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))]
        p99s[case] = p99
        rows.append(_row("hedge", f"{case}.p50",
                         round(lat[len(lat) // 2] * 1e3, 2), "ms"))
        rows.append(_row("hedge", f"{case}.p99",
                         round(p99 * 1e3, 2), "ms"))
        rows.append(_row("hedge", f"{case}.stalls",
                         plan.counts().get("delay", 0), "faults"))
    rows.append(_row("hedge", "speedup.p99",
                     round(p99s["unhedged"] / max(p99s["hedged"], 1e-9), 2),
                     "x"))
    return rows


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    with _bench_dir() as td:
        arrays = _make_corpus(td, quick)
        with BasketServer(os.path.join(td, "ra"), workers=0) as srv_a, \
                BasketServer(os.path.join(td, "rb"), workers=0) as srv_b:
            srv_a.start(), srv_b.start()
            rows = _kind_rows(srv_a, arrays, quick)
            rows += _soak_rows(td, srv_a, srv_b, arrays, quick)
            rows += _hedge_rows(srv_a, srv_b, arrays, quick)
    emit(rows, out_csv)
    return rows


def check(rows: list[dict]) -> int:
    """CI chaos gate (see module docstring)."""
    ok = True

    def fail(msg):
        nonlocal ok
        print(f"FAIL: {msg}", file=sys.stderr)
        ok = False

    by = {(r["stage"], r["case"]): r for r in rows}
    for kind in ("garble", "drop", "delay", "reset", "short"):
        f = by.get(("kinds", f"{kind}.fired"))
        if f is None or int(f["value"]) < 1:
            fail(f"fault kind {kind!r} never fired")
        b = by.get(("kinds", f"{kind}.bytes"))
        if b is None or b["value"] != "ok":
            fail(f"bytes wrong after injected {kind!r}")
    errs = by.get(("soak", "errors"))
    if errs is None or int(errs["value"]) != 0:
        fail(f"soak had client-visible errors: "
             f"{errs['value'] if errs else 'missing row'}")
    mm = by.get(("soak", "mismatches"))
    if mm is None or int(mm["value"]) != 0:
        fail("soak returned wrong bytes")
    wire = sum(int(by[k]["value"]) for k in by
               if k[0] == "soak" and k[1].startswith("injected.")
               and k[1] != "injected.diskrot")
    if wire < 3:
        fail(f"soak injected only {wire} wire faults — proves nothing")
    rot = by.get(("soak", "injected.diskrot"))
    if rot is None or int(rot["value"]) < 1:
        fail("soak never exercised the corrupt-basket quarantine path")
    hu = by.get(("hedge", "unhedged.p99"))
    hh = by.get(("hedge", "hedged.p99"))
    if hu is None or hh is None:
        fail("missing hedge quantiles")
    elif not float(hh["value"]) < float(hu["value"]):
        fail(f"hedged p99 {hh['value']}ms not better than "
             f"unhedged {hu['value']}ms")
    if ok:
        print("fig_fault check: all gates passed")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpus, fewer clients/reps")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the chaos soak absorbed "
                         "every injected fault (zero errors, byte "
                         "identity) and hedging beat the stalls (CI gate)")
    ap.add_argument("--out", default="artifacts/bench/fig_fault.csv")
    ap.add_argument("--json", default="",
                    help="also write the rows as a BENCH-style perf "
                         "trajectory JSON (cross-PR comparison)")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    if args.json:
        from .common import write_json
        write_json(args.json, {"fig_fault": rows})
    return check(rows) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
