"""Adaptive-tuning benchmark: repro.tune vs every static profile.

The paper's survey picks one (algo, level, preconditioner) per *use case*;
``repro.tune`` re-runs the survey per *branch* on sampled live data.  This
benchmark asks the acceptance question directly, on two corpora:

* **ckpt** — a model-zoo-style checkpoint state (float weight/moment
  planes + an int64 offset-like optimizer column), the checkpoint
  operating point;
* **events** — the paper's NanoAOD-like event tree (``repro.data.events``):
  18 mixed-dtype branches including the §2.2 offset arrays.

For each corpus, every static ``PROFILES`` entry is measured once (write
wall, read wall, compressed bytes).  Then for each declared objective
(``min_bytes`` / ``max_write_tput`` / ``max_read_tput``) a fresh tuner
writes the corpus ``STEPS`` times — the production shape: the first write
measures trials, later writes reuse cached decisions (what a checkpoint
series or shard sequence does) — and reports the objective metric plus
``overhead_frac`` = trial seconds / total write wall.

``--check`` is the CI perf-smoke gate: for each corpus and objective the
tuned run must match or beat the best static profile on that objective's
metric (2% tolerance; deterministic for bytes, measured for throughput —
throughput gates compare against a *paired* re-measure of the best static
profile taken back-to-back with the tuned series, because machine speed
drifts over the minutes the full sweep takes),
and tuning overhead must stay ≤ 5% of write wall-time (≤ 25% under
``--quick``, whose corpora are deliberately tiny — per-branch trial cost
is constant, so only the full-size run states the 5% claim).
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

import numpy as np

from repro.core.bfile import BasketFile, write_arrays
from repro.core.policy import PROFILES, choose
from repro.data.events import make_events
from repro.tune import Tuner

from .common import emit

MB = 1 << 20
OBJECTIVES = ["min_bytes", "max_write_tput", "max_read_tput"]
TOL = 0.02          # acceptance tolerance on every objective metric
MAX_OVERHEAD = 0.05  # tuning wall / write wall at full corpus size


def _ckpt_corpus(total_bytes: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(31)
    nf = (total_bytes * 3 // 4) // 4
    ni = (total_bytes // 4) // 8
    return {
        "params.w": rng.standard_normal(nf // 2).astype(np.float32).reshape(-1, 256),
        "opt.m": rng.standard_normal(nf // 2).astype(np.float32),
        "opt.off": np.cumsum(rng.integers(1, 9, ni)).astype(np.int64),
        "step": np.int64(4321),
    }


def _read_all(path: str) -> int:
    with BasketFile(path) as f:
        return sum(f.read_branch(n).nbytes for n in f.branch_names())


def _best(fn, reps):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _mbps(nbytes: int, seconds: float) -> float:
    return round(nbytes / max(seconds, 1e-9) / 1e6, 1)


def _row(corpus, case, raw, comp, write_s, read_s, raw_per_write=None,
         overhead="", trial_s=""):
    per_file = raw_per_write or raw     # tuned rows write `steps` files
    return {
        "bench": "fig_tune", "corpus": corpus, "case": case,
        "raw_bytes": raw, "comp_bytes": comp,
        "ratio": round(per_file / max(comp, 1), 3),
        "write_MBps": _mbps(raw, write_s),
        "read_MBps": _mbps(per_file, read_s),
        "overhead_frac": overhead, "trial_s": trial_s,
        "paired_static": "",
    }


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    rows: list[dict] = []
    # the tuned workload is a write *series* (one tuner, `steps` files) —
    # the production shape: a checkpoint sequence or shard corpus tunes
    # once and reuses decisions; statics pay no tuning so one write each
    # measures them.  Full mode is sized so per-corpus trial cost (which
    # is constant) amortizes the way it does in production.
    steps = 2 if quick else 6
    read_reps = 2 if quick else 3
    ckpt_mb = 4 if quick else 32
    n_events = 20_000 if quick else 90_000

    corpora = {
        "ckpt": _ckpt_corpus(ckpt_mb * MB),
        "events": make_events(n_events, seed=5),
    }
    with tempfile.TemporaryDirectory(prefix="fig_tune_") as td:
        for cname, arrays in corpora.items():
            raw = sum(np.ascontiguousarray(a).nbytes for a in arrays.values())

            # ---- static PROFILES sweep (one write + timed reads each) ---
            statics: dict[str, dict] = {}
            for prof, p in PROFILES.items():
                if p["algo"] == "none":
                    continue        # "off" stores raw bytes: not a codec
                path = os.path.join(td, f"{cname}-{prof}.bskt")
                t0 = time.perf_counter()
                write_arrays(path, arrays,
                             cfg_for=lambda n, a, _p=prof: choose(n, a, _p))
                w_s = time.perf_counter() - t0
                r_s = _best(lambda: _read_all(path), read_reps)
                with BasketFile(path) as f:
                    comp = f.compressed_bytes()
                row = _row(cname, f"static-{prof}", raw, comp, w_s, r_s)
                statics[prof] = {**row, "path": path}
                rows.append(row)

            # ---- tuned, per objective (write series, tuner shared) ------
            for obj in OBJECTIVES:
                tuner = Tuner(obj)
                t0 = time.perf_counter()
                for s in range(steps):
                    path = os.path.join(td, f"{cname}-{obj}-{s}.bskt")
                    write_arrays(path, arrays, tuner=tuner)
                w_s = time.perf_counter() - t0
                r_s = _best(lambda: _read_all(path), read_reps)
                with BasketFile(path) as f:
                    comp = f.compressed_bytes()
                overhead = tuner.stats["trial_s"] / max(w_s, 1e-9)
                row = _row(
                    cname, f"tuned-{obj}", raw * steps, comp,
                    w_s, r_s, raw_per_write=raw,
                    overhead=round(overhead, 4),
                    trial_s=round(tuner.stats["trial_s"], 3))
                # paired baseline for the throughput gates: machine speed
                # drifts over the minutes the sweep takes, so the best
                # static profile is re-measured back-to-back with the
                # tuned series it gates — same phase, same cache state
                if obj == "max_write_tput":
                    bp = max(statics, key=lambda k: statics[k]["write_MBps"])
                    t0 = time.perf_counter()
                    write_arrays(os.path.join(td, f"{cname}-paired.bskt"),
                                 arrays,
                                 cfg_for=lambda n, a, _p=bp: choose(n, a, _p))
                    row["paired_static"] = _mbps(
                        raw, time.perf_counter() - t0)
                elif obj == "max_read_tput":
                    bp = max(statics, key=lambda k: statics[k]["read_MBps"])
                    row["paired_static"] = _mbps(raw, _best(
                        lambda: _read_all(statics[bp]["path"]), read_reps))
                rows.append(row)

    emit(rows, out_csv)
    return rows


def check(rows: list[dict], quick: bool = False) -> int:
    """CI perf-smoke gate (see module docstring)."""
    ok = True
    # quick mode shrinks the corpora but not the (constant) per-branch
    # trial cost, so only the full-size run states the <=5% claim; the
    # quick gate is a regression tripwire, not the acceptance number
    max_overhead = 0.5 if quick else MAX_OVERHEAD

    def fail(msg):
        nonlocal ok
        print(f"FAIL: {msg}", file=sys.stderr)
        ok = False

    corpora = sorted({r["corpus"] for r in rows})
    if not corpora:
        fail("no rows")
    for cname in corpora:
        statics = [r for r in rows
                   if r["corpus"] == cname and r["case"].startswith("static-")]
        if not statics:
            fail(f"{cname}: no static rows")
            continue
        for obj in OBJECTIVES:
            tuned = [r for r in rows if r["corpus"] == cname
                     and r["case"] == f"tuned-{obj}"]
            if not tuned:
                fail(f"{cname}: no tuned-{obj} row")
                continue
            t = tuned[0]
            if obj == "min_bytes":
                best = min(r["comp_bytes"] for r in statics)
                if t["comp_bytes"] > best * (1 + TOL):
                    fail(f"{cname}/{obj}: tuned {t['comp_bytes']}B > "
                         f"best static {best}B * {1 + TOL}")
            else:
                col = "write_MBps" if obj == "max_write_tput" else "read_MBps"
                # gate against the paired same-phase re-measure of the
                # best static profile when present (machine speed drifts
                # over the minutes the sweep takes); the sweep values
                # remain in the rows for reporting
                best = t.get("paired_static") or max(r[col] for r in statics)
                if t[col] < best * (1 - TOL):
                    fail(f"{cname}/{obj}: tuned {t[col]} {col} < "
                         f"best static {best} * {1 - TOL}")
            if t["overhead_frac"] > max_overhead:
                fail(f"{cname}/{obj}: tuning overhead "
                     f"{t['overhead_frac']} > {max_overhead} of write wall")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small corpora, fewer steps (CI smoke)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless tuned matches/beats every "
                         "static profile per objective with bounded "
                         "tuning overhead")
    ap.add_argument("--out", default="artifacts/bench/fig_tune.csv")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    return check(rows, quick=args.quick) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
