"""Roofline table from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh): the three terms in seconds
    compute    = HLO_FLOPs / (197 TFLOP/s bf16)
    memory     = HLO_bytes / (819 GB/s HBM)
    collective = wire_bytes / (50 GB/s ICI link)
(all per-device quantities from the SPMD module), the dominant term,
MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference), the
useful-compute ratio MODEL_FLOPS/HLO_FLOPs, and the roofline fraction
(model-flop time / dominant term).
"""

from __future__ import annotations

import glob
import json
import os

from .common import emit

V5E_FLOPS = 197e12


def load_records(art_dir: str = "artifacts/dryrun", tag: str = "") -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(art_dir, f"*{tag}.json"))):
        base = os.path.basename(p)[:-5]
        if tag:
            if not base.endswith(tag):
                continue
        elif base.count("__") != 2 or not base.split("__")[2] in ("16x16", "2x16x16"):
            continue
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def rows_from(recs: list[dict]) -> list[dict]:
    rows = []
    for r in recs:
        t = r["roofline_s"]
        dom = max(t, key=t.get)
        model_t = r["model_flops_global"] / r["devices"] / V5E_FLOPS
        hlo_flops = r["per_device"]["hlo_flops"]
        useful = (r["model_flops_global"] / r["devices"]) / max(hlo_flops, 1)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_ms": round(t["compute"] * 1e3, 2),
            "memory_ms": round(t["memory"] * 1e3, 2),
            "collective_ms": round(t["collective"] * 1e3, 2),
            "bottleneck": dom,
            "useful_flops_ratio": round(useful, 3),
            "roofline_frac": round(model_t / max(max(t.values()), 1e-30), 4),
            "peak_GiB": round(r["per_device"]["peak_bytes"] / 2**30, 2),
        })
    rows.sort(key=lambda x: (x["mesh"], x["arch"], x["shape"]))
    return rows


def run(out_csv: str | None = None, art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = load_records(art_dir)
    rows = rows_from(recs)
    emit(rows, out_csv)
    if rows:
        single = [r for r in rows if r["mesh"] == "16x16"]
        worst = min(single, key=lambda r: r["roofline_frac"]) if single else None
        coll = max(single, key=lambda r: r["collective_ms"]) if single else None
        print(f"# cells={len(rows)}  worst-roofline={worst['arch']}/{worst['shape']}"
              f" ({worst['roofline_frac']})  most-collective={coll['arch']}/{coll['shape']}"
              f" ({coll['collective_ms']}ms)")
    return rows


if __name__ == "__main__":
    run("artifacts/bench/roofline.csv")
