"""Continuous-profiling benchmark: sampler overhead, hotspot
attribution, crash flight recorder (DESIGN.md §17).

Three stages, each answering "can the profiler + flight recorder run in
production?":

* **overhead** — a loopback READV workload (every basket of both
  branches through ``fetch_wire``) with the sampling profiler on
  (``DEFAULT_HZ``, RSS watermarks armed) vs off, interleaved same-phase
  A/B so machine drift cancels, best-of-reps.  The CI gate holds the
  profiled run within **3%** (+ a timer-jitter epsilon) of the
  unprofiled run — a 67 Hz wall-clock sampler must be invisible at
  wire granularity.

* **hotspot** — a synthetic spin function burning CPU inside a root
  span while the profiler samples at 250 Hz.  ``--check`` asserts the
  spin function holds the **plurality of self samples** and that the
  fold stacks attribute it to ``span:fig.hot`` — the two properties a
  flamegraph is useless without.

* **postmortem** — a subprocess installs the flight recorder and the
  profiler, does real work (counter + span + spin), then dies on an
  unhandled exception.  ``--check`` asserts the crash left a
  ``repro-flight`` bundle carrying metrics, trace events, and profile
  samples, and that ``tools/obstat.py --postmortem`` renders it —
  the ISSUE-10 acceptance shape.
"""

from __future__ import annotations

import glob
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro import obs
from repro.core.bfile import write_arrays
from repro.core.codec import CompressionConfig
from repro.remote import BasketServer, RemoteBasketFile

from .common import emit

MB = 1 << 20
OVERHEAD_BUDGET = 0.03          # the CI gate: <3% on loopback READV
ABS_EPS_S = 0.010               # timer-jitter floor for very fast runs
HOT_MIN_SAMPLES = 5             # hotspot stage must actually sample

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the crashing workload for the postmortem stage: real spans, real
# counters, real samples, then an unhandled exception
_CRASH_SCRIPT = r"""
import time
from repro import obs
obs.flight.install(interval_s=0.05)
obs.profile.start(hz=200, mem="rss")
c = obs.counter("fig.crash_work")
with obs.trace.span("fig.doomed"):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < 0.4:
        for _ in range(1000):
            c.inc()
raise RuntimeError("fig_profile synthetic crash")
"""


def _bench_dir():
    for d in ("/dev/shm", None):
        if d is None or (os.path.isdir(d) and os.access(d, os.W_OK)):
            return tempfile.TemporaryDirectory(dir=d, prefix="fig_prof_")


def _write_events(td: str, size: int) -> str:
    rng = np.random.default_rng(29)
    path = os.path.join(td, "events.bskt")
    write_arrays(path,
                 {"energy": np.cumsum(rng.integers(1, 9, size // 8))
                  .astype(np.int64),
                  "pid": rng.integers(0, 100, size // 32).astype(np.int32)},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1, "delta8"),
                 target_basket_bytes=64 * 1024)
    return path


def _read_all(rf: RemoteBasketFile, name: str) -> None:
    nb = len(rf.branches[name]["baskets"])
    rf.fetch_wire(name, list(range(nb)))


def _overhead_rows(quick: bool) -> list[dict]:
    reps = 3 if quick else 5
    size = (4 if quick else 16) * MB
    t_on = t_off = float("inf")
    with _bench_dir() as td:
        _write_events(td, size)
        with BasketServer(td, workers=4, heat=False) as srv:
            srv.start()
            with RemoteBasketFile(srv.url("events.bskt"), wire=None,
                                  batch_baskets=64) as rf:
                _read_all(rf, "energy")         # warm conns + page cache
                for _ in range(reps):
                    # interleaved same-phase A/B: drift hits both arms
                    t0 = time.perf_counter()
                    _read_all(rf, "energy")
                    _read_all(rf, "pid")
                    t_off = min(t_off, time.perf_counter() - t0)
                    obs.profile.start(hz=obs.profile.DEFAULT_HZ, mem="rss")
                    t0 = time.perf_counter()
                    _read_all(rf, "energy")
                    _read_all(rf, "pid")
                    t_on = min(t_on, time.perf_counter() - t0)
                    obs.profile.stop()
                    obs.profile.reset()     # bounded folds; keep arms equal
                    obs.trace.clear()
    pct = (t_on - t_off) / t_off * 100.0
    rows = []
    for case, t in [("profiler-off", t_off), ("profiler-on", t_on)]:
        rows.append({"bench": "fig_profile", "stage": "overhead",
                     "case": case, "wall_s": round(t, 4),
                     "overhead_pct": round(pct, 2)
                     if case == "profiler-on" else "",
                     "value": "", "unit": ""})
    return rows


def _spin(n: int) -> int:
    acc = 1
    for _ in range(n):
        acc = (acc * 1103515245 + 12345) & 0xFFFFFFFF
    return acc


def _hotspot_rows(quick: bool) -> list[dict]:
    budget = 0.3 if quick else 0.8
    obs.profile.reset()
    obs.profile.start(hz=250)
    try:
        with obs.trace.span("fig.hot"):
            t0 = time.perf_counter()
            while time.perf_counter() - t0 < budget:
                _spin(20000)
    finally:
        obs.profile.stop()
    doc = obs.profile.drain()
    self_c = obs.profile.self_counts(doc)
    total = sum(self_c.values())
    top, top_n = "", 0
    if self_c:
        top, top_n = max(self_c.items(), key=lambda kv: kv[1])
    hot_ok = "_spin" in top
    attr_ok = any(k.startswith("span:fig.hot;") and "_spin" in k
                  for k in doc.get("folds", {}))
    return [
        {"bench": "fig_profile", "stage": "hotspot",
         "case": "samples.self_total", "wall_s": "", "overhead_pct": "",
         "value": total, "unit": "count"},
        {"bench": "fig_profile", "stage": "hotspot",
         "case": "hot.frame", "wall_s": "", "overhead_pct": "",
         "value": top if hot_ok else f"WRONG:{top}", "unit": ""},
        {"bench": "fig_profile", "stage": "hotspot",
         "case": "hot.share_pct", "wall_s": "", "overhead_pct": "",
         "value": round(top_n / total * 100.0, 1) if total else 0,
         "unit": "count"},
        {"bench": "fig_profile", "stage": "hotspot",
         "case": "span.attributed", "wall_s": "", "overhead_pct": "",
         "value": "ok" if attr_ok else "MISSING", "unit": ""},
    ]


def _postmortem_rows(quick: bool) -> list[dict]:
    rows = []
    with _bench_dir() as td:
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(_ROOT, "src"),
                   REPRO_FLIGHT_DIR=td)
        env.pop("REPRO_OBS", None)
        proc = subprocess.run([sys.executable, "-c", _CRASH_SCRIPT],
                              env=env, cwd=td, capture_output=True,
                              text=True, timeout=120)
        bundles = sorted(glob.glob(os.path.join(td, "*.json")))
        crash_ok = proc.returncode != 0 and len(bundles) == 1
        sections_ok = render_ok = False
        if bundles:
            doc = obs.flight.load_bundle(bundles[0])
            m = doc.get("final_metrics") or {}
            sections_ok = (
                doc.get("kind") == obs.flight.BUNDLE_KIND
                and (m.get("counters") or {}).get("fig.crash_work", 0) > 0
                and any(e.get("name") == "fig.doomed"
                        for e in doc.get("trace_events") or [])
                and (doc.get("profile") or {}).get("samples", 0) > 0
                and (doc.get("exception") or {}).get("type") == "RuntimeError")
            view = subprocess.run(
                [sys.executable, os.path.join(_ROOT, "tools", "obstat.py"),
                 "--postmortem", bundles[0]],
                env=env, capture_output=True, text=True, timeout=120)
            render_ok = (view.returncode == 0
                         and "RuntimeError" in view.stdout
                         and "fig_profile synthetic crash" in view.stdout)
    for case, ok in [("crash.bundle_written", crash_ok),
                     ("bundle.sections", sections_ok),
                     ("obstat.postmortem", render_ok)]:
        rows.append({"bench": "fig_profile", "stage": "postmortem",
                     "case": case, "wall_s": "", "overhead_pct": "",
                     "value": "ok" if ok else "MISSING", "unit": ""})
    return rows


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    rows = (_overhead_rows(quick) + _hotspot_rows(quick)
            + _postmortem_rows(quick))
    emit(rows, out_csv)
    return rows


def check(rows: list[dict]) -> int:
    """CI perf-smoke gate (see module docstring)."""
    ok = True

    def fail(msg):
        nonlocal ok
        print(f"FAIL: {msg}", file=sys.stderr)
        ok = False

    over = {r["case"]: r for r in rows if r["stage"] == "overhead"}
    if "profiler-on" not in over or "profiler-off" not in over:
        fail("missing overhead rows")
    else:
        t_on = over["profiler-on"]["wall_s"]
        t_off = over["profiler-off"]["wall_s"]
        if t_on > t_off * (1.0 + OVERHEAD_BUDGET) + ABS_EPS_S:
            fail(f"profiler overhead "
                 f"{over['profiler-on']['overhead_pct']}% exceeds the "
                 f"{OVERHEAD_BUDGET:.0%} budget (on={t_on}s off={t_off}s)")
    hot = {r["case"]: r for r in rows if r["stage"] == "hotspot"}
    n = int(hot.get("samples.self_total", {}).get("value") or 0)
    if n < HOT_MIN_SAMPLES:
        fail(f"hotspot stage captured only {n} samples "
             f"(want ≥ {HOT_MIN_SAMPLES})")
    frame = str(hot.get("hot.frame", {}).get("value") or "")
    if "_spin" not in frame or frame.startswith("WRONG:"):
        fail(f"hot function not the top self-time frame: {frame!r}")
    if hot.get("span.attributed", {}).get("value") != "ok":
        fail("no fold stack attributed the hot function to span:fig.hot")
    for case in ("crash.bundle_written", "bundle.sections",
                 "obstat.postmortem"):
        row = next((r for r in rows if r["case"] == case), None)
        if row is None or row["value"] != "ok":
            fail(f"postmortem stage: {case} failed")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller containers, fewer repeats")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless profiler overhead is "
                         "within budget, the synthetic hot function "
                         "dominates self samples under its span, and a "
                         "crashed worker leaves a flight bundle obstat "
                         "can render (CI perf-smoke)")
    ap.add_argument("--out", default="artifacts/bench/fig_profile.csv")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    return check(rows) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
