"""Observability benchmark: instrumentation overhead + access telemetry.

Two stages, both answering "can obs stay default-on?":

* **overhead** — the fig_zerocopy-style quick workload (``save_pytree`` +
  ``load_pytree`` of a mixed float/int tree) runs with obs enabled and
  with ``REPRO_OBS`` disabled (``obs.set_enabled`` — same process, same
  phase, interleaved reps so machine drift cancels), best-of-reps each.
  The CI gate holds the instrumented run within **2%** (+ a small
  absolute epsilon for timer jitter) of the disabled run.

* **micro** — per-event instrument costs (counter inc, histogram observe,
  span enter/exit), enabled vs disabled, in ns/op.  Not gated; the table
  is the evidence behind the budget.

* **hot-branches** — a fig_remote-style loopback workload: two branches
  read with deliberately skewed frequency through a ``BasketServer``,
  then the access telemetry is read back over the RBSP ``STATS`` verb.
  ``--check`` asserts the per-branch read counters rank the hot branch
  first and that per-verb latency histograms carry quantiles — the
  signal the ROADMAP's background repacker consumes.
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

import numpy as np

from repro import obs
from repro.checkpoint.manager import load_pytree, save_pytree
from repro.core.bfile import write_arrays
from repro.core.codec import CompressionConfig
from repro.remote import BasketServer, RemoteBasketFile
from repro.remote.client import fetch_stats

from .common import emit

MB = 1 << 20
OVERHEAD_BUDGET = 0.02          # the CI gate: <2% on the quick workload
ABS_EPS_S = 0.010               # timer-jitter floor for very fast runs


def _bench_dir():
    for d in ("/dev/shm", None):
        if d is None or (os.path.isdir(d) and os.access(d, os.W_OK)):
            return tempfile.TemporaryDirectory(dir=d, prefix="fig_obs_")


def _make_tree(mb: int) -> dict:
    rng = np.random.default_rng(7)
    n = mb * MB // 8
    return {
        "params": {"w": (rng.normal(0, 0.02, n // 2)
                         .astype(np.float32).reshape(-1, 256)),
                   "b": rng.normal(0, 0.02, n // 8).astype(np.float32)},
        "opt": {"mu": rng.normal(0, 1e-3, n // 2).astype(np.float32),
                "step": np.arange(n // 8, dtype=np.int64)},
    }


def _workload(td: str, tree: dict) -> None:
    path = os.path.join(td, "wl.bskt")
    save_pytree(path, tree, workers=2)
    load_pytree(path, workers=2)


def _overhead_rows(quick: bool) -> list[dict]:
    reps = 3 if quick else 5
    tree = _make_tree(4 if quick else 16)
    t_on = t_off = float("inf")
    with _bench_dir() as td:
        _workload(td, tree)                      # warm pools, page cache
        for _ in range(reps):
            # interleaved same-phase A/B: drift hits both arms equally
            prev = obs.set_enabled(False)
            try:
                t0 = time.perf_counter()
                _workload(td, tree)
                t_off = min(t_off, time.perf_counter() - t0)
            finally:
                obs.set_enabled(prev)
            t0 = time.perf_counter()
            _workload(td, tree)
            t_on = min(t_on, time.perf_counter() - t0)
    pct = (t_on - t_off) / t_off * 100.0
    rows = []
    for case, t in [("obs-off", t_off), ("obs-on", t_on)]:
        rows.append({"bench": "fig_obs", "stage": "overhead", "case": case,
                     "wall_s": round(t, 4),
                     "overhead_pct": round(pct, 2) if case == "obs-on" else "",
                     "value": "", "unit": ""})
    return rows


def _micro_rows() -> list[dict]:
    n = 200_000
    rows = []

    def best(fn, reps=3):
        # best-of-reps: single 200k-iteration loops wobble ~2x under VM
        # clock jitter, and the trajectory gate (tools/benchdiff.py)
        # compares these numbers across PRs
        t = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            t = min(t, time.perf_counter() - t0)
        return t

    for case, enabled in [("enabled", True), ("disabled", False)]:
        prev = obs.set_enabled(enabled)
        try:
            c = obs.counter("fig_obs.micro")
            h = obs.histogram("fig_obs.micro_s")
            def incs():
                for _ in range(n):
                    c.inc()

            def observes():
                for _ in range(n):
                    h.observe(1e-3)
            t_c = best(incs)
            t_h = best(observes)

            def spans():
                for _ in range(n // 10):
                    with obs.trace.span("fig_obs.micro"):
                        pass
            t_s = best(spans)
        finally:
            obs.set_enabled(prev)
        for op, t, m in [("counter.inc", t_c, n), ("hist.observe", t_h, n),
                         ("trace.span", t_s, n // 10)]:
            rows.append({"bench": "fig_obs", "stage": "micro",
                         "case": f"{op}/{case}", "wall_s": "",
                         "overhead_pct": "",
                         "value": round(t / m * 1e9, 1), "unit": "ns/op"})
    obs.trace.clear()           # micro spans must not pollute captures
    return rows


def _hot_branch_rows(quick: bool) -> list[dict]:
    rows = []
    size = (4 if quick else 16) * MB
    rng = np.random.default_rng(11)
    hot = np.cumsum(rng.integers(1, 9, size // 8)).astype(np.int64)
    cold = rng.integers(0, 100, size // 32).astype(np.int32)
    with _bench_dir() as td:
        write_arrays(os.path.join(td, "events.bskt"),
                     {"energy": hot, "pid": cold},
                     cfg_for=lambda n, a: CompressionConfig("zlib", 1,
                                                            "delta8"),
                     target_basket_bytes=64 * 1024)
        with BasketServer(td, workers=4) as srv:
            srv.start()
            with RemoteBasketFile(srv.url("events.bskt"), wire=None,
                                  batch_baskets=64) as rf:
                for _ in range(5):              # skewed access: energy hot
                    rf.read_branch("energy")
                rf.read_branch("pid")
            body = fetch_stats(srv.host, srv.port)
    snap = body.get("metrics") or {}
    from repro.obs.__main__ import _hist_stats, hot_branches
    for branch, path, _delta, total in hot_branches(
            snap.get("counters", {}), {}, top=5):
        rows.append({"bench": "fig_obs", "stage": "hot-branches",
                     "case": f"reads/{branch}", "wall_s": "",
                     "overhead_pct": "", "value": total, "unit": "reads"})
    h = snap.get("hists", {}).get("server.request_s{verb=readv}")
    if h:
        n, _mean, p50, p99 = _hist_stats(h)
        rows.append({"bench": "fig_obs", "stage": "hot-branches",
                     "case": "readv.p50", "wall_s": "", "overhead_pct": "",
                     "value": round(p50 * 1e3, 3), "unit": "ms"})
        rows.append({"bench": "fig_obs", "stage": "hot-branches",
                     "case": "readv.p99", "wall_s": "", "overhead_pct": "",
                     "value": round(p99 * 1e3, 3), "unit": "ms"})
    return rows


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    rows = _overhead_rows(quick) + _micro_rows() + _hot_branch_rows(quick)
    emit(rows, out_csv)
    return rows


def check(rows: list[dict]) -> int:
    """CI perf-smoke gate (see module docstring)."""
    ok = True

    def fail(msg):
        nonlocal ok
        print(f"FAIL: {msg}", file=sys.stderr)
        ok = False

    over = {r["case"]: r for r in rows if r["stage"] == "overhead"}
    if "obs-on" not in over or "obs-off" not in over:
        fail("missing overhead rows")
    else:
        t_on, t_off = over["obs-on"]["wall_s"], over["obs-off"]["wall_s"]
        if t_on > t_off * (1.0 + OVERHEAD_BUDGET) + ABS_EPS_S:
            fail(f"instrumentation overhead {over['obs-on']['overhead_pct']}% "
                 f"exceeds the {OVERHEAD_BUDGET:.0%} budget "
                 f"(on={t_on}s off={t_off}s)")
    reads = [r for r in rows if r["stage"] == "hot-branches"
             and str(r["case"]).startswith("reads/")]
    if len(reads) < 2:
        fail("STATS telemetry returned fewer than 2 per-branch counters")
    else:
        ranked = sorted(reads, key=lambda r: -int(r["value"]))
        if not str(ranked[0]["case"]).endswith("energy"):
            fail(f"hot branch ranked wrong: {[r['case'] for r in ranked]}")
    if not any(r["case"] == "readv.p99" for r in rows):
        fail("missing readv latency quantiles from STATS")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller tree, fewer repeats")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless instrumentation overhead is "
                         "within budget and STATS telemetry ranks the hot "
                         "branch (CI perf-smoke)")
    ap.add_argument("--out", default="artifacts/bench/fig_obs.csv")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    return check(rows) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
