"""Entropy-stage & LZ4-decode microbenchmark: legacy vs vectorized cores.

Measures the PR-2 vectorized codec cores against the pre-vectorization
paths they replaced (both kept importable exactly for this comparison):

* **Huffman** — ``encode(n_streams=1)`` + the serial ``_decode_legacy``
  loop vs the N-stream container + lockstep decoder (``repro.core.huffman``).
* **LZ4 block decode** — ``_decompress_block_legacy`` (single-pass serial)
  vs the two-pass ``decompress_block`` (``repro.core.tokexec``).

Baskets (1 MiB, truncatable):

* ``text``   — small-vocabulary record text: the entropy-coder workload.
* ``xref``   — remix of a 24 KiB seed window into 4-6 byte fragments:
  dense far-referencing sequences, the per-sequence-overhead workload the
  two-pass decoder targets (dictionary/record-style reuse).
* ``offsets_shuf`` — shuffle4-preconditioned ROOT offset array (Fig. 6
  motif): close-referencing byte planes, the two-pass decoder's worst
  regime (it degrades to a serial loop there — reported, not hidden).
* ``random`` — incompressible, exercises the serial fast route.

``--check`` exits non-zero unless vectorized Huffman decode beats the
legacy path on the 1 MiB text basket — the CI perf-smoke gate.
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import huffman, lz4
from repro.core.precond import apply_precond

from .common import emit

MB = 1 << 20


def _basket_text(size: int) -> bytes:
    rng = np.random.default_rng(11)
    words = [bytes(rng.integers(97, 122, rng.integers(4, 12), dtype=np.uint8))
             for _ in range(4000)]
    picks = rng.integers(0, 4000, size // 5 + 16)
    return b" ".join(words[i] for i in picks)[:size]


def _basket_xref(size: int) -> bytes:
    rng = np.random.default_rng(7)
    seed = rng.integers(0, 256, 24 << 10, dtype=np.uint8).tobytes()
    parts = [seed]
    total = len(seed)
    while total < size:
        ln = int(rng.integers(4, 7))
        off = int(rng.integers(0, (24 << 10) - ln))
        parts.append(seed[off:off + ln])
        total += ln
    return b"".join(parts)[:size]


def _basket_offsets_shuf(size: int) -> bytes:
    rng = np.random.default_rng(3)
    offs = (0x01000000 + np.cumsum(rng.integers(1, 5, size // 4))).astype(">u4")
    return apply_precond("shuffle4", offs.tobytes())[:size]


def _basket_random(size: int) -> bytes:
    rng = np.random.default_rng(5)
    return bytes(rng.integers(0, 256, size, dtype=np.uint8))


BASKETS = {
    "text": _basket_text,
    "xref": _basket_xref,
    "offsets_shuf": _basket_offsets_shuf,
    "random": _basket_random,
}

# decode-side benchmark: compress xref with the HC matcher so fragments
# actually become matches (the greedy table is too small for a full window)
_LZ4_LEVEL = {"text": 1, "xref": 9, "offsets_shuf": 1, "random": 1}


def _mbps(nbytes: int, seconds: float) -> float:
    return round(nbytes / seconds / 1e6, 2)


def _ab_best(fn_a, fn_b, reps: int) -> tuple[float, float]:
    """Interleaved best-of-reps for two callables.

    Alternating A and B samples them across the same time window, so
    machine-load drift hits both sides instead of skewing the ratio the
    way two back-to-back ``time_fn`` windows can."""
    import time as _time
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = _time.perf_counter()
        fn_a()
        best_a = min(best_a, _time.perf_counter() - t0)
        t0 = _time.perf_counter()
        fn_b()
        best_b = min(best_b, _time.perf_counter() - t0)
    return best_a, best_b


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    sizes = [MB] if quick else [64 << 10, 256 << 10, MB]
    reps = 3 if quick else 5
    rows: list[dict] = []

    for size in sizes:
        data = _basket_text(size)
        legacy_blob = huffman.encode(data, n_streams=1)
        vect_blob = huffman.encode(data)
        t_el, t_ev = _ab_best(lambda: huffman.encode(data, 1),
                              lambda: huffman.encode(data), reps)
        t_dl, t_dv = _ab_best(lambda: huffman.decode(legacy_blob),
                              lambda: huffman.decode(vect_blob), reps)
        assert huffman.decode(vect_blob) == data
        rows.append({
            "bench": "fig_entropy", "stage": "huffman", "basket": "text",
            "size": size,
            "enc_legacy_MBps": _mbps(size, t_el),
            "enc_vect_MBps": _mbps(size, t_ev),
            "dec_legacy_MBps": _mbps(size, t_dl),
            "dec_vect_MBps": _mbps(size, t_dv),
            "dec_speedup": round(t_dl / t_dv, 2),
            "ratio_legacy": round(len(legacy_blob) / size, 4),
            "ratio_vect": round(len(vect_blob) / size, 4),
        })

    for basket, make in BASKETS.items():
        for size in sizes:
            data = make(size)
            blob = lz4.compress_block(data, _LZ4_LEVEL[basket])
            t_l, t_v = _ab_best(
                lambda: lz4._decompress_block_legacy(blob, size),
                lambda: lz4.decompress_block(blob, size), reps)
            assert lz4.decompress_block(blob, size) == data
            rows.append({
                "bench": "fig_entropy", "stage": "lz4_decode",
                "basket": basket, "size": size,
                "enc_legacy_MBps": "", "enc_vect_MBps": "",
                "dec_legacy_MBps": _mbps(size, t_l),
                "dec_vect_MBps": _mbps(size, t_v),
                "dec_speedup": round(t_l / t_v, 2),
                "ratio_legacy": round(len(blob) / size, 4),
                "ratio_vect": round(len(blob) / size, 4),
            })

    emit(rows, out_csv)
    return rows


def check(rows: list[dict]) -> int:
    """CI perf-smoke gate: vectorized Huffman decode must beat legacy on a
    1 MiB basket, and the N-stream ratio must stay within 2%."""
    ok = True
    for r in rows:
        if r["stage"] == "huffman" and r["size"] == MB:
            if r["dec_speedup"] <= 1.0:
                print(f"FAIL: vectorized huffman decode not faster "
                      f"({r['dec_speedup']}x) on 1 MiB", file=sys.stderr)
                ok = False
            if r["ratio_vect"] > r["ratio_legacy"] * 1.02:
                print(f"FAIL: N-stream ratio {r['ratio_vect']} worse than "
                      f"legacy {r['ratio_legacy']} by >2%", file=sys.stderr)
                ok = False
    if not any(r["stage"] == "huffman" and r["size"] == MB for r in rows):
        print("FAIL: no 1 MiB huffman row", file=sys.stderr)
        ok = False
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="1 MiB baskets only, fewer repeats")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless vectorized huffman decode "
                         "beats legacy on 1 MiB (CI perf-smoke)")
    ap.add_argument("--out", default="artifacts/bench/fig_entropy.csv")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    return check(rows) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
