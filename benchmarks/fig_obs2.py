"""Distributed-observability benchmark: propagation overhead, durable
heat, stitched traces (DESIGN.md §16).

Three stages, each answering "can obs v2 stay default-on?":

* **propagation** — a loopback READV workload (every basket of both
  branches through ``fetch_wire``) with traceparent propagation on vs
  off (``RemoteBasketFile(propagate=...)``), interleaved same-phase A/B
  so machine drift cancels, best-of-reps.  The CI gate holds the
  propagating run within **2%** (+ a timer-jitter epsilon) of the
  non-propagating run — carrying a 55-byte ``tp`` and minting span ids
  must be free at wire granularity.

* **heat** — a 40x-skewed workload (hot branch read 40 rounds, cold
  once) against a server with instant heat flushing; the server is then
  **restarted** and the cold branch read once more.  ``--check``
  asserts the reloaded sidecar still ranks the hot branch first with
  ≥ 10x the cold branch's heat — durability plus EWMA accumulation
  across a restart, the property the ROADMAP repacker depends on.

* **stitch** — one traced loopback READV; the client ring and the
  server's ``STATS trace_events`` drain are stitched and the span tree
  rebuilt.  ``--check`` asserts the client fetch span is an ancestor of
  the server's readv/pread spans — the ISSUE-9 acceptance shape.
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

import numpy as np

from repro import obs
from repro.core.bfile import write_arrays
from repro.core.codec import CompressionConfig
from repro.remote import BasketServer, RemoteBasketFile
from repro.remote.client import fetch_stats

from .common import emit

MB = 1 << 20
OVERHEAD_BUDGET = 0.02          # the CI gate: <2% on loopback READV
ABS_EPS_S = 0.010               # timer-jitter floor for very fast runs
HEAT_RATIO_MIN = 10.0           # 40x skew must survive restart ≥ 10x


def _bench_dir():
    for d in ("/dev/shm", None):
        if d is None or (os.path.isdir(d) and os.access(d, os.W_OK)):
            return tempfile.TemporaryDirectory(dir=d, prefix="fig_obs2_")


def _write_events(td: str, size: int) -> str:
    rng = np.random.default_rng(23)
    path = os.path.join(td, "events.bskt")
    write_arrays(path,
                 {"energy": np.cumsum(rng.integers(1, 9, size // 8))
                  .astype(np.int64),
                  "pid": rng.integers(0, 100, size // 32).astype(np.int32)},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1, "delta8"),
                 target_basket_bytes=64 * 1024)
    return path


def _read_all(rf: RemoteBasketFile, name: str) -> None:
    nb = len(rf.branches[name]["baskets"])
    rf.fetch_wire(name, list(range(nb)))


def _propagation_rows(quick: bool) -> list[dict]:
    reps = 3 if quick else 5
    size = (4 if quick else 16) * MB
    t_on = t_off = float("inf")
    with _bench_dir() as td:
        _write_events(td, size)
        with BasketServer(td, workers=4, heat=False) as srv:
            srv.start()
            url = srv.url("events.bskt")
            with RemoteBasketFile(url, wire=None, batch_baskets=64,
                                  propagate=False) as rf_off, \
                    RemoteBasketFile(url, wire=None, batch_baskets=64,
                                     propagate=True) as rf_on:
                for rf in (rf_off, rf_on):      # warm conns + page cache
                    _read_all(rf, "energy")
                for _ in range(reps):
                    # interleaved same-phase A/B: drift hits both arms
                    t0 = time.perf_counter()
                    _read_all(rf_off, "energy")
                    _read_all(rf_off, "pid")
                    t_off = min(t_off, time.perf_counter() - t0)
                    t0 = time.perf_counter()
                    _read_all(rf_on, "energy")
                    _read_all(rf_on, "pid")
                    t_on = min(t_on, time.perf_counter() - t0)
                    obs.trace.clear()   # bounded either way; keep arms equal
    pct = (t_on - t_off) / t_off * 100.0
    rows = []
    for case, t in [("propagate-off", t_off), ("propagate-on", t_on)]:
        rows.append({"bench": "fig_obs2", "stage": "propagation",
                     "case": case, "wall_s": round(t, 4),
                     "overhead_pct": round(pct, 2)
                     if case == "propagate-on" else "",
                     "value": "", "unit": ""})
    return rows


def _heat_rows(quick: bool) -> list[dict]:
    from repro.obs import heat as H
    size = (2 if quick else 8) * MB
    rows = []
    with _bench_dir() as td:
        path = _write_events(td, size)
        # phase 1: 40x-skewed reads, instant flush, clean shutdown
        with BasketServer(td, workers=2, heat_flush_s=0.0) as srv:
            srv.start()
            with RemoteBasketFile(srv.url("events.bskt"), wire=None,
                                  batch_baskets=64) as rf:
                for _ in range(40):
                    _read_all(rf, "energy")
                _read_all(rf, "pid")
        # phase 2: restart — the sidecar must reload and keep accumulating
        with BasketServer(td, workers=2, heat_flush_s=0.0) as srv:
            srv.start()
            with RemoteBasketFile(srv.url("events.bskt"), wire=None,
                                  batch_baskets=64) as rf:
                _read_all(rf, "pid")
            live = fetch_stats(srv.host, srv.port, heat=True)
        doc = H.load_sidecar(path + H.SIDECAR_SUFFIX)
        ranked = H.rank_branches(doc) if doc else []
    for branch, heat_now, reads, nbytes in ranked:
        rows.append({"bench": "fig_obs2", "stage": "heat",
                     "case": f"heat/{branch}", "wall_s": "",
                     "overhead_pct": "", "value": round(heat_now, 2),
                     "unit": ""})
        rows.append({"bench": "fig_obs2", "stage": "heat",
                     "case": f"reads/{branch}", "wall_s": "",
                     "overhead_pct": "", "value": reads, "unit": "reads"})
    n_live = len(((live.get("heat") or {}).get(os.path.abspath(path))
                  or {}).get("branches") or {})
    rows.append({"bench": "fig_obs2", "stage": "heat",
                 "case": "stats.live_branches", "wall_s": "",
                 "overhead_pct": "", "value": n_live, "unit": "count"})
    return rows


def _stitch_rows(quick: bool) -> list[dict]:
    size = (2 if quick else 8) * MB
    with _bench_dir() as td:
        _write_events(td, size)
        with BasketServer(td, workers=2, heat=False) as srv:
            srv.start()
            with RemoteBasketFile(srv.url("events.bskt"), wire=None,
                                  batch_baskets=64) as rf:
                obs.trace.clear()
                _read_all(rf, "energy")
                client_events = obs.trace.drain()
        # loopback shares one ring: the serve/pread spans can append a
        # beat after the client saw the response, so take a second
        # capture once the server has fully drained and stitch both.
        server_events = obs.trace.drain()
    merged = obs.trace.stitch(client_events, server_events)
    roots = obs.trace.build_tree([e for e in merged if e.get("ph") == "X"])

    def _has_chain(node, chain):
        if not chain:
            return True
        head, rest = chain[0], chain[1:]
        if node["name"] == head:
            if not rest:
                return True
            return any(_has_chain(c, rest) for c in node["children"])
        return any(_has_chain(c, chain) for c in node["children"])

    chain_ok = any(_has_chain(r, ["rbsp.fetch_wire", "rbsp.serve",
                                  "server.pread"]) for r in roots)
    return [{"bench": "fig_obs2", "stage": "stitch",
             "case": "events.merged", "wall_s": "", "overhead_pct": "",
             "value": len(merged), "unit": "count"},
            {"bench": "fig_obs2", "stage": "stitch",
             "case": "chain.fetch>serve>pread", "wall_s": "",
             "overhead_pct": "", "value": "ok" if chain_ok else "MISSING",
             "unit": ""}]


def run(out_csv: str | None = None, quick: bool = False) -> list[dict]:
    rows = (_propagation_rows(quick) + _heat_rows(quick)
            + _stitch_rows(quick))
    emit(rows, out_csv)
    return rows


def check(rows: list[dict]) -> int:
    """CI perf-smoke gate (see module docstring)."""
    ok = True

    def fail(msg):
        nonlocal ok
        print(f"FAIL: {msg}", file=sys.stderr)
        ok = False

    over = {r["case"]: r for r in rows if r["stage"] == "propagation"}
    if "propagate-on" not in over or "propagate-off" not in over:
        fail("missing propagation rows")
    else:
        t_on = over["propagate-on"]["wall_s"]
        t_off = over["propagate-off"]["wall_s"]
        if t_on > t_off * (1.0 + OVERHEAD_BUDGET) + ABS_EPS_S:
            fail(f"propagation overhead "
                 f"{over['propagate-on']['overhead_pct']}% exceeds the "
                 f"{OVERHEAD_BUDGET:.0%} budget (on={t_on}s off={t_off}s)")
    heat = {r["case"]: r for r in rows if r["stage"] == "heat"}
    h_hot = heat.get("heat/energy")
    h_cold = heat.get("heat/pid")
    if h_hot is None or h_cold is None:
        fail("heat sidecar missing a branch after restart")
    elif float(h_hot["value"]) < float(h_cold["value"]) * HEAT_RATIO_MIN:
        fail(f"reloaded heat ratio too flat: energy={h_hot['value']} "
             f"pid={h_cold['value']} (want ≥ {HEAT_RATIO_MIN}x)")
    if not any(r["case"] == "stats.live_branches" and int(r["value"]) >= 2
               for r in rows):
        fail("STATS heat=true did not export reloaded branches")
    chain = next((r for r in rows
                  if r["case"] == "chain.fetch>serve>pread"), None)
    if chain is None or chain["value"] != "ok":
        fail("stitched trace lacks the client->server causal chain")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller containers, fewer repeats")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless propagation overhead is "
                         "within budget, reloaded heat ranks the skewed "
                         "branch ≥10x, and the stitched trace chains "
                         "client->server (CI perf-smoke)")
    ap.add_argument("--out", default="artifacts/bench/fig_obs2.csv")
    args = ap.parse_args(argv)
    rows = run(args.out, quick=args.quick)
    return check(rows) if args.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
