"""Figure 6: NanoAOD compression ratio — LZ4 vs LZ4+Shuffle vs
LZ4+BitShuffle vs ZLIB, per branch class and overall.

The paper's claim: BitShuffle preconditioning lets LZ4 beat ZLIB on ratio
while keeping LZ4's decompression speed.  Both halves are measured.
"""

from __future__ import annotations

import numpy as np

from repro.core import CompressionConfig
from repro.core.basket import pack_basket, unpack_basket

from .common import emit, paper_tree_bytes, time_fn


def _precond_for(name: str, precond_kind: str, arr_bytes: bytes,
                 itemsize: int) -> str:
    if precond_kind == "none":
        return "none"
    if precond_kind == "shuffle":
        return f"shuffle{itemsize}"
    return f"bitshuffle{max(itemsize, 2)}"


def run(out_csv: str | None = None) -> list[dict]:
    from .common import EVENTS, paper_tree_bytes
    tree = paper_tree_bytes()
    from benchmarks import common
    events = common.EVENTS
    variants = [
        ("lz4", CompressionConfig("lz4", 1)),
        ("lz4+shuffle", None),
        ("lz4+bitshuffle", None),
        ("zlib", CompressionConfig("zlib", 6)),
        ("zstd+bitshuffle", None),
    ]
    rows = []
    totals = {v[0]: [0, 0, 0.0] for v in variants}   # raw, comp, dec_s
    for name, blob in tree.items():
        itemsize = events[name].dtype.itemsize
        for vname, cfg in variants:
            if cfg is None:
                algo = "zstd" if vname.startswith("zstd") else "lz4"
                kind = "shuffle" if "+" in vname and "bit" not in vname else "bitshuffle"
                cfg_v = CompressionConfig(algo, 1 if algo == "lz4" else 3,
                                          _precond_for(name, kind, blob, itemsize))
            else:
                cfg_v = cfg
            payload, meta = pack_basket(blob, cfg_v)
            dt = time_fn(lambda: unpack_basket(payload, meta),
                         repeat=2, min_time=0.005)
            totals[vname][0] += len(blob)
            totals[vname][1] += len(payload)
            totals[vname][2] += dt
    for vname, (raw, comp, dec_s) in totals.items():
        rows.append({"bench": "fig6", "variant": vname,
                     "ratio": round(raw / comp, 3),
                     "decomp_MBps": round(raw / dec_s / 1e6, 1)})
    emit(rows, out_csv)
    return rows


if __name__ == "__main__":
    run("artifacts/bench/fig6.csv")
