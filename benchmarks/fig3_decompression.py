"""Figure 3: decompression speed by algorithm and input-file level.

The paper's observation: decompression speed is primarily a function of
the ALGORITHM, not the level the file was written at (levels 0/1/6/9).
"""

from __future__ import annotations

from repro.core import CODECS, CompressionConfig, compress, decompress
from repro.configs.paper_io import PAPER_IO

from .common import emit, paper_tree_bytes, time_fn


def run(out_csv: str | None = None) -> list[dict]:
    tree = paper_tree_bytes()
    total = sum(len(b) for b in tree.values())
    rows = []
    for algo in PAPER_IO.codecs:
        if algo not in CODECS:
            continue
        for level in (0,) + PAPER_IO.levels:
            cfg = CompressionConfig(algo=algo, level=level)
            comp = {n: compress(b, cfg) for n, b in tree.items()}
            dt = time_fn(lambda: [decompress(c, len(tree[n]), cfg)
                                  for n, c in comp.items()],
                         repeat=3, min_time=0.02)
            rows.append({
                "bench": "fig3", "algo": algo, "level": level,
                "decomp_MBps": round(total / dt / 1e6, 2),
            })
    emit(rows, out_csv)
    return rows


if __name__ == "__main__":
    run("artifacts/bench/fig3.csv")
