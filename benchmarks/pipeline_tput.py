"""Data-pipeline read throughput: the paper's "simultaneous read and
decompression of multiple events" — tokens/s with 0 vs N decompression
workers, and checkpoint write/read bandwidth through the codec policy."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.bfile import BasketFile
from repro.data import TokenPipeline, write_token_shards

from .common import emit


def run(out_csv: str | None = None) -> list[dict]:
    rows = []
    with tempfile.TemporaryDirectory() as td:
        shards = [os.path.join(td, f"s{i}.bskt") for i in range(2)]
        write_token_shards(shards, vocab=50_000, tokens_per_shard=600_000,
                           seed=1, profile="analysis")
        for workers in (0, 2, 4):
            f = BasketFile(shards[0])
            t0 = time.perf_counter()
            arr = f.read_branch("tokens", workers=workers)
            dt = time.perf_counter() - t0
            rows.append({"bench": "pipeline", "what": f"branch_read_w{workers}",
                         "MBps": round(arr.nbytes / dt / 1e6, 1)})
        pipe = TokenPipeline(shards, batch=8, seq_len=512, prefetch=4,
                             decomp_workers=4)
        n_tok = 0
        t0 = time.perf_counter()
        for _ in range(40):
            b = next(pipe)
            n_tok += b["tokens"].size
        dt = time.perf_counter() - t0
        pipe.close()
        rows.append({"bench": "pipeline", "what": "token_stream",
                     "MBps": round(n_tok * 4 / dt / 1e6, 1)})
    emit(rows, out_csv)
    return rows


if __name__ == "__main__":
    run("artifacts/bench/pipeline.csv")
