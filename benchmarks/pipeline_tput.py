"""Data-pipeline read throughput: the paper's "simultaneous read and
decompression of multiple events" — tokens/s with 0 vs N decompression
workers, pipelined parallel basket *writes* through the repro.io engine
(workers=1 vs workers=8 must favor 8 on any multi-core host), and the
decompress-ahead reader on the token hot path."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import CompressionConfig
from repro.core.bfile import BasketFile
from repro.core.codec import is_pure_python
from repro.data import TokenPipeline, write_token_shards

from .common import emit

#: per-codec write-bench payload: pure-Python codecs run ~MB/s, C codecs
#: ~100MB/s — size so each timing is ~seconds, not minutes.
_WRITE_LEVEL = {"zstd": 3, "lz4": 1, "zlib": 6}


def _write_payload_bytes(algo: str) -> int:
    return (3 << 20) if is_pure_python(algo) else (16 << 20)


def write_scaling_rows(td: str, algos=("zstd", "lz4"),
                       workers_list=(1, 8)) -> list[dict]:
    """Pipelined basket compression: same bytes out, N cores in.  The
    engine is pre-warmed so the rows compare steady-state throughput, not
    one-off pool startup."""
    from repro.core.bfile import BasketWriter
    from repro.io import CompressionEngine

    rows = []
    rng = np.random.default_rng(7)
    for algo in algos:
        n = _write_payload_bytes(algo) // 4
        arr = (rng.standard_normal(n) * 0.01).astype(np.float32)
        cfg = CompressionConfig(algo, _WRITE_LEVEL.get(algo, 3), "shuffle4")
        for workers in workers_list:
            path = os.path.join(td, f"w_{algo}_{workers}.bskt")
            with CompressionEngine(workers) as eng:
                eng.warmup(algo)
                t0 = time.perf_counter()
                with BasketWriter(path, engine=eng) as w:
                    w.write_branch("x", arr, cfg, 256 * 1024)
                dt = time.perf_counter() - t0
            rows.append({"bench": "pipeline",
                         "what": f"write_{algo}_w{workers}",
                         "MBps": round(arr.nbytes / dt / 1e6, 1)})
    return rows


def run(out_csv: str | None = None) -> list[dict]:
    rows = []
    with tempfile.TemporaryDirectory() as td:
        shards = [os.path.join(td, f"s{i}.bskt") for i in range(2)]
        write_token_shards(shards, vocab=50_000, tokens_per_shard=600_000,
                           seed=1, profile="analysis")
        for workers in (0, 2, 4):
            f = BasketFile(shards[0])
            t0 = time.perf_counter()
            arr = f.read_branch("tokens", workers=workers)
            dt = time.perf_counter() - t0
            rows.append({"bench": "pipeline", "what": f"branch_read_w{workers}",
                         "MBps": round(arr.nbytes / dt / 1e6, 1)})
        # decompress-ahead reader (repro.io.prefetch) on the same branch
        with BasketFile(shards[0], workers=4, prefetch=4) as f:
            t0 = time.perf_counter()
            arr = f.read_branch("tokens")
            dt = time.perf_counter() - t0
            rows.append({"bench": "pipeline", "what": "branch_read_prefetch",
                         "MBps": round(arr.nbytes / dt / 1e6, 1)})
        pipe = TokenPipeline(shards, batch=8, seq_len=512, prefetch=4,
                             decomp_workers=4)
        n_tok = 0
        t0 = time.perf_counter()
        for _ in range(40):
            b = next(pipe)
            n_tok += b["tokens"].size
        dt = time.perf_counter() - t0
        pipe.close()
        rows.append({"bench": "pipeline", "what": "token_stream",
                     "MBps": round(n_tok * 4 / dt / 1e6, 1)})
        rows += write_scaling_rows(td)
    emit(rows, out_csv)
    return rows


if __name__ == "__main__":
    run("artifacts/bench/pipeline.csv")
