"""Figure 2: compression ratio vs compression speed, per (algo, level).

The paper's test: the artificial 2000-event tree, every ROOT codec at
levels 1/6/9 (level 0 = off shown as the 1.0x reference).  x = overall
ratio, y = compression MB/s.
"""

from __future__ import annotations

from repro.core import CODECS, CompressionConfig, compress
from repro.configs.paper_io import PAPER_IO

from .common import emit, paper_tree_bytes, time_fn


def run(out_csv: str | None = None) -> list[dict]:
    tree = paper_tree_bytes()
    blob = b"".join(tree.values())
    total = len(blob)
    rows = []
    for algo in PAPER_IO.codecs:
        if algo not in CODECS:
            continue
        for level in PAPER_IO.levels:
            cfg = CompressionConfig(algo=algo, level=level)
            # per-branch compression, like ROOT baskets
            comp = sum(len(compress(b, cfg)) for b in tree.values())
            slow = algo in ("repro-deflate", "repro-deflate-ref", "repro-zstd", "lzma")
            reps = 1 if slow else 3
            if slow and level > 6:
                level_cfg = cfg  # still measured, just once
            dt = time_fn(lambda: [compress(b, cfg) for b in tree.values()],
                         repeat=reps, min_time=0.01)
            rows.append({
                "bench": "fig2", "algo": algo, "level": level,
                "ratio": round(total / comp, 3),
                "comp_MBps": round(total / dt / 1e6, 2),
            })
    emit(rows, out_csv)
    return rows


if __name__ == "__main__":
    run("artifacts/bench/fig2.csv")
