#!/usr/bin/env python
"""Stand a chaos proxy in front of a running basket server::

    tools/chaos.py HOST:PORT --port 9148 \\
        --rule garble:p=0.02,dir=s2c --rule delay:verb=readv,ms=100,p=0.5

Clients point at the proxy's address instead of the server's; every RBSP
frame in both directions passes through the seeded FaultPlan.  Rule
syntax is ``kind[:k=v,...]`` with kinds drop/delay/reset/garble/short and
keys p, dir (c2s/s2c), verb, every, after (bytes), ms (delay), max —
see ``repro.fault.inject.parse_rule``.  On SIGINT the proxy prints the
per-kind firing counts, so a soak run ends with proof of what it injected.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.fault import ChaosProxy, FaultPlan, parse_rule  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools/chaos.py",
        description="RBSP-aware chaos TCP proxy (repro.fault).")
    ap.add_argument("upstream", help="basket server address, HOST:PORT")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral; printed on stdout)")
    ap.add_argument("--rule", action="append", default=[],
                    help="fault rule spec (repeatable): kind[:k=v,...]")
    ap.add_argument("--seed", type=int, default=0,
                    help="fault plan seed (same seed + traffic = same faults)")
    args = ap.parse_args(argv)

    host, _, port = args.upstream.rpartition(":")
    if not host or not port:
        ap.error(f"upstream {args.upstream!r} is not HOST:PORT")
    plan = FaultPlan([parse_rule(s) for s in args.rule], seed=args.seed)
    proxy = ChaosProxy(host, int(port), plan,
                       host=args.host, port=args.port)
    print(f"chaos proxy on {proxy.host}:{proxy.port} -> {host}:{port} "
          f"({len(plan.rules)} rules, seed={plan.seed})", flush=True)
    try:
        while True:
            time.sleep(1)
    except KeyboardInterrupt:
        pass
    finally:
        proxy.close()
        print(f"injected: {plan.counts() or 'nothing'}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
