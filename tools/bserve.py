#!/usr/bin/env python
"""Serve a directory of BasketFiles — thin wrapper over
``python -m repro.remote`` that works from a source checkout without
PYTHONPATH gymnastics::

    tools/bserve.py /data/shards --port 9147 [--workers N] [--no-transcode]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.remote.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
