#!/usr/bin/env python
"""One-shot scrub/heal of basket containers — local or remote::

    tools/bscrub.py data/run3/*.bskt                 # verify + heal in place
    tools/bscrub.py --no-heal big.bskt               # verify only
    tools/bscrub.py --mbps 50 /data                  # pace a whole tree
    tools/bscrub.py repro://host:9147                # server-side full scrub
    tools/bscrub.py repro://host:9147/run3/ev.bskt   # ... one container
    tools/bscrub.py --reconcile host:9148 ev.bskt    # pull unhealable
                                                     # baskets from a replica

Each local PATH may be one container or a directory (every ``*.bskt``
under it).  A ``repro://`` target runs the scrub on the server via the
RBSP ``SCRUB`` verb.  With ``--reconcile HOST:PORT`` (repeatable), local
damage that parity cannot heal is pulled from replica servers through
the anti-entropy path (:func:`repro.repair.repair_replica`).

Exit status: 0 = everything verified clean (healing counts as clean);
1 = damage remains that nothing could repair; 2 = usage/connection error.
The summary names every surviving ``(branch, index)`` — the operator's
list of what the fleet has actually lost.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.remote import parse_url, request_scrub  # noqa: E402
from repro.repair import repair_replica, scrub_container  # noqa: E402


def _local_containers(path: str) -> list[str]:
    if os.path.isdir(path):
        out = []
        for dirpath, _dirs, files in os.walk(path):
            out += [os.path.join(dirpath, f) for f in sorted(files)
                    if f.endswith(".bskt")]
        return sorted(out)
    return [path]


def _endpoint(spec: str) -> tuple[str, int]:
    host, _, port = spec.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"{spec!r} is not HOST:PORT")
    return host, int(port)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools/bscrub.py",
        description="one-shot scrub/heal of basket containers "
                    "(repro.repair; DESIGN.md §15)")
    ap.add_argument("targets", nargs="+",
                    help="container path, directory, or repro://host:port"
                         "[/path] URL")
    ap.add_argument("--no-heal", action="store_true",
                    help="verify only; report damage without repairing")
    ap.add_argument("--mbps", type=float, default=None, metavar="MB/S",
                    help="byte-rate budget (compressed bytes read)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore persisted scrub cursors, start from 0")
    ap.add_argument("--reconcile", action="append", default=[],
                    metavar="HOST:PORT",
                    help="replica endpoint to pull unhealable baskets "
                         "from (repeatable; local targets only)")
    ap.add_argument("--timeout", type=float, default=30.0)
    ap.add_argument("--json", action="store_true",
                    help="machine-readable per-container reports")
    args = ap.parse_args(argv)

    try:
        endpoints = [_endpoint(s) for s in args.reconcile]
    except ValueError as e:
        ap.error(str(e))

    reports: list[dict] = []
    rc = 0
    for target in args.targets:
        if target.startswith("repro://"):
            try:
                rest = target[len("repro://"):]
                if "/" in rest:
                    host, port, path = parse_url(target)
                else:                       # bare endpoint: whole export root
                    host, port = _endpoint(rest)
                    path = ""
            except ValueError as e:
                print(f"bscrub: {e}", file=sys.stderr)
                return 2
            try:
                resp = request_scrub(host, port, action="scrub",
                                     path=path or None,
                                     timeout=args.timeout)
            except Exception as e:
                print(f"bscrub: {target}: {e}", file=sys.stderr)
                return 2
            reports += resp.get("reports", [])
            continue
        for cpath in _local_containers(target):
            rep = scrub_container(cpath, heal=not args.no_heal,
                                  mbps=args.mbps,
                                  resume=not args.no_resume)
            if endpoints and (rep.get("unhealable") or "error" in rep):
                try:
                    rec = repair_replica(
                        cpath, os.path.basename(cpath), endpoints,
                        timeout=args.timeout, scrub_mbps=args.mbps)
                    rep = dict(rec["post_scrub"], reconcile={
                        k: rec[k] for k in ("pulled", "patched",
                                            "rewritten", "converged")})
                except Exception as e:
                    rep["reconcile_error"] = str(e)
            reports.append(rep)

    remaining = []
    for rep in reports:
        remaining += [(rep.get("path", "?"), br, i)
                      for br, i in rep.get("unhealable", [])]
        if "error" in rep or "reconcile_error" in rep:
            rc = 1
    if args.json:
        print(json.dumps(reports, indent=1, sort_keys=True))
    else:
        for rep in reports:
            if "error" in rep:
                print(f"{rep.get('path', '?')}: TORN — {rep['error']}")
                continue
            state = "clean" if not rep.get("unhealable") else "DAMAGED"
            print(f"{rep.get('path', '?')}: {state} — "
                  f"{rep.get('baskets', 0)} baskets, "
                  f"{rep.get('corrupt', 0)} corrupt, "
                  f"{rep.get('healed', 0)} healed"
                  + (f", resumed" if rep.get("resumed") else ""))
    if remaining:
        rc = 1
        print(f"bscrub: {len(remaining)} unhealable basket(s):",
              file=sys.stderr)
        for path, br, i in remaining:
            print(f"  {path}: branch={br!r} index={i}", file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
