#!/usr/bin/env python
"""Access-heat query tool: rank branches/baskets by measured read heat.

Reads either the durable ``<container>.heat`` sidecars a
:class:`repro.remote.BasketServer` folds its telemetry into, or a live
server's STATS view — the evidence the ROADMAP's background repacker
consumes (DESIGN.md §16)::

    tools/heatmap.py DIR                    # scan sidecars under DIR
    tools/heatmap.py events.bskt.heat       # one sidecar
    tools/heatmap.py HOST:PORT              # live server (STATS heat=true)
    tools/heatmap.py DIR --top 5 --baskets  # per-basket detail
    tools/heatmap.py DIR --json             # machine-readable
    tools/heatmap.py replicaA/ replicaB/    # multi-replica merged view
    tools/heatmap.py 'shard*/  *.heat'      # globs expand too

Ranking is by decayed EWMA heat (recency-weighted), with cumulative
reads as tiebreak — "hot now" first, "popular ever" second.

With several targets (directories, sidecar files, globs, live servers —
mixable), same-named containers across replicas fold into ONE row: each
replica's heat is decayed to now first, then heat/reads/bytes/basket
counts sum — the fleet-wide hottest-first view a multi-replica repacker
wants.  A single target ranks exactly as before.
"""
import argparse
import glob as _glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs import heat as H  # noqa: E402


def _collect_sidecars(target: str) -> dict[str, dict]:
    """``{container_path: sidecar_doc}`` from a file or directory walk."""
    docs = {}
    if os.path.isfile(target):
        doc = H.load_sidecar(target)
        if doc is not None:
            docs[target[:-len(H.SIDECAR_SUFFIX)]
                 if target.endswith(H.SIDECAR_SUFFIX) else target] = doc
        return docs
    for dirpath, _dirs, files in os.walk(target):
        for fn in files:
            if not fn.endswith(H.SIDECAR_SUFFIX):
                continue
            p = os.path.join(dirpath, fn)
            doc = H.load_sidecar(p)
            if doc is not None:
                docs[p[:-len(H.SIDECAR_SUFFIX)]] = doc
    return docs


def _collect_live(target: str) -> dict[str, dict]:
    """Live STATS heat snapshot reshaped into sidecar-like docs."""
    from repro.remote.client import fetch_stats
    host, _, port = target.rpartition(":")
    body = fetch_stats(host, int(port), heat=True)
    docs = {}
    for path, rec in (body.get("heat") or {}).items():
        branches = {}
        for branch, b in (rec.get("branches") or {}).items():
            branches[branch] = {"reads": b.get("reads", 0),
                                "bytes": b.get("bytes", 0),
                                "heat": b.get("heat", 0.0),
                                "t": None,  # already decayed server-side
                                "baskets": b.get("baskets_hot") or {}}
        docs[path] = {"version": 1,
                      "halflife_s": rec.get("halflife_s", 3600.0),
                      "branches": branches}
    return docs


def _collect_target(target: str) -> dict[str, dict]:
    """Sidecar docs from one target: live HOST:PORT, file, or directory."""
    host, _, port = target.rpartition(":")
    if host and port.isdigit() and not os.path.exists(target):
        return _collect_live(target)
    return _collect_sidecars(target)


def merge_docs(per_target: list[dict[str, dict]]) -> dict[str, dict]:
    """Fold several targets' docs into one map; same-named containers
    (by basename — replicas hold copies under different roots) merge into
    a single doc whose branch heat is decayed to now *before* summing, so
    a replica flushed an hour ago doesn't outweigh one flushed a second
    ago.  Merged docs carry ``t: None`` (already decayed) and a
    ``replicas`` count; a single target passes through untouched."""
    if len(per_target) <= 1:
        return per_target[0] if per_target else {}
    import time as _time
    now = _time.time()
    out: dict[str, dict] = {}
    seen_from: dict[str, set] = {}
    for ti, docs in enumerate(per_target):
        for path, doc in docs.items():
            key = os.path.basename(path)
            hl = float(doc.get("halflife_s") or 3600.0)
            m = out.get(key)
            if m is None:
                m = out[key] = {"version": 1, "halflife_s": hl,
                                "branches": {}, "replicas": 0}
                seen_from[key] = set()
            seen_from[key].add(ti)
            m["replicas"] = len(seen_from[key])
            for br, rec in (doc.get("branches") or {}).items():
                t = rec.get("t")
                heat = float(rec.get("heat", 0.0))
                if t is not None:       # sidecar heat: decay to now first
                    heat = H._decay(heat, now - float(t), hl)
                dst = m["branches"].setdefault(
                    br, {"reads": 0, "bytes": 0, "heat": 0.0, "t": None,
                         "baskets": {}})
                dst["reads"] += int(rec.get("reads", 0))
                dst["bytes"] += int(rec.get("bytes", 0))
                dst["heat"] += heat
                for bk, n in (rec.get("baskets") or {}).items():
                    dst["baskets"][bk] = dst["baskets"].get(bk, 0) + int(n)
    return out


def rank_all(docs: dict[str, dict]) -> list[dict]:
    """Flatten to ``[{container, branch, heat, reads, bytes}, ...]``,
    hottest first across every container."""
    rows = []
    for path, doc in docs.items():
        live = any(rec.get("t") is None
                   for rec in (doc.get("branches") or {}).values())
        if live:    # STATS heat is already decayed to "now"
            ranked = [(br, float(rec.get("heat", 0.0)),
                       int(rec.get("reads", 0)), int(rec.get("bytes", 0)))
                      for br, rec in doc["branches"].items()]
            ranked.sort(key=lambda r: (-r[1], -r[2], r[0]))
        else:
            ranked = H.rank_branches(doc)
        for branch, heat_now, reads, nbytes in ranked:
            rows.append({"container": path, "branch": branch,
                         "heat": heat_now, "reads": reads, "bytes": nbytes})
    rows.sort(key=lambda r: (-r["heat"], -r["reads"], r["branch"]))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools/heatmap.py",
        description="rank branches by persistent access heat")
    ap.add_argument("targets", nargs="+", metavar="TARGET",
                    help="directories of .heat sidecars, sidecar files, "
                         "globs thereof, or HOST:PORT of live servers; "
                         "several targets merge into one replica-summed "
                         "ranking")
    ap.add_argument("--top", type=int, default=20, metavar="N",
                    help="rows shown (default 20)")
    ap.add_argument("--baskets", action="store_true",
                    help="also show each branch's hottest baskets")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output (the repacker input)")
    args = ap.parse_args(argv)

    # expand globs (quoted on the command line, or host shells that don't
    # expand); a pattern matching nothing falls through as a literal so
    # the "no heat telemetry found" path still reports it
    targets: list[str] = []
    for t in args.targets:
        hits = sorted(_glob.glob(t)) if any(c in t for c in "*?[") else []
        targets.extend(hits or [t])
    per_target = [_collect_target(t) for t in targets]
    docs = merge_docs(per_target)
    rows = rank_all(docs)

    if args.json:
        json.dump({"rows": rows[:args.top]}, sys.stdout, sort_keys=True)
        print()
        return 0
    if not rows:
        print("no heat telemetry found")
        return 1
    print(f"{'heat':>10}  {'reads':>8}  {'MB':>8}  branch  (container)")
    for r in rows[:args.top]:
        print(f"{r['heat']:>10.2f}  {r['reads']:>8}  "
              f"{r['bytes'] / 1e6:>8.2f}  {r['branch']}  "
              f"({os.path.basename(r['container'])})")
        if args.baskets:
            doc = docs.get(r["container"]) or {}
            rec = (doc.get("branches") or {}).get(r["branch"]) or {}
            hot = sorted((rec.get("baskets") or {}).items(),
                         key=lambda kv: (-int(kv[1]), int(kv[0])))[:8]
            if hot:
                print("            baskets: "
                      + " ".join(f"{k}:{v}" for k, v in hot))
    return 0


if __name__ == "__main__":
    sys.exit(main())
