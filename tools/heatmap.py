#!/usr/bin/env python
"""Access-heat query tool: rank branches/baskets by measured read heat.

Reads either the durable ``<container>.heat`` sidecars a
:class:`repro.remote.BasketServer` folds its telemetry into, or a live
server's STATS view — the evidence the ROADMAP's background repacker
consumes (DESIGN.md §16)::

    tools/heatmap.py DIR                    # scan sidecars under DIR
    tools/heatmap.py events.bskt.heat       # one sidecar
    tools/heatmap.py HOST:PORT              # live server (STATS heat=true)
    tools/heatmap.py DIR --top 5 --baskets  # per-basket detail
    tools/heatmap.py DIR --json             # machine-readable

Ranking is by decayed EWMA heat (recency-weighted), with cumulative
reads as tiebreak — "hot now" first, "popular ever" second.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs import heat as H  # noqa: E402


def _collect_sidecars(target: str) -> dict[str, dict]:
    """``{container_path: sidecar_doc}`` from a file or directory walk."""
    docs = {}
    if os.path.isfile(target):
        doc = H.load_sidecar(target)
        if doc is not None:
            docs[target[:-len(H.SIDECAR_SUFFIX)]
                 if target.endswith(H.SIDECAR_SUFFIX) else target] = doc
        return docs
    for dirpath, _dirs, files in os.walk(target):
        for fn in files:
            if not fn.endswith(H.SIDECAR_SUFFIX):
                continue
            p = os.path.join(dirpath, fn)
            doc = H.load_sidecar(p)
            if doc is not None:
                docs[p[:-len(H.SIDECAR_SUFFIX)]] = doc
    return docs


def _collect_live(target: str) -> dict[str, dict]:
    """Live STATS heat snapshot reshaped into sidecar-like docs."""
    from repro.remote.client import fetch_stats
    host, _, port = target.rpartition(":")
    body = fetch_stats(host, int(port), heat=True)
    docs = {}
    for path, rec in (body.get("heat") or {}).items():
        branches = {}
        for branch, b in (rec.get("branches") or {}).items():
            branches[branch] = {"reads": b.get("reads", 0),
                                "bytes": b.get("bytes", 0),
                                "heat": b.get("heat", 0.0),
                                "t": None,  # already decayed server-side
                                "baskets": b.get("baskets_hot") or {}}
        docs[path] = {"version": 1,
                      "halflife_s": rec.get("halflife_s", 3600.0),
                      "branches": branches}
    return docs


def rank_all(docs: dict[str, dict]) -> list[dict]:
    """Flatten to ``[{container, branch, heat, reads, bytes}, ...]``,
    hottest first across every container."""
    rows = []
    for path, doc in docs.items():
        live = any(rec.get("t") is None
                   for rec in (doc.get("branches") or {}).values())
        if live:    # STATS heat is already decayed to "now"
            ranked = [(br, float(rec.get("heat", 0.0)),
                       int(rec.get("reads", 0)), int(rec.get("bytes", 0)))
                      for br, rec in doc["branches"].items()]
            ranked.sort(key=lambda r: (-r[1], -r[2], r[0]))
        else:
            ranked = H.rank_branches(doc)
        for branch, heat_now, reads, nbytes in ranked:
            rows.append({"container": path, "branch": branch,
                         "heat": heat_now, "reads": reads, "bytes": nbytes})
    rows.sort(key=lambda r: (-r["heat"], -r["reads"], r["branch"]))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools/heatmap.py",
        description="rank branches by persistent access heat")
    ap.add_argument("target",
                    help="directory of .heat sidecars, one sidecar file, "
                         "or HOST:PORT of a live server")
    ap.add_argument("--top", type=int, default=20, metavar="N",
                    help="rows shown (default 20)")
    ap.add_argument("--baskets", action="store_true",
                    help="also show each branch's hottest baskets")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output (the repacker input)")
    args = ap.parse_args(argv)

    host, _, port = args.target.rpartition(":")
    if host and port.isdigit() and not os.path.exists(args.target):
        docs = _collect_live(args.target)
    else:
        docs = _collect_sidecars(args.target)
    rows = rank_all(docs)

    if args.json:
        json.dump({"rows": rows[:args.top]}, sys.stdout, sort_keys=True)
        print()
        return 0
    if not rows:
        print("no heat telemetry found")
        return 1
    print(f"{'heat':>10}  {'reads':>8}  {'MB':>8}  branch  (container)")
    for r in rows[:args.top]:
        print(f"{r['heat']:>10.2f}  {r['reads']:>8}  "
              f"{r['bytes'] / 1e6:>8.2f}  {r['branch']}  "
              f"({os.path.basename(r['container'])})")
        if args.baskets:
            doc = docs.get(r["container"]) or {}
            rec = (doc.get("branches") or {}).get(r["branch"]) or {}
            hot = sorted((rec.get("baskets") or {}).items(),
                         key=lambda kv: (-int(kv[1]), int(kv[0])))[:8]
            if hot:
                print("            baskets: "
                      + " ".join(f"{k}:{v}" for k, v in hot))
    return 0


if __name__ == "__main__":
    sys.exit(main())
