"""Dev tool: lower one cell and list the biggest HLO tensors (replication hunting)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re, collections
import jax
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, parallelism_for
from repro.parallel.actctx import activation_context

arch, shape = sys.argv[1], sys.argv[2]
cfg = get_config(arch)
mesh = make_production_mesh(multi_pod=len(sys.argv) > 3)
cell = build_cell(cfg, SHAPES[shape], mesh, parallelism_for(cfg))
with mesh, activation_context(mesh):
    c = jax.jit(cell.fn, in_shardings=cell.in_shardings, out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums).lower(*cell.args).compile()
mem = c.memory_analysis()
print(f"peak={(mem.argument_size_in_bytes+mem.output_size_in_bytes+mem.temp_size_in_bytes-mem.alias_size_in_bytes)/2**30:.1f}GiB "
      f"temp={mem.temp_size_in_bytes/2**30:.1f} arg={mem.argument_size_in_bytes/2**30:.1f} out={mem.output_size_in_bytes/2**30:.1f} alias={mem.alias_size_in_bytes/2**30:.1f}")
sizes = collections.Counter()
for m in re.finditer(r'(bf16|f32|s32|u32|f16|pred|u8|s8)\[([0-9,]+)\]', c.as_text()):
    dims = [int(d) for d in m.group(2).split(",")]
    n = 1
    for d in dims: n *= d
    b = n * {"bf16":2,"f32":4,"s32":4,"u32":4,"f16":2,"pred":1,"u8":1,"s8":1}[m.group(1)]
    key = f"{m.group(1)}[{m.group(2)}]"
    if b > 2**27:
        sizes[key] = b
for k, v in sizes.most_common(18):
    print(f"  {v/2**30:8.2f}GiB {k}")
