#!/usr/bin/env python
"""Perf-trajectory sentinel: detect regressions across BENCH_pr*.json.

Every PR commits a machine-readable perf artifact
(``artifacts/bench/BENCH_pr<N>.json``, schema 1 — see
``benchmarks/common.py write_json`` / ``benchmarks/run.py --json``).
This tool parses the whole committed series, groups rows into
``(bench, stage, case, unit)`` metric series, and flags the latest
file's value when it is worse than **every** baseline (the last
``--last`` prior files that measured the same series) by more than the
noise band.  "Worse than all baselines" — not "worse than the best" —
is what makes one lucky-fast historical run unable to fail CI forever.

Direction comes from the unit: throughput-like units (MB/s, x,
items/s) must not drop; time/size-like units (s, ms, ns/op, wall_s, B)
must not grow.  Unitless or count-like series (workload constants such
as ``reads``) carry no perf meaning and are skipped.  Noise bands are
per-unit: generous for timing (scheduler jitter), tight for
deterministic byte sizes.

Exit status is the CI contract: 0 = no regression (or nothing
comparable yet), 1 = regression beyond the band, 2 = usage error.

    tools/benchdiff.py                          # whole committed series
    tools/benchdiff.py --dir /tmp/bench --last 2 --band 0.5
    tools/benchdiff.py --json                   # machine-readable report

``--json`` emits every comparable series (``"series"``) with a
``verdict`` (ok / regressed / improved), its noise ``band``, direction,
baselines, and delta vs the worst baseline — so a CI step can annotate
per-series outcomes instead of only reading the exit code.  Exit codes
are identical in both modes.
"""
import argparse
import glob
import json
import os
import re
import sys

# lower-is-better units and their relative noise bands
_LOWER = {"s": 0.40, "ms": 0.40, "us": 0.40, "ns/op": 0.40,
          "wall_s": 0.40, "B": 0.10, "MB": 0.10, "%": 0.40}
# higher-is-better units
_HIGHER = {"MB/s": 0.40, "GB/s": 0.40, "x": 0.25, "items/s": 0.40,
           "ops/s": 0.40}
# measured but direction-free (workload constants, identities): never judged
_SKIP = {"", "reads", "count", "events", "baskets"}

_PR_RE = re.compile(r"BENCH_pr(\d+)\.json$")


def load_series(bench_dir: str):
    """``({series_key: [(pr, value), ...]}, [pr, ...])`` from every
    BENCH_pr*.json under ``bench_dir`` (prs ascending)."""
    files = []
    for path in glob.glob(os.path.join(bench_dir, "BENCH_pr*.json")):
        m = _PR_RE.search(os.path.basename(path))
        if m:
            files.append((int(m.group(1)), path))
    files.sort()
    series: dict[tuple, list] = {}
    for pr, path in files:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            print(f"benchdiff: unreadable {path}: {e}", file=sys.stderr)
            continue
        for bench, rows in (doc.get("benches") or {}).items():
            for row in rows:
                if not isinstance(row, dict):
                    continue
                stage = str(row.get("stage", ""))
                case = str(row.get("case", ""))
                unit = str(row.get("unit", ""))
                # the primary value, and wall_s as its own timing series
                for metric, u in (("value", unit), ("wall_s", "wall_s")):
                    v = row.get(metric)
                    if isinstance(v, bool) or not isinstance(v, (int, float)):
                        continue
                    key = (bench, stage, case, u if metric == "value"
                           else "wall_s")
                    series.setdefault(key, []).append((pr, float(v)))
    return series, [pr for pr, _ in files]


def judge(series: dict, prs: list, last: int, band_override=None):
    """Compare each series' newest value against its baselines.

    Returns ``(regressions, improvements, compared)`` — lists of report
    dicts.  A series is judged only when the newest PR measured it and
    at least one earlier PR did too."""
    if not prs:
        return [], [], []
    newest = prs[-1]
    regressions, improvements, compared = [], [], []
    for key in sorted(series):
        bench, stage, case, unit = key
        if unit in _SKIP:
            continue
        if unit in _LOWER:
            lower_better, band = True, _LOWER[unit]
        elif unit in _HIGHER:
            lower_better, band = False, _HIGHER[unit]
        else:
            continue        # unknown unit: no direction, no verdict
        if band_override is not None:
            band = band_override
        points = series[key]
        cur = [v for pr, v in points if pr == newest]
        base = [(pr, v) for pr, v in points if pr != newest]
        if not cur or not base:
            continue
        value = cur[-1]
        base_prs = sorted({pr for pr, _ in base})[-last:]
        baselines = [v for pr, v in base if pr in base_prs]
        rep = {"series": f"{bench}/{stage}/{case}",
               "unit": unit, "value": value,
               "baselines": baselines, "band": band,
               "vs_prs": base_prs, "pr": newest,
               "direction": "lower" if lower_better else "higher",
               "verdict": "ok"}
        compared.append(rep)
        if lower_better:
            worst = max(baselines)
            best = min(baselines)
            rep["delta"] = value / worst - 1.0
            if value > worst * (1.0 + band):
                rep["verdict"] = "regressed"
                regressions.append(rep)
            elif value < best * (1.0 - band):
                rep["delta"] = value / best - 1.0
                rep["verdict"] = "improved"
                improvements.append(rep)
        else:
            worst = min(baselines)
            best = max(baselines)
            rep["delta"] = value / worst - 1.0
            if value < worst * (1.0 - band):
                rep["verdict"] = "regressed"
                regressions.append(rep)
            elif value > best * (1.0 + band):
                rep["delta"] = value / best - 1.0
                rep["verdict"] = "improved"
                improvements.append(rep)
    return regressions, improvements, compared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools/benchdiff.py",
        description="perf-trajectory regression sentinel over "
                    "artifacts/bench/BENCH_pr*.json")
    default_dir = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "artifacts", "bench")
    ap.add_argument("--dir", default=default_dir, metavar="DIR",
                    help="directory of BENCH_pr*.json files")
    ap.add_argument("--last", type=int, default=2, metavar="N",
                    help="baseline files per series (default 2)")
    ap.add_argument("--band", type=float, default=None, metavar="FRAC",
                    help="override every per-unit noise band "
                         "(e.g. 0.5 = 50%%)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report")
    args = ap.parse_args(argv)
    if not os.path.isdir(args.dir):
        print(f"benchdiff: no such directory: {args.dir}", file=sys.stderr)
        return 2

    series, prs = load_series(args.dir)
    regressions, improvements, compared = judge(series, prs, args.last,
                                                args.band)
    if args.json:
        # the CI annotator's input: every comparable series with its
        # verdict, noise band, direction, and delta vs the worst baseline
        # — not only the failures.  "compared" stays a count (the shape
        # older scripts consumed); the per-series list is "series".
        json.dump({"prs": prs, "compared": len(compared),
                   "series": compared,
                   "regressions": regressions,
                   "improvements": improvements}, sys.stdout, sort_keys=True)
        print()
        return 1 if regressions else 0

    if not prs:
        print("benchdiff: no BENCH_pr*.json files found — nothing to judge")
        return 0
    print(f"benchdiff: trajectory PR{prs[0]}..PR{prs[-1]} "
          f"({len(series)} series, {len(compared)} comparable "
          f"vs last {args.last})")
    for rep in improvements:
        print(f"  improved  {rep['series']} [{rep['unit']}]: "
              f"{rep['value']:.4g} vs {rep['baselines']} "
              f"({rep['delta']:+.0%})")
    for rep in regressions:
        print(f"  REGRESSED {rep['series']} [{rep['unit']}]: "
              f"{rep['value']:.4g} vs {rep['baselines']} "
              f"({rep['delta']:+.0%}, band {rep['band']:.0%})")
    if regressions:
        print(f"benchdiff: {len(regressions)} regression(s) beyond the "
              f"noise band — failing")
        return 1
    print("benchdiff: no regressions beyond the noise band")
    return 0


if __name__ == "__main__":
    sys.exit(main())
