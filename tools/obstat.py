#!/usr/bin/env python
"""Observability CLI — thin wrapper over ``python -m repro.obs`` that
works from a source checkout without PYTHONPATH gymnastics::

    tools/obstat.py HOST:PORT                      # one-shot dump
    tools/obstat.py HOST:PORT --watch --top 10     # hot branches + latency
                                                   #   + profiler section
    tools/obstat.py HOST:PORT --trace out.json     # Chrome trace window
    tools/obstat.py HOST:PORT --prof capture \\
                    --prof-out flame.folded        # live flamegraph (PROF)
    tools/obstat.py --postmortem flight-123.json   # crash bundle viewer
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
