#!/usr/bin/env python
"""Observability CLI — thin wrapper over ``python -m repro.obs`` that
works from a source checkout without PYTHONPATH gymnastics::

    tools/obstat.py HOST:PORT                      # one-shot dump
    tools/obstat.py HOST:PORT --watch --top 10     # hot branches + latency
    tools/obstat.py HOST:PORT --trace out.json     # Chrome trace window
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
