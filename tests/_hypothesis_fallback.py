"""Drop-in stand-ins for ``hypothesis`` so property-based tests *skip*
cleanly (instead of aborting collection) when the package is absent.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_fallback import given, settings, st

``@given(...)`` replaces the test with a zero-argument skipper, so pytest
never tries to resolve the strategy parameters as fixtures; ``settings``
and the ``st`` strategy namespace are inert no-ops.
"""

import pytest


class _InertStrategies:
    def __getattr__(self, name):
        def _strategy(*args, **kwargs):
            return None
        _strategy.__name__ = name
        return _strategy


st = _InertStrategies()


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco


def given(*args, **kwargs):
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco
