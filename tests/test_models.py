"""Per-arch smoke tests: every assigned architecture instantiates at
REDUCED scale (same structure), runs one train step (loss+grads finite),
and serves (prefill + decode parity with the full forward pass)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced, shapes_for
from repro.models import Model
from repro.train import init_train_state, make_train_step

ARCHS = list_archs()


def _mini_batch(cfg, B=2, S=16, key=0):
    tok = jax.random.randint(jax.random.key(key), (B, S), 0, cfg.vocab)
    batch = {"tokens": tok, "targets": jnp.roll(tok, -1, axis=1)}
    if cfg.is_encdec:
        batch["frames"] = jnp.ones((B, 8, cfg.d_model), jnp.float32) * 0.02
    if cfg.n_img_tokens:
        batch["patches"] = jnp.ones((B, cfg.n_img_tokens, cfg.d_model),
                                    jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, peak_lr=1e-3, warmup=2, total_steps=10))
    batch = _mini_batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["loss"]) < 2.5 * np.log(cfg.vocab)
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    leaf = jax.tree.leaves(state.params)[0]
    assert np.isfinite(np.asarray(leaf)).all()
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_serve_parity(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 12
    batch = _mini_batch(cfg, B, S, key=2)
    prefix = cfg.n_img_tokens
    max_len = S + prefix + 4

    # full forward last-position logits
    h, _ = model.forward(params, batch)
    ref_logits = model.unembed(params, h[:, -1])
    assert ref_logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(ref_logits)).all(), arch

    if cfg.is_encdec:
        return  # decode path for enc-dec covered in test_encdec_decode below

    logits_pre, cache = model.prefill(params, batch, max_len=max_len)
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(ref_logits),
                               atol=0.25, rtol=0.1)

    lg, cache = model.decode_step(params, cache, batch["tokens"][:, :1],
                                  jnp.asarray(S + prefix, jnp.int32))
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all(), arch


def test_encdec_decode():
    cfg = reduced(get_config("seamless-m4t-medium"))
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 10
    batch = _mini_batch(cfg, B, S)
    logits, cache = model.prefill(params, batch, max_len=S + 4)
    assert np.isfinite(np.asarray(logits)).all()
    lg, _ = model.decode_step(params, cache, batch["tokens"][:, :1],
                              jnp.asarray(S, jnp.int32))
    assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "jamba-v0.1-52b"])
def test_recurrent_stepwise_matches_full(arch):
    """Chunked full-sequence pass == step-by-step decode (state parity).

    Capacity is raised to dropless for this test: token-choice capacity
    MoE *by design* drops differently under teacher-forced full passes
    (tokens compete across the sequence) than under per-step decode
    (S=1 never exceeds capacity) — the well-known train/serve skew of
    Switch-style routing, documented in DESIGN.md §6b.  Here we verify the
    recurrent-state machinery, so routing must be deterministic."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config(arch)), capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    B, S = 1, 9
    batch = _mini_batch(cfg, B, S, key=4)
    h, _ = model.forward(params, batch)
    ref_logits = model.unembed(params, h[:, -1])
    cache = model.init_cache(B, S + 2)
    lg = None
    for t in range(S):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t:t + 1],
                                      jnp.asarray(t, jnp.int32))
    if arch == "rwkv6-1.6b":
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_logits),
                                   atol=0.3, rtol=0.1)
    else:
        # deep hybrid composite: each mamba layer carries ~1e-3 fp32
        # reassociation drift (associative scan vs sequential recurrence;
        # the strict per-module bound is test below) which compounds
        # through 16 untrained layers.  Assert the predictive
        # DISTRIBUTION matches.
        pr = jax.nn.softmax(ref_logits)
        pd = jax.nn.softmax(lg)
        kl = float(jnp.sum(pr * (jnp.log(pr + 1e-9) - jnp.log(pd + 1e-9))))
        assert kl < 0.25, kl


def test_mamba_module_stepwise_strict():
    """Raw mamba full-pass vs stepwise: tight bound (the per-module
    invariant backing the composite KL test above)."""
    import dataclasses
    from repro.models import ssm as S
    from repro.models.specs import init_params
    cfg = reduced(get_config("jamba-v0.1-52b"))
    p = init_params(S.mamba_specs(cfg), jax.random.key(9))
    x = (jax.random.normal(jax.random.key(5), (2, 12, cfg.d_model)) * 0.5
         ).astype(jnp.bfloat16)
    y_full, st_full = S.mamba(p, x, cfg, return_state=True)
    st = S.init_mamba_state(cfg, 2)
    ys = []
    for t in range(12):
        yt, st = S.mamba_step(p, x[:, t:t + 1], st, cfg)
        ys.append(yt)
    y_step = jnp.concatenate(ys, 1)
    err = float(jnp.abs(y_full.astype(jnp.float32)
                        - y_step.astype(jnp.float32)).max())
    assert err < 5e-3, err
    assert float(jnp.abs(st_full["ssm"] - st["ssm"]).max()) < 5e-3


def test_shapes_assignment():
    """The assigned 40-cell grid: 4 shapes for ssm/hybrid, 3 otherwise."""
    cells = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        names = {s.name for s in shapes_for(cfg)}
        if arch in ("rwkv6-1.6b", "jamba-v0.1-52b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names
        cells += 4  # the grid counts all 4; non-sub-quadratic are documented skips
    assert cells == 40


def test_gemma2_softcap_effective():
    cfg = reduced(get_config("gemma2-9b"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _mini_batch(cfg)
    h, _ = model.forward(params, batch)
    logits = model.unembed(params, h)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_moe_aux_losses_reported():
    cfg = reduced(get_config("llama4-scout-17b-a16e"))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    loss, metrics = model.loss(params, _mini_batch(cfg))
    assert float(metrics["lb_loss"]) > 0
    assert np.isfinite(float(metrics["z_loss"]))
