"""Vectorized codec cores (PR 2): N-stream Huffman container, two-pass
LZ4/token decode, batched matcher — roundtrip fuzz, legacy-format golden
blobs, and wire-format invariants."""

import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip; the rest still run
    from _hypothesis_fallback import given, settings, st

from golden_payloads import dict_prefix, payloads
from repro.core import huffman, lz4, tokexec
from repro.core import repro_deflate as rdef
from repro.core.codec import CompressionConfig, compress, decompress

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


def _golden():
    with open(os.path.join(GOLDEN, "manifest.json")) as f:
        return json.load(f)


def _blob(name):
    with open(os.path.join(GOLDEN, name + ".bin"), "rb") as f:
        return f.read()


# ---------------------------------------------------------------------------
# golden blobs: payloads compressed by the PRE-vectorization codecs must
# still decode byte-identically through the new cores
# ---------------------------------------------------------------------------

def test_golden_blobs_still_decode():
    P = payloads()
    d = dict_prefix()
    checked = 0
    for name, meta in _golden().items():
        blob = _blob(name)
        data = P[meta["payload"]]
        if meta["kind"] == "huffman":
            assert huffman.decode(blob) == data, name
        elif meta["kind"] == "lz4":
            assert lz4.decompress_block(blob, len(data)) == data, name
        elif meta["kind"] == "lz4_dict":
            assert lz4.decompress_block(blob, len(data), dict_prefix=d) == data, name
        elif meta["kind"] in ("rdef", "rzstd"):
            assert rdef.decompress(blob, len(data)) == data, name
        elif meta["kind"] == "rdef_dict":
            assert rdef.decompress(blob, len(data), dictionary=d) == data, name
        elif meta["kind"] == "codec":
            cfg = CompressionConfig(algo=meta["algo"], level=meta["level"],
                                    precond=meta["precond"])
            assert decompress(blob, len(data), cfg) == data, name
        else:  # pragma: no cover - manifest grew a kind this test doesn't know
            raise AssertionError(f"unknown golden kind {meta['kind']}")
        checked += 1
    assert checked >= 50


def test_legacy_huffman_encode_is_bit_identical():
    """encode(n_streams=1) must reproduce the pre-PR wire bytes exactly —
    it is the format old files were written in."""
    P = payloads()
    for name, meta in _golden().items():
        if meta["kind"] != "huffman":
            continue
        assert huffman.encode(P[meta["payload"]], n_streams=1) == _blob(name), name


# ---------------------------------------------------------------------------
# N-stream Huffman container
# ---------------------------------------------------------------------------

def test_huffman_v2_magic_cannot_collide_with_legacy():
    # legacy blobs start with n_symbols_present <= 256 (LE); the V2 magic
    # decodes to 0x4846 = 18502, unreachable by any legacy encoder
    assert int.from_bytes(huffman._V2_MAGIC, "little") > 256


def test_huffman_stream_roundtrip_all_payloads():
    for name, data in payloads().items():
        for ns in (None, 1, 2, 4, 5, 64, 255):
            blob = huffman.encode(data, n_streams=ns)
            assert huffman.decode(blob) == data, (name, ns)


def test_huffman_auto_format_selection():
    small = b"basket" * 100          # < _V2_MIN_SYMBOLS: legacy format
    blob = huffman.encode(small)
    assert blob[:2] != huffman._V2_MAGIC
    big = b"basket" * 2000           # >= threshold: N-stream container
    blob = huffman.encode(big)
    assert blob[:2] == huffman._V2_MAGIC
    assert blob[2] == huffman._V2_VERSION
    assert blob[3] >= huffman._MIN_STREAMS


def test_huffman_v2_ratio_within_2pct(rng):
    data = bytes(rng.integers(97, 117, 1 << 20, dtype=np.uint8))
    legacy = huffman.encode(data, n_streams=1)
    vect = huffman.encode(data)
    assert len(vect) <= len(legacy) * 1.02


def test_huffman_rejects_bad_stream_counts():
    with pytest.raises(ValueError):
        huffman.encode(b"x", n_streams=0)
    with pytest.raises(ValueError):
        huffman.encode(b"x", n_streams=256)


def test_huffman_rejects_unknown_version():
    blob = bytearray(huffman.encode(b"data" * 4096))
    assert blob[:2] == huffman._V2_MAGIC
    blob[2] = 9
    with pytest.raises(ValueError):
        huffman.decode(bytes(blob))


@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=0, max_size=6000),
       ns=st.one_of(st.none(), st.integers(1, 255)))
def test_huffman_roundtrip_fuzz(data, ns):
    assert huffman.decode(huffman.encode(data, n_streams=ns)) == data


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       ns=st.integers(2, 64))
def test_huffman_skewed_alphabet_fuzz(seed, ns):
    rng = np.random.default_rng(seed)
    # zipf-ish skew drives long code lengths (exercises the 15-bit cap)
    vals = np.minimum(rng.zipf(1.2, 20_000), 255).astype(np.uint8)
    data = vals.tobytes()
    assert huffman.decode(huffman.encode(data, n_streams=ns)) == data


# ---------------------------------------------------------------------------
# two-pass LZ4 / token decode
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=0, max_size=4096), level=st.integers(1, 9))
def test_lz4_decode_fuzz_matches_legacy(data, level):
    blob = lz4.compress_block(data, level)
    out = lz4.decompress_block(blob, len(data))
    assert out == data
    assert lz4._decompress_block_legacy(blob, len(data)) == out


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lz4_vector_route_fuzz(seed):
    """Blobs big enough to take the vectorized parse + batched execute."""
    rng = np.random.default_rng(seed)
    win = rng.integers(0, 256, 4 << 10, dtype=np.uint8).tobytes()
    parts = [win]
    total = len(win)
    while total < 64 << 10:
        ln = int(rng.integers(4, 9))
        off = int(rng.integers(0, len(win) - ln))
        parts.append(win[off:off + ln])
        total += ln
    data = b"".join(parts)
    for level in (1, 6):
        blob = lz4.compress_block(data, level)
        assert lz4.decompress_block(blob, len(data)) == data
        assert lz4._decompress_block_legacy(blob, len(data)) == data


def test_lz4_two_pass_agrees_with_legacy_on_corpus(rng):
    payload_list = list(payloads().values()) + [
        bytes(rng.integers(0, 4, 200_000, dtype=np.uint8)),   # dense matches
        (b"\xff" * 300 + b"x") * 500,                          # 255-run exts
        bytes(rng.integers(0, 256, 9000, dtype=np.uint8)) * 30,
    ]
    for data in payload_list:
        for level in (1, 6):
            blob = lz4.compress_block(data, level)
            assert (lz4.decompress_block(blob, len(data))
                    == lz4._decompress_block_legacy(blob, len(data)) == data)


def test_lz4_giant_match_in_dense_stream(rng):
    """Regression: a match length far exceeding the COMP size (matches
    expand) inside a vector-routed stream — the speculative parse must not
    clamp its extension value to the blob length."""
    win = rng.integers(0, 256, 4 << 10, dtype=np.uint8).tobytes()
    parts = []
    for _ in range(2000):
        ln = int(rng.integers(4, 9))
        off = int(rng.integers(0, len(win) - ln))
        parts.append(win[off:off + ln])
    data = (win + b"".join(parts[:1000]) + b"\x07" * 50_000
            + b"".join(parts[1000:]))
    for level in (1, 6):
        blob = lz4.compress_block(data, level)
        assert (lz4.decompress_block(blob, len(data))
                == lz4._decompress_block_legacy(blob, len(data)) == data)


def test_basket_roundtrip_all_preconds_paper_shapes(rng):
    """unpack_basket exercises the stored_len (bitshuffle padding) path the
    codec benchmarks go through."""
    from repro.core.basket import pack_basket, unpack_basket
    payloads_ = [
        (rng.standard_normal(12_001) * 0.3).astype("<f4").tobytes(),
        (0x01000000 + np.cumsum(rng.integers(1, 5, 4002))).astype(">u4").tobytes(),
    ]
    for data in payloads_:
        for precond in ("none", "shuffle4", "bitshuffle4", "delta4+shuffle4"):
            for lvl in (1, 6):
                cfg = CompressionConfig("lz4", lvl, precond)
                payload, meta = pack_basket(data, cfg)
                assert unpack_basket(payload, meta) == data, (precond, lvl)


def test_parse_sequences_vector_matches_scalar(rng):
    """The speculative vectorized parse must agree with the scalar scan."""
    win = rng.integers(0, 256, 2 << 10, dtype=np.uint8).tobytes()
    parts = [win]
    total = len(win)
    while total < 32 << 10:
        ln = int(rng.integers(4, 9))
        off = int(rng.integers(0, len(win) - ln))
        parts.append(win[off:off + ln])
        total += ln
    blob = lz4.compress_block(b"".join(parts), 6)
    scalar = tokexec._scalar_arrays(
        blob, tokexec._scan_scalar(blob, 0, 2, None), 2)
    vector = tokexec._parse_vector(blob, 0, 2)
    for a, b in zip(scalar, vector):
        assert np.array_equal(a, b)


def test_lz4_corrupt_stream_raises():
    data = b"the quick brown fox " * 500
    blob = lz4.compress_block(data, 1)
    with pytest.raises(ValueError):
        lz4.decompress_block(blob, len(data) + 1)
    # dense stream whose matches reach before the window start: the
    # vectorized route must reject it, not scatter out of bounds
    bad = b"\x10A\x60\xea" * 2000 + b"\x10B"   # dist 60000 from position ~5
    with pytest.raises(ValueError):
        lz4.decompress_block(bad, 2000 * 5 + 1)


@settings(max_examples=25, deadline=None)
@given(data=st.binary(min_size=0, max_size=4096),
       level=st.integers(1, 9),
       window_log=st.sampled_from([15, 18]))
def test_rdef_roundtrip_fuzz(data, level, window_log):
    blob = rdef.compress(data, level=level, window_log=window_log)
    assert rdef.decompress(blob, len(data)) == data


def test_dictionary_paths_roundtrip(rng):
    d = dict_prefix()
    for data in (b"", b"suffix-common-tail", payloads()["text"]):
        blob = lz4.compress_block(data, 1, dict_prefix=d)
        assert lz4.decompress_block(blob, len(data), dict_prefix=d) == data
        blob = rdef.compress(data, level=5, dictionary=d)
        assert rdef.decompress(blob, len(data), dictionary=d) == data


# ---------------------------------------------------------------------------
# codec-layer satellites
# ---------------------------------------------------------------------------

def test_lzma_rejects_dictionary_on_compress_only():
    data = b"payload" * 100
    cfg = CompressionConfig(algo="lzma", level=3, dictionary=b"somedict")
    with pytest.raises(ValueError, match="dictionar"):
        compress(data, cfg)
    # decompression must tolerate a configured dictionary: files written
    # before the compress-side check are plain XZ streams
    blob = compress(data, CompressionConfig(algo="lzma", level=3))
    assert decompress(blob, len(data), cfg) == data


def test_engine_inline_small_baskets_byte_identical():
    from repro.io.engine import CompressionEngine
    rng = np.random.default_rng(0)
    raw = [bytes(rng.integers(0, 200, 2000, dtype=np.uint8)) for _ in range(6)]
    chunks = [(i * 10, 10, r) for i, r in enumerate(raw)]
    cfg = CompressionConfig(algo="zlib", level=5)
    with CompressionEngine(workers=2, inline_bytes=1 << 30) as eng:
        inline = list(eng.pack_stream(iter(chunks), cfg))
    with CompressionEngine(workers=2, inline_bytes=0) as eng:
        pooled = list(eng.pack_stream(iter(chunks), cfg))
    assert [p[2] for p in inline] == [p[2] for p in pooled]
    assert [(p[0], p[1]) for p in inline] == [(c[0], c[1]) for c in chunks]
