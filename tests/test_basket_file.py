"""BasketFile container: format invariants, atomicity, seekability,
truncation detection."""

import os

import numpy as np
import pytest

from repro.core import CompressionConfig
from repro.core.basket import pack_basket, unpack_basket, split_array
from repro.core.bfile import BasketFile, BasketWriter, read_arrays, write_arrays


def test_basket_integrity_checksum(rng):
    data = bytes(rng.integers(0, 256, 5000, dtype=np.uint8))
    cfg = CompressionConfig("zlib", 5, "shuffle4")
    payload, meta = pack_basket(data, cfg)
    assert unpack_basket(payload, meta) == data
    # corrupt payload -> either the codec or the checksum must reject it
    bad = bytearray(payload)
    bad[len(bad) // 2] ^= 0xFF
    with pytest.raises(Exception):
        unpack_basket(bytes(bad), meta)
    # silent corruption (valid codec stream, wrong content) -> adler32 catches
    import dataclasses
    meta_bad = dataclasses.replace(meta, checksum=meta.checksum ^ 1)
    with pytest.raises(ValueError, match="checksum"):
        unpack_basket(payload, meta_bad)


def test_split_array_covers_all_rows(rng):
    arr = rng.standard_normal((1000, 3)).astype(np.float32)
    parts = list(split_array(arr, target_basket_bytes=4096))
    assert len(parts) > 1
    assert sum(c for _, c, _ in parts) == 1000
    assert parts[0][0] == 0


def test_write_read_multibasket(tmp_path, rng):
    arrays = {
        "f": rng.standard_normal(50_000).astype(np.float32),
        "i": rng.integers(0, 1000, 50_000).astype(np.int32),
        "off": np.cumsum(rng.integers(1, 7, 50_000)).astype(np.int64),
    }
    p = str(tmp_path / "t.bskt")
    write_arrays(p, arrays, target_basket_bytes=16 * 1024)
    f = BasketFile(p)
    assert set(f.branch_names()) == set(arrays)
    for name in arrays:
        assert len(f.branches[name]["baskets"]) > 1, "must be multi-basket"
        np.testing.assert_array_equal(f.read_branch(name), arrays[name])
        np.testing.assert_array_equal(f.read_branch(name, workers=4), arrays[name])


def test_read_entries_range(tmp_path, rng):
    arr = np.arange(10_000, dtype=np.int64)
    p = str(tmp_path / "r.bskt")
    write_arrays(p, {"x": arr}, target_basket_bytes=8192)
    f = BasketFile(p)
    got = f.read_entries("x", 1234, 5678)
    np.testing.assert_array_equal(got, arr[1234:5678])


def test_atomic_abort_leaves_nothing(tmp_path):
    p = str(tmp_path / "a.bskt")
    w = BasketWriter(p)
    w.write_branch("x", np.arange(10))
    w.abort()
    assert not os.path.exists(p)
    assert not os.path.exists(p + ".tmp")


def test_truncated_file_detected(tmp_path, rng):
    p = str(tmp_path / "t.bskt")
    write_arrays(p, {"x": rng.standard_normal(1000).astype(np.float32)})
    blob = open(p, "rb").read()
    open(p, "wb").write(blob[:-7])  # chop the trailer
    with pytest.raises(ValueError, match="truncated|magic"):
        BasketFile(p)


def test_compression_stats(tmp_path, rng):
    p = str(tmp_path / "s.bskt")
    write_arrays(p, {"runs": np.zeros(100_000, np.int32)})
    f = BasketFile(p)
    assert f.compression_ratio() > 20
    assert f.compressed_bytes() < f.raw_bytes()
