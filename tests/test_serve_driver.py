"""Serve engine behaviour + end-to-end train driver fault-tolerance drill."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.serve import ServeEngine, sample_logits

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def tiny_served():
    cfg = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_head=16, d_ff=128, vocab=128,
                      remat="none")
    m = Model(cfg)
    params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
        m.init(jax.random.key(0)))
    return m, params


def test_engine_drains_queue(tiny_served):
    m, params = tiny_served
    eng = ServeEngine(m, params, batch_slots=3, max_len=64, eos_id=-1)
    rids = [eng.submit(np.arange(4) + i, max_new=6) for i in range(7)]
    out = eng.run()
    assert sorted(out) == sorted(rids)
    assert all(len(v) == 6 for v in out.values())


def test_engine_greedy_deterministic(tiny_served):
    m, params = tiny_served
    outs = []
    for _ in range(2):
        eng = ServeEngine(m, params, batch_slots=2, max_len=64, eos_id=-1)
        eng.submit(np.asarray([5, 6, 7]), max_new=8)
        outs.append(eng.run()[0])
    np.testing.assert_array_equal(outs[0], outs[1])


def test_engine_variable_prompt_lengths(tiny_served):
    m, params = tiny_served
    eng = ServeEngine(m, params, batch_slots=4, max_len=64, eos_id=-1)
    for i, L in enumerate((3, 9, 5, 12, 7)):
        eng.submit(np.arange(L) + 2, max_new=4)
    out = eng.run()
    assert len(out) == 5 and all(len(v) == 4 for v in out.values())


def test_sample_logits_temperature():
    logits = jnp.asarray([[0.0, 10.0, 0.0]])
    assert int(sample_logits(logits, jax.random.key(0), 0.0)[0]) == 1
    draws = {int(sample_logits(logits, jax.random.key(s), 5.0)[0])
             for s in range(50)}
    assert len(draws) > 1  # high temperature actually samples


# ---------------------------------------------------------------------------
# end-to-end driver: preempt + resume drill
# ---------------------------------------------------------------------------

def _drive(workdir, extra):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "rwkv6-1.6b",
         "--reduced", "--steps", "30", "--batch", "4", "--seq-len", "64",
         "--ckpt-every", "10", "--log-every", "30",
         "--workdir", workdir] + extra,
        capture_output=True, text=True, timeout=560, env=env)


@pytest.mark.slow
def test_train_driver_preempt_resume(tmp_path):
    wd = str(tmp_path / "run")
    # phase 1: simulate preemption after 10 steps (checkpoint at 10)
    r1 = _drive(wd, ["--simulate-preempt", "10"])
    assert r1.returncode == 17, r1.stderr[-2000:]
    assert "simulated preemption" in r1.stdout
    # phase 2: resume to completion
    r2 = _drive(wd, [])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 10" in r2.stdout
    log = [json.loads(l) for l in open(os.path.join(wd, "train_log.jsonl"))]
    assert log[-1]["step"] == 30
    assert np.isfinite(log[-1]["loss"])
