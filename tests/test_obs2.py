"""repro.obs v2: trace propagation, heat telemetry, SLO engine, benchdiff.

What's pinned here (DESIGN.md §16):

* traceparent round-trip and malformed-input rejection (a peer's bad
  header must never fail the request it rode in on);
* span parentage: nested spans chain through the thread-local context,
  ``root=True`` mints a trace, id-free spans stay id-free;
* the loopback client→server READV produces one stitched causal tree —
  the normalized span-name forest is a golden file;
* ``obs.trace.dropped`` counts ring evictions; process-pool workers'
  trace rings fold back through ``collect_obs()``;
* bucket-mean quantiles are *exact* for repeated values at bucket edges
  (bsums), and exemplars link a quantile to a concrete trace_id;
* ``snapshot(reset=True)`` vs ``merge`` under concurrency never double-
  counts or drops (the worker-folding race);
* heat sidecars: EWMA decay, atomic persistence, reload-after-restart
  accumulation, and SIGKILL-mid-flush leaves old-or-new, never torn;
* the SLO engine judges rolling windows, not lifetime totals;
* tools/benchdiff.py: exit 0 on the committed trajectory, exit 1 on a
  synthetic injected regression.
"""

from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import context as C
from repro.obs import heat as H
from repro.obs import metrics as M
from repro.obs import slo as S
from repro.obs import trace as T

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
REPO = os.path.dirname(SRC)
GOLDEN_TREE = os.path.join(os.path.dirname(__file__), "golden",
                           "trace_tree_pr9.json")


# ---------------------------------------------------------------------------
# context: traceparent round-trip and rejection
# ---------------------------------------------------------------------------

def test_traceparent_roundtrip():
    ctx = C.SpanContext(C.new_trace_id(), C.new_span_id())
    tp = ctx.to_traceparent()
    assert len(tp) == 55 and tp.startswith("00-")
    assert C.from_traceparent(tp) == ctx
    child = ctx.child()
    assert child.trace_id == ctx.trace_id
    assert child.span_id != ctx.span_id


@pytest.mark.parametrize("bad", [
    None, 42, "", "garbage", "00-abc-def-01",
    "00-" + "g" * 32 + "-" + "a" * 16 + "-01",        # non-hex trace
    "00-" + "0" * 32 + "-" + "a" * 16 + "-01",        # all-zero trace
    "00-" + "a" * 32 + "-" + "0" * 16 + "-01",        # all-zero span
    "00-" + "a" * 31 + "-" + "a" * 16 + "-01",        # short trace
    "00-" + "a" * 32 + "-" + "a" * 16 + "-1",         # short flags
    "00-" + "a" * 32 + "-" + "a" * 16,                # missing flags
])
def test_traceparent_malformed_rejected(bad):
    assert C.from_traceparent(bad) is None


def test_activated_accepts_string_and_none():
    assert C.current() is None
    tp = "00-" + "ab" * 16 + "-" + "cd" * 8 + "-01"
    with C.activated(tp) as ctx:
        assert C.current() is ctx and ctx.trace_id == "ab" * 16
        assert C.current_traceparent() == tp
    assert C.current() is None
    with C.activated(None) as ctx:                    # no-op
        assert ctx is None and C.current() is None
    with C.activated("not-a-traceparent") as ctx:     # malformed => no-op
        assert ctx is None and C.current() is None


# ---------------------------------------------------------------------------
# span parentage
# ---------------------------------------------------------------------------

def test_nested_spans_chain_and_plain_spans_stay_id_free():
    T.clear()
    with T.span("plain.op"):                          # no ctx, no root
        pass
    with T.span("root.op", root=True):
        with T.span("child.op"):
            with T.span("grandchild.op"):
                pass
    evs = {e["name"]: e for e in T.drain()}
    assert "span_id" not in (evs["plain.op"].get("args") or {})
    root = evs["root.op"]["args"]
    child = evs["child.op"]["args"]
    grand = evs["grandchild.op"]["args"]
    assert "parent_id" not in root
    assert child["parent_id"] == root["span_id"]
    assert grand["parent_id"] == child["span_id"]
    assert root["trace_id"] == child["trace_id"] == grand["trace_id"]
    assert C.current() is None                        # stack fully popped


def test_span_adopts_remote_traceparent():
    T.clear()
    remote = C.SpanContext(C.new_trace_id(), C.new_span_id())
    with C.activated(remote.to_traceparent()):
        with T.span("served.op"):
            pass
    (ev,) = T.drain()
    assert ev["args"]["trace_id"] == remote.trace_id
    assert ev["args"]["parent_id"] == remote.span_id


def test_build_tree_orphans_become_roots():
    evs = [{"ph": "X", "name": "orphan", "ts": 1.0,
            "args": {"span_id": "b", "parent_id": "missing"}},
           {"ph": "X", "name": "anon", "ts": 2.0, "args": {}}]
    roots = T.build_tree(evs)
    assert [r["name"] for r in roots] == ["orphan"]   # anon has no span_id


# ---------------------------------------------------------------------------
# ring eviction accounting + worker trace folding
# ---------------------------------------------------------------------------

def test_trace_dropped_counter_on_eviction():
    T.clear()
    T.set_capacity(4)
    try:
        before = obs.snapshot()["counters"].get("obs.trace.dropped", 0)
        for i in range(10):
            T.instant(f"e{i}")
        dropped = obs.snapshot()["counters"]["obs.trace.dropped"] - before
        assert dropped == 6                           # 10 events, 4 kept
        assert [e["name"] for e in T.events()] == [f"e{i}" for i in
                                                   range(6, 10)]
    finally:
        T.set_capacity(65536)
        T.clear()


def test_ingest_folds_foreign_events():
    T.clear()
    n = T.ingest([{"name": "w.op", "ph": "X", "ts": 1.0}, "junk", None])
    assert n == 1
    assert [e["name"] for e in T.drain()] == ["w.op"]


def test_process_pool_worker_spans_fold_back():
    """A traced submit through the *process* pool must bring the worker's
    engine.unpack span home via collect_obs() (drain + ingest)."""
    from repro.core.codec import CompressionConfig
    from repro.io.engine import CompressionEngine

    raw = np.arange(65_536, dtype=np.int64).tobytes()
    T.clear()
    with CompressionEngine(workers=1, shm=False) as eng:
        with T.span("test.root", root=True):
            out = list(eng.pack_stream(
                [(0, 65_536, raw)], CompressionConfig("repro-deflate", 1)))
            assert len(out) == 1
        eng.collect_obs()
    names = {e["name"]: e for e in T.drain()}
    assert "engine.pack" in names
    root = names["test.root"]["args"]
    pack = names["engine.pack"]["args"]
    assert pack["trace_id"] == root["trace_id"]       # one causal tree


# ---------------------------------------------------------------------------
# bucket-mean quantiles (bsums) + exemplars
# ---------------------------------------------------------------------------

def test_quantile_exact_for_repeated_value_at_bucket_edge():
    """2.0 sits exactly on a bucket edge ([2, 4)); positional
    interpolation would report up to ~4.0 for high quantiles, bucket
    means report 2.0 exactly."""
    reg = M.Registry()
    h = reg.histogram("lat_s")
    for _ in range(1000):
        h.observe(2.0)
    for q in (0.5, 0.9, 0.99, 1.0):
        assert h.quantile(q) == 2.0
    # mixed bucket: the mean is exact per-bucket, clamped to bounds
    h2 = reg.histogram("mix_s")
    for _ in range(99):
        h2.observe(1.0)
    h2.observe(256.0)
    assert h2.quantile(0.5) == 1.0                    # mean of [1,2) bucket
    assert h2.quantile(0.999) == 256.0


def test_quantile_falls_back_without_bsums():
    b = {str(M.bucket_index(1.0)): 100}               # old-style snapshot
    q = M.quantile_from_buckets(b, 0.5)
    lo, hi = M.bucket_bounds(M.bucket_index(1.0))
    assert lo < q < hi                                # interpolated


def test_exemplar_links_quantile_to_trace():
    reg = M.Registry()
    h = reg.histogram("req_s")
    for _ in range(99):
        h.observe(0.001)                              # no context: no exemplar
    slow = C.SpanContext(C.new_trace_id(), C.new_span_id())
    with C.activated(slow):
        h.observe(4.0)
    snap = reg.snapshot()["hists"]["req_s"]
    # q=0.999 lands in the slow bucket (cumulative 99 < target 99.9)
    ex = M.exemplar_for_quantile(snap, 0.999)
    assert ex and ex["trace_id"] == slow.trace_id
    assert ex["value"] == 4.0
    assert M.exemplar_for_quantile(snap, 0.0) is None  # fast bucket: none
    # exemplars survive the wire and merge last-writer-wins
    other = M.Registry()
    other.merge(json.loads(json.dumps(reg.snapshot(), sort_keys=True)))
    ex2 = M.exemplar_for_quantile(other.snapshot()["hists"]["req_s"], 0.999)
    assert ex2 == ex


# ---------------------------------------------------------------------------
# snapshot(reset)+merge concurrency: never double-count, never drop
# ---------------------------------------------------------------------------

def test_concurrent_reset_snapshots_and_merge_exact_total():
    src, dst = M.Registry(), M.Registry()
    N_THREADS, N_INC = 4, 25_000
    stop = threading.Event()
    merged_lock = threading.Lock()

    def worker():
        c = src.counter("n")
        h = src.histogram("v_s")
        for _ in range(N_INC):
            c.inc()
            h.observe(1.0)

    def folder():
        while not stop.is_set():
            snap = src.snapshot(reset=True)
            with merged_lock:
                dst.merge(snap)

    threads = [threading.Thread(target=worker) for _ in range(N_THREADS)]
    f = threading.Thread(target=folder)
    f.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    f.join()
    dst.merge(src.snapshot(reset=True))               # the final delta
    snap = dst.snapshot()
    assert snap["counters"]["n"] == N_THREADS * N_INC
    assert snap["hists"]["v_s"]["count"] == N_THREADS * N_INC
    assert snap["hists"]["v_s"]["sum"] == pytest.approx(N_THREADS * N_INC)
    b = snap["hists"]["v_s"]["buckets"]
    assert sum(int(v) for v in b.values()) == N_THREADS * N_INC


# ---------------------------------------------------------------------------
# heat: EWMA, persistence, reload, crash safety
# ---------------------------------------------------------------------------

def test_heat_ewma_decays_by_halflife():
    assert H._decay(100.0, 0.0, 60.0) == 100.0
    assert H._decay(100.0, 60.0, 60.0) == pytest.approx(50.0)
    assert H._decay(100.0, 120.0, 60.0) == pytest.approx(25.0)


def test_heatlog_records_and_ranks(tmp_path):
    hl = H.HeatLog(halflife_s=3600.0)
    p = str(tmp_path / "a.bskt")
    for _ in range(40):
        hl.record(p, "hot", [0, 1], 2048)
    hl.record(p, "cold", [5], 64)
    snap = hl.snapshot()
    rec = snap[os.path.abspath(p)]["branches"]
    assert rec["hot"]["reads"] == 80 and rec["cold"]["reads"] == 1
    assert rec["hot"]["heat"] > 10 * rec["cold"]["heat"]
    assert rec["hot"]["baskets_hot"] == {"0": 40, "1": 40}


def test_heat_sidecar_persists_and_reloads(tmp_path):
    p = str(tmp_path / "a.bskt")
    hl = H.HeatLog(halflife_s=3600.0)
    hl.record(p, "hot", [0], 1024)
    hl.record(p, "hot", [0], 1024)
    hl.flush()
    side = os.path.abspath(p) + H.SIDECAR_SUFFIX
    assert os.path.exists(side)
    doc = H.load_sidecar(side)
    assert doc["version"] == 1
    # a new process adopts the sidecar and keeps accumulating
    hl2 = H.HeatLog(halflife_s=3600.0)
    hl2.record(p, "hot", [0], 1024)
    hl2.record(p, "cold", [3], 64)
    snap = hl2.snapshot()[os.path.abspath(p)]["branches"]
    assert snap["hot"]["reads"] == 3                  # 2 reloaded + 1 new
    ranked = H.rank_branches(H.load_sidecar(side))
    assert ranked[0][0] == "hot"


def test_heat_sidecar_corrupt_is_ignored(tmp_path):
    side = str(tmp_path / ("x.bskt" + H.SIDECAR_SUFFIX))
    for blob in (b"", b"not json", b'{"version": 99}',
                 b'{"version": 1, "branches": "nope"}'):
        with open(side, "wb") as f:
            f.write(blob)
        assert H.load_sidecar(side) is None
    hl = H.HeatLog()
    hl.record(str(tmp_path / "x.bskt"), "b", [0], 1)  # adopts nothing
    assert hl.snapshot()


def test_heat_sidecar_sigkill_mid_flush_never_torn(tmp_path):
    """Kill a flushing writer at a random moment; the sidecar must
    always parse as the old or the new generation — never torn (the
    atomic tmp→fsync→rename commit, same contract as PR 7 containers)."""
    p = str(tmp_path / "k.bskt")
    side = os.path.abspath(p) + H.SIDECAR_SUFFIX
    hl = H.HeatLog()
    hl.record(p, "v1", [0], 1)
    hl.flush()
    old = open(side, "rb").read()

    script = (
        "import sys\n"
        f"sys.path.insert(0, {SRC!r})\n"
        "from repro.obs.heat import HeatLog\n"
        "hl = HeatLog()\n"
        "for i in range(2000):\n"
        "    hl.record(sys.argv[1], 'v2_%d' % i, list(range(64)), 1 << 20)\n"
        "while True:\n"
        "    hl.flush()\n")
    for delay in (0.05, 0.1, 0.2):
        proc = subprocess.Popen([sys.executable, "-c", script, p])
        time.sleep(delay)
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        blob = open(side, "rb").read()
        doc = H.load_sidecar(side)
        assert doc is not None, "sidecar torn by SIGKILL"
        assert blob == old or "v2_0" in doc["branches"]


# ---------------------------------------------------------------------------
# SLO engine
# ---------------------------------------------------------------------------

def _snap_with(verb: str, n: int, bucket_val: float, errors: int = 0):
    i = M.bucket_index(bucket_val)
    key = M.format_key("server.request_s", {"verb": verb})
    snap = {"counters": {M.format_key("server.requests", {"verb": verb}): n},
            "hists": {key: {"count": n, "sum": n * bucket_val,
                            "buckets": {str(i): n},
                            "bsums": {str(i): n * bucket_val}}}}
    if errors:
        snap["counters"][M.format_key("server.errors",
                                      {"verb": verb})] = errors
    return snap


def test_slo_needs_two_ticks_then_judges_window_delta():
    eng = S.SLOEngine([S.SLOSpec("readv-latency", "readv", p99_s=0.250)])
    eng.tick(_snap_with("readv", 100, 0.010), t=1000.0)
    assert eng.evaluate() == []                       # one tick: no window
    eng.tick(_snap_with("readv", 200, 0.010), t=1010.0)
    (v,) = eng.evaluate()
    assert v["ok"] and v["requests"] == 100
    assert v["p99_s"] < 0.250


def test_slo_flags_p99_violation_from_window_not_lifetime():
    """900 historically-fast requests must not mask a slow window."""
    eng = S.SLOEngine([S.SLOSpec("readv-latency", "readv", p99_s=0.250)],
                      max_ticks=16)
    fast = _snap_with("readv", 900, 0.010)
    eng.tick(fast, t=0.0)
    slow = _snap_with("readv", 900, 0.010)
    slow["hists"][M.format_key("server.request_s", {"verb": "readv"})] = {
        "count": 1000,
        "sum": 900 * 0.010 + 100 * 2.0,
        "buckets": {str(M.bucket_index(0.010)): 900,
                    str(M.bucket_index(2.0)): 100},
        "bsums": {str(M.bucket_index(0.010)): 9.0,
                  str(M.bucket_index(2.0)): 200.0}}
    slow["counters"][M.format_key("server.requests",
                                  {"verb": "readv"})] = 1000
    eng.tick(slow, t=10.0)
    (v,) = eng.evaluate()
    assert not v["ok"]
    assert v["p99_s"] == pytest.approx(2.0)


def test_slo_error_budget_burn():
    eng = S.SLOEngine([S.SLOSpec("readv-errors", "readv",
                                 error_budget=0.01)])
    eng.tick(_snap_with("readv", 100, 0.001, errors=0), t=0.0)
    eng.tick(_snap_with("readv", 200, 0.001, errors=5), t=10.0)
    (v,) = eng.evaluate()
    assert not v["ok"]
    assert v["error_rate"] == pytest.approx(0.05)
    assert v["burn"] == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# benchdiff: the perf-trajectory sentinel
# ---------------------------------------------------------------------------

BENCHDIFF = os.path.join(REPO, "tools", "benchdiff.py")


def _write_bench(d, pr, value, unit="MB/s"):
    doc = {"schema": 1, "benches": {"b": [
        {"bench": "b", "stage": "s", "case": "c",
         "value": value, "unit": unit, "wall_s": ""}]}}
    with open(os.path.join(d, f"BENCH_pr{pr}.json"), "w") as f:
        json.dump(doc, f)


def test_benchdiff_green_on_committed_trajectory():
    r = subprocess.run([sys.executable, BENCHDIFF], capture_output=True,
                       text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "trajectory" in r.stdout


def test_benchdiff_flags_injected_regression(tmp_path):
    d = str(tmp_path)
    _write_bench(d, 1, 1000.0)
    _write_bench(d, 2, 1010.0)
    _write_bench(d, 3, 400.0)                         # -60% throughput
    r = subprocess.run([sys.executable, BENCHDIFF, "--dir", d],
                       capture_output=True, text=True)
    assert r.returncode == 1
    assert "REGRESSED" in r.stdout
    # within the noise band: green
    _write_bench(d, 3, 950.0)
    r = subprocess.run([sys.executable, BENCHDIFF, "--dir", d],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout


def test_benchdiff_one_lucky_baseline_cannot_fail_forever(tmp_path):
    """Regression = worse than ALL baselines beyond the band, so a single
    historically lucky-fast run does not poison the gate."""
    d = str(tmp_path)
    _write_bench(d, 1, 5000.0)                        # lucky outlier
    _write_bench(d, 2, 1000.0)
    _write_bench(d, 3, 900.0)                         # fine vs pr2
    r = subprocess.run([sys.executable, BENCHDIFF, "--dir", d],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout


def test_benchdiff_skips_directionless_units(tmp_path):
    d = str(tmp_path)
    _write_bench(d, 1, 1280, unit="reads")
    _write_bench(d, 2, 32, unit="reads")              # workload constant
    r = subprocess.run([sys.executable, BENCHDIFF, "--dir", d],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout


# ---------------------------------------------------------------------------
# the acceptance loopback: one stitched trace + heat restart + heatmap
# ---------------------------------------------------------------------------

@pytest.fixture
def skewed_dir(tmp_path):
    from repro.core.bfile import write_arrays
    from repro.core.codec import CompressionConfig
    rng = np.random.default_rng(11)
    write_arrays(str(tmp_path / "ev.bskt"),
                 {"hot": rng.integers(0, 99, 150_000).astype(np.int64),
                  "cold": rng.integers(0, 99, 150_000).astype(np.int32)},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1, "delta8"),
                 target_basket_bytes=32 * 1024)
    return tmp_path


def _tree_names(roots):
    """Normalize a span forest to names only (ids and times are random)."""
    return [{"name": r["name"], "children": _tree_names(r["children"])}
            for r in roots]


def test_loopback_readv_stitches_one_causal_tree(skewed_dir):
    from repro.remote import BasketServer, RemoteBasketFile
    with BasketServer(str(skewed_dir), workers=2, heat=False) as srv:
        srv.start()
        with RemoteBasketFile(srv.url("ev.bskt"), wire=None) as rf:
            T.clear()
            rf.fetch_wire("hot", [0])
            client = T.drain()
    server = T.drain()                                # post-shutdown stragglers
    merged = T.stitch(client, server)
    roots = T.build_tree([e for e in merged
                          if (e.get("args") or {}).get("trace_id")])
    forest = _tree_names(roots)
    got = json.dumps(forest, sort_keys=True, indent=1)
    if not os.path.exists(GOLDEN_TREE):               # first run: write golden
        with open(GOLDEN_TREE, "w") as f:
            f.write(got)
    assert got == open(GOLDEN_TREE).read(), (
        "stitched span forest drifted from tests/golden/trace_tree_pr9.json;"
        " if the propagation chain changed intentionally, delete the golden"
        " and rerun")
    # and the shape is the documented one regardless of the golden
    assert forest == [{"name": "rbsp.fetch_wire", "children": [
        {"name": "rbsp.serve", "children": [
            {"name": "server.pread", "children": []}]}]}]


def test_heat_survives_server_restart_and_heatmap_ranks_it(skewed_dir):
    from repro.remote import BasketServer, RemoteBasketFile
    root = str(skewed_dir)
    with BasketServer(root, workers=2, heat_flush_s=0.0) as srv:
        srv.start()
        with RemoteBasketFile(srv.url("ev.bskt"), wire=None) as rf:
            nb = len(rf.branches["hot"]["baskets"])
            for _ in range(40):
                rf.fetch_wire("hot", list(range(nb)))
            rf.fetch_wire("cold", [0])
    # restart: the sidecar reloads and keeps accumulating
    with BasketServer(root, workers=2, heat_flush_s=0.0) as srv:
        srv.start()
        with RemoteBasketFile(srv.url("ev.bskt"), wire=None) as rf:
            rf.fetch_wire("cold", [0])
    side = os.path.join(root, "ev.bskt" + H.SIDECAR_SUFFIX)
    doc = H.load_sidecar(side)
    ranked = H.rank_branches(doc)
    assert ranked[0][0] == "hot"
    assert ranked[0][1] > 10 * ranked[1][1]           # 40x skew, ≥10x heat
    assert ranked[1][2] == 2                          # cold reads accumulated
    # tools/heatmap.py agrees (both sidecar-scan and --json modes)
    heatmap = os.path.join(REPO, "tools", "heatmap.py")
    r = subprocess.run([sys.executable, heatmap, root, "--json"],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    rows = json.loads(r.stdout)["rows"]
    assert rows[0]["branch"] == "hot"
