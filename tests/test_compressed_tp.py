"""Compressed TP-reduce numerics (§Perf iteration 7 — kept as a flagged
variant; see EXPERIMENTS.md for why it is not the default)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_compressed_rowparallel_numerics():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", """
import jax, jax.numpy as jnp
from repro.parallel.actctx import activation_context
from repro.parallel.compressed import rowparallel_einsum_compressed
mesh = jax.make_mesh((2, 4), ("data", "model"))
y = jax.random.normal(jax.random.key(0), (4, 16, 32), jnp.float32).astype(jnp.bfloat16)
w = jax.random.normal(jax.random.key(1), (32, 24), jnp.float32) * 0.2
ref = jnp.einsum("bse,ed->bsd", y.astype(jnp.float32), w)
with mesh, activation_context(mesh):
    out = jax.jit(lambda y, w: rowparallel_einsum_compressed(y, w))(y, w)
rel = float(jnp.linalg.norm(out.astype(jnp.float32) - ref) / jnp.linalg.norm(ref))
assert rel < 0.02, rel
print("REL", rel)
"""], capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "REL" in out.stdout


def test_fallback_without_context():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.parallel.compressed import rowparallel_einsum_compressed
    y = jax.random.normal(jax.random.key(0), (2, 8, 16))
    w = jax.random.normal(jax.random.key(1), (16, 12))
    out = rowparallel_einsum_compressed(y, w)
    ref = jnp.einsum("bse,ed->bsd", y, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=1e-3)
