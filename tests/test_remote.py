"""repro.remote: the basket-granular content service (DESIGN.md §12).

Covers the ISSUE-5 acceptance surface:

* local-vs-remote byte identity for every events-corpus branch, plain and
  transcoded wires (checksums verified end-to-end across the transcode);
* vectored-read coalescing unit tests;
* tiered-cache hit / eviction / spill / generation-keying tests;
* multi-client concurrent soak (8 clients, one server);
* malformed / truncated-frame rejection, client and server side;
* a golden wire-frame blob pinning the protocol bytes;
* the PR-5 satellite bugfixes: generation-checked preads (a replaced file
  raises instead of serving stale baskets) and idempotent closes.
"""

import os
import socket
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core.bfile import BasketFile, BasketWriter, write_arrays
from repro.core.codec import CompressionConfig
from repro.data.events import write_event_file
from repro.io import fdcache
from repro.io.prefetch import PrefetchReader
from repro.remote import (BasketServer, ProtocolError, RemoteBasketFile,
                          TieredCache, basket_key, coalesce)
from repro.remote import protocol as P
from repro.remote import transcode as T

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "wire_pr5.bin")


# ---------------------------------------------------------------------------
# fixtures: one served directory per module
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served(tmp_path_factory):
    td = tmp_path_factory.mktemp("remote")
    events = write_event_file(str(td / "events.bskt"), n_events=1500,
                              profile="analysis", basket_bytes=4096)
    # an archive-tier container: what the transcoder exists for
    arch = {"Jet_pt": events["Jet_pt"], "Jet_offsets": events["Jet_offsets"]}
    write_arrays(str(td / "archive.bskt"), arch,
                 cfg_for=lambda n, a: CompressionConfig("lzma", 2, "shuffle"),
                 target_basket_bytes=16 * 1024)
    with BasketServer(str(td), workers=2) as srv:
        srv.start()
        yield {"dir": td, "server": srv, "events": events}


def _open(served, **kw):
    return RemoteBasketFile(served["server"].url("events.bskt"), **kw)


# ---------------------------------------------------------------------------
# byte identity, plain and transcoded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire", [None, "auto"])
def test_every_branch_byte_identical(served, wire):
    with BasketFile(str(served["dir"] / "events.bskt")) as local, \
            _open(served, wire=wire) as rf:
        assert rf.branch_names() == local.branch_names()
        for name in local.branch_names():
            a, b = local.read_branch(name), rf.read_branch(name)
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("wire", [None, "auto"])
def test_archive_file_transcoded_identical(served, wire):
    with BasketFile(str(served["dir"] / "archive.bskt")) as local, \
            RemoteBasketFile(served["server"].url("archive.bskt"),
                             wire=wire, objective="max_read_tput") as rf:
        for name in local.branch_names():
            np.testing.assert_array_equal(local.read_branch(name),
                                          rf.read_branch(name))


def test_transcode_actually_happened(served):
    before = served["server"].stats["transcoded"]
    with RemoteBasketFile(served["server"].url("archive.bskt"),
                          wire="auto", objective="max_read_tput") as rf:
        rf.read_branch("Jet_pt")
    assert served["server"].stats["transcoded"] > before


def test_read_entries_matches_local(served):
    with BasketFile(str(served["dir"] / "events.bskt")) as local, \
            _open(served) as rf:
        for (lo, hi) in [(0, 10), (100, 1100), (1490, 1500), (700, 701)]:
            np.testing.assert_array_equal(
                local.read_entries("Jet_pt", lo, hi),
                rf.read_entries("Jet_pt", lo, hi))
        assert rf.read_entries("Jet_pt", 50_000, 60_000).size == 0


def test_catalog_mirrors_toc(served):
    with BasketFile(str(served["dir"] / "events.bskt")) as local, \
            _open(served) as rf:
        assert rf.tuning_decisions() == local.tuning_decisions()
        assert rf.generation == local.generation
        assert rf.compressed_bytes() == local.compressed_bytes()
        assert rf.raw_bytes() == local.raw_bytes()
        assert rf.ping()


def test_prefetch_reader_remote_source(served):
    with BasketFile(str(served["dir"] / "events.bskt")) as local, \
            _open(served) as rf:
        want = local.read_branch("Muon_pt")
        r = PrefetchReader(rf, "Muon_pt", ahead=2)
        np.testing.assert_array_equal(r.read_all(), want)
        np.testing.assert_array_equal(r.read_entries(5, 60), want[5:60])
        assert r.hits + r.misses > 0
        r.close()


# ---------------------------------------------------------------------------
# coalescing
# ---------------------------------------------------------------------------

def test_coalesce_adjacent_and_gaps():
    # adjacent ranges merge; a gap <= max_gap merges; a larger one splits
    got = coalesce([(0, 10), (10, 10), (30, 5)], max_gap=16, max_span=1 << 20)
    assert got == [(0, 35, [0, 1, 2])]
    got = coalesce([(0, 10), (100, 10)], max_gap=16)
    assert got == [(0, 10, [0]), (100, 10, [1])]


def test_coalesce_sorts_and_keeps_member_indices():
    got = coalesce([(100, 10), (0, 10), (110, 5)], max_gap=0)
    assert got == [(0, 10, [1]), (100, 15, [0, 2])]


def test_coalesce_span_cap():
    got = coalesce([(0, 6), (6, 6)], max_gap=64, max_span=10)
    assert got == [(0, 6, [0]), (6, 6, [1])]


def test_coalesce_overlapping_ranges():
    got = coalesce([(0, 20), (10, 5)], max_gap=0)
    assert got == [(0, 20, [0, 1])]
    assert got[0][0] + got[0][1] >= 15


def test_coalesced_server_preads(served):
    # one vectored request over an entire branch must cost far fewer
    # preads than baskets (the events file lays a branch's baskets
    # adjacently, so they coalesce into a handful of sequential reads)
    srv = served["server"]
    with _open(served, wire=None, batch_baskets=1024) as rf:
        n_baskets = len(rf.branches["Jet_pt"]["baskets"])
        assert n_baskets > 4
        before = dict(srv.stats)
        rf.read_branch("Jet_pt")
        d_req = srv.stats["requests"] - before["requests"]
        d_pread = srv.stats["preads"] - before["preads"]
        assert d_req == 1
        assert d_pread < n_baskets
        assert srv.stats["baskets_served"] >= n_baskets


# ---------------------------------------------------------------------------
# transcoding decisions
# ---------------------------------------------------------------------------

def _lzma_basket():
    rng = np.random.default_rng(3)
    arr = np.cumsum(rng.integers(1, 9, 8192)).astype(np.int64)
    from repro.core.basket import pack_basket
    payload, meta = pack_basket(memoryview(arr).cast("B"),
                                CompressionConfig("lzma", 2, "shuffle"))
    return payload, meta.to_json()


def test_ratio_bound_objective_keeps_archive():
    payload, meta = _lzma_basket()
    wp, wm = T.transcode_basket(payload, meta, None, "min_bytes")
    assert wm is meta and wp is payload
    assert T.wire_candidates(meta, "production", T.DEFAULT_ACCEPT) == []


def test_read_bound_objective_transcodes_lzma():
    payload, meta = _lzma_basket()
    wp, wm = T.transcode_basket(payload, meta, None, "max_read_tput")
    assert wm["algo"] != "lzma"
    assert wm["algo"] in T.DEFAULT_ACCEPT
    # invariants across the transcode: raw identity is checksum-protected
    assert wm["orig_len"] == meta["orig_len"]
    assert wm["stored_len"] == meta["stored_len"]
    assert wm["checksum"] == meta["checksum"]
    assert wm["precond"] == meta["precond"]
    assert wm["comp_len"] == len(wp)
    assert T.verify_transcode(payload, meta, wp, wm)


def test_identity_and_none_source_pass_through():
    from repro.core.basket import pack_basket
    raw = os.urandom(4096)     # incompressible: identity payload
    payload, meta = pack_basket(raw, CompressionConfig("none", 0))
    wp, wm = T.transcode_basket(payload, meta.to_json(), None, "max_read_tput")
    assert wm["algo"] == "none" and bytes(wp) == bytes(payload)


def test_transcode_never_decodes_slower_codec():
    # zlib-1 already decodes faster than the pure-Python lz4 core could
    # even in lz4's best case, so the prefilter prunes it before any
    # encode CPU is spent and the payload passes through
    from repro.core.basket import pack_basket
    arr = np.arange(4096, dtype=np.int64)
    payload, meta = pack_basket(memoryview(arr).cast("B"),
                                CompressionConfig("zlib", 1, "delta8"))
    assert T.wire_candidates(meta.to_json(), "max_read_tput", ("lz4",)) == []
    wp, wm = T.transcode_basket(payload, meta.to_json(), None,
                                "max_read_tput", accept=("lz4",))
    assert wm is meta.to_json() or wm == meta.to_json()
    assert bytes(wp) == bytes(payload)


def test_slow_link_shifts_wire_choice():
    # on a fast link identity wins the read-bound blend; on a slow link
    # wire bytes dominate and a real wire codec (or the archive itself)
    # must win over identity
    payload, meta = _lzma_basket()
    _wp, wm_fast = T.transcode_basket(payload, meta, None, "max_read_tput",
                                      link_mbps=10_000.0)
    assert wm_fast["algo"] == "none"
    _wp, wm_slow = T.transcode_basket(payload, meta, None, "max_read_tput",
                                      link_mbps=5.0)
    assert wm_slow["algo"] != "none"


# ---------------------------------------------------------------------------
# tiered cache
# ---------------------------------------------------------------------------

def test_cache_mem_hit_and_eviction():
    c = TieredCache(mem_bytes=100, disk_bytes=0)
    k1, k2, k3 = (basket_key("p", (1, 2), "b", i) for i in range(3))
    c.put_decoded(k1, b"a" * 40)
    c.put_decoded(k2, b"b" * 40)
    assert c.get_decoded(k1) == b"a" * 40      # touch k1 -> k2 is LRU
    c.put_decoded(k3, b"c" * 40)               # evicts k2
    assert c.get_decoded(k2) is None
    assert c.get_decoded(k1) is not None and c.get_decoded(k3) is not None
    st = c.stats()
    assert st["mem_used"] <= 100 and st["mem_hits"] >= 3
    c.close()


def test_cache_disk_spill_and_budget(tmp_path):
    c = TieredCache(mem_bytes=0, disk_bytes=100, disk_dir=str(tmp_path / "d"))
    k1, k2, k3 = (basket_key("p", (1, 2), "b", i) for i in range(3))
    meta = {"algo": "none", "comp_len": 40}
    c.put_wire(k1, b"a" * 40, meta)
    c.put_wire(k2, b"b" * 40, meta)
    p, m = c.get_wire(k1)
    assert p == b"a" * 40 and m["comp_len"] == 40
    c.put_wire(k3, b"c" * 40, meta)            # budget 100: k2 evicted
    assert c.get_wire(k2) is None
    assert c.get_wire(k3)[0] == b"c" * 40
    assert c.stats()["disk_used"] <= 100
    files = os.listdir(str(tmp_path / "d"))
    assert len(files) == 2                     # evicted file deleted
    c.close()
    assert os.listdir(str(tmp_path / "d")) == []


def test_cache_generation_keying():
    c = TieredCache(mem_bytes=1 << 10)
    old = basket_key("p", (1, 2), "b", 0)
    new = basket_key("p", (1, 3), "b", 0)      # replaced file: new inode
    c.put_decoded(old, b"stale")
    assert c.get_decoded(new) is None          # never served across gens
    assert old != new
    c.close()


def test_client_cache_tiers_round_trip(served):
    cache = TieredCache(mem_bytes=1 << 20, disk_bytes=1 << 20)
    with _open(served, cache=cache) as rf:
        want = rf.read_branch("Jet_eta")       # cold: all misses
        st0 = cache.stats()
        assert st0["misses"] > 0
        np.testing.assert_array_equal(rf.read_branch("Jet_eta"), want)
        st1 = cache.stats()
        # warm: served from the cache tiers, no new misses
        assert st1["misses"] == st0["misses"]
        assert st1["mem_hits"] > st0["mem_hits"] \
            or st1["disk_hits"] > st0["disk_hits"]
        # per-basket path exercises decoded promotion; keys are
        # endpoint-qualified so same-named files on two servers can
        # never collide in a shared cache
        raw0 = rf.read_basket_raw("Jet_eta", 0)
        key = rf._key("Jet_eta", 0)
        assert key[0] == f"{rf.host}:{rf.port}/{rf.path}"
        assert cache.get_decoded(key) == raw0
        # async spill lands after flush: wire tier has the basket too
        cache.flush()
        assert cache.get_wire(key) is not None
    cache.close()


# ---------------------------------------------------------------------------
# malformed / truncated frames
# ---------------------------------------------------------------------------

def test_frame_round_trip_and_rejections():
    import io
    frame = P.pack_frame(P.REQ_READV, {"path": "x", "baskets": [["b", 0]]},
                         b"payload")
    ftype, body, payload = P.read_frame(io.BytesIO(frame))
    assert (ftype, body["path"], payload) == (P.REQ_READV, "x", b"payload")

    with pytest.raises(P.ProtocolError, match="bad magic"):
        P.read_frame(io.BytesIO(b"XXXX" + frame[4:]))
    with pytest.raises(P.ProtocolError, match="truncated"):
        P.read_frame(io.BytesIO(frame[:10]))
    with pytest.raises(P.ProtocolError, match="mid-frame"):
        P.read_frame(io.BytesIO(frame[:-3]))   # truncated payload
    corrupt = frame[:-3] + bytes([frame[-3] ^ 0xFF]) + frame[-2:]
    with pytest.raises(P.ProtocolError, match="checksum"):
        P.read_frame(io.BytesIO(corrupt))
    with pytest.raises(P.ProtocolError, match="unknown frame type"):
        P.read_frame(io.BytesIO(frame[:4] + b"\x7f" + frame[5:]))
    with pytest.raises(EOFError):
        P.read_frame(io.BytesIO(b""))


def test_server_rejects_garbage_connection(served):
    srv = served["server"]
    with socket.create_connection((srv.host, srv.port), timeout=10) as s:
        s.sendall(b"GET / HTTP/1.1\r\nHost: nonsense\r\n\r\n")
        rf = s.makefile("rb")
        ftype, body, _ = P.read_frame(rf)
        assert ftype == P.RESP_ERROR and "protocol" in body["error"]
        assert rf.read(1) == b""               # server hung up


def test_server_error_isolation(served):
    # a bad request answers an error frame; the connection stays usable
    with _open(served) as rf:
        with pytest.raises(RuntimeError, match="no branch"):
            rf.fetch_wire("nope", [0])
        with pytest.raises(RuntimeError, match="out of range"):
            rf.fetch_wire("Jet_pt", [10_000])
        np.testing.assert_array_equal(
            rf.read_branch("nJet"),
            BasketFile(str(served["dir"] / "events.bskt")).read_branch("nJet"))


def test_pipeline_resync_after_midstream_error(served):
    # a pipelined multi-batch fetch whose FIRST batch errors leaves later
    # batches' responses on the wire; the client must drain them so the
    # next request doesn't read an orphaned response as its own
    local = BasketFile(str(served["dir"] / "events.bskt"))
    with _open(served, wire=None, batch_baskets=1) as rf:
        with pytest.raises(RuntimeError, match="out of range"):
            rf.fetch_wire("Jet_pt", [99_999, 0, 1])
        np.testing.assert_array_equal(rf.read_branch("Jet_pt"),
                                      local.read_branch("Jet_pt"))
        np.testing.assert_array_equal(rf.read_branch("nJet"),
                                      local.read_branch("nJet"))
    local.close()


def test_failed_open_raises_cleanly(served):
    with pytest.raises(RuntimeError, match="server error"):
        RemoteBasketFile(served["server"].url("does-not-exist.bskt"))


def test_server_rejects_path_escape(served):
    with _open(served) as rf:
        rf.path = "../events.bskt"
        with pytest.raises(RuntimeError, match="invalid path"):
            rf.fetch_wire("Jet_pt", [0])


# ---------------------------------------------------------------------------
# golden wire blob — the protocol cannot drift silently
# ---------------------------------------------------------------------------

def _golden_frames() -> bytes:
    """Canonical frames with fully-pinned contents (no live generation)."""
    f1 = P.pack_frame(P.REQ_CATALOG, {"path": "events.bskt"})
    f2 = P.pack_frame(P.REQ_READV, {
        "path": "events.bskt", "generation": [11, 22],
        "baskets": [["Jet_pt", 0], ["Jet_pt", 1]],
        "wire": {"objective": "max_read_tput",
                 "accept": ["zstd-fast", "lz4", "none"]}})
    meta = {"algo": "none", "level": 0, "precond": "none", "orig_len": 4,
            "stored_len": 4, "comp_len": 4, "checksum": 67502338,
            "entry_start": 0, "entry_count": 1, "has_dict": False}
    f3 = P.pack_frame(P.RESP_READV, {
        "path": "events.bskt", "generation": [11, 22],
        "baskets": [{"branch": "Jet_pt", "index": 0, "len": 4,
                     "meta": meta}]}, b"\x01\x02\x03\x04")
    f4 = P.pack_frame(P.RESP_ERROR, {"error": "protocol: bad magic b'XXXX'"})
    return f1 + f2 + f3 + f4


def test_golden_wire_blob():
    blob = _golden_frames()
    if not os.path.exists(GOLDEN):      # first run: write the golden
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "wb") as f:
            f.write(blob)
    with open(GOLDEN, "rb") as f:
        assert f.read() == blob, (
            "wire frames changed byte-for-byte — if the protocol change is "
            "intentional, bump the RBP magic version and regenerate "
            "tests/golden/wire_pr5.bin")


def test_golden_blob_still_parses():
    import io
    r = io.BytesIO(_golden_frames())
    types = []
    while True:
        try:
            ftype, _body, _payload = P.read_frame(r)
        except EOFError:
            break
        types.append(ftype)
    assert types == [P.REQ_CATALOG, P.REQ_READV, P.RESP_READV, P.RESP_ERROR]


# ---------------------------------------------------------------------------
# PR-9 body extensions: traceparent + STATS filter/heat (golden + live)
# ---------------------------------------------------------------------------

GOLDEN9 = os.path.join(os.path.dirname(__file__), "golden", "wire_pr9.bin")


def _golden_frames_pr9() -> bytes:
    """Fully-pinned frames for the PR-9 body keys: ``tp`` (traceparent
    propagation) on READV, ``filter``/``heat`` on STATS.  Bodies are
    free-form canonical JSON so the frame layout is untouched — this
    pins that the *extended* bodies stay byte-stable too, alongside the
    PR-5 golden which pins that the old bodies never changed."""
    tp = "00-000102030405060708090a0b0c0d0e0f-0001020304050607-01"
    f1 = P.pack_frame(P.REQ_READV, {
        "path": "events.bskt", "generation": [11, 22],
        "baskets": [["Jet_pt", 0]], "tp": tp})
    f2 = P.pack_frame(P.REQ_STATS, {"filter": ["remote.", "server."],
                                    "heat": True, "tp": tp})
    f3 = P.pack_frame(P.REQ_STATS, {})              # bare poll, unchanged
    return f1 + f2 + f3


def test_golden_wire_blob_pr9():
    blob = _golden_frames_pr9()
    if not os.path.exists(GOLDEN9):     # first run: write the golden
        with open(GOLDEN9, "wb") as f:
            f.write(blob)
    with open(GOLDEN9, "rb") as f:
        assert f.read() == blob, (
            "PR-9 wire frames changed byte-for-byte — if the protocol "
            "change is intentional, regenerate tests/golden/wire_pr9.bin")


def test_golden_blob_pr9_still_parses():
    import io
    r = io.BytesIO(_golden_frames_pr9())
    seen = []
    while True:
        try:
            ftype, body, _payload = P.read_frame(r)
        except EOFError:
            break
        seen.append((ftype, body))
    assert [t for t, _b in seen] == [P.REQ_READV, P.REQ_STATS, P.REQ_STATS]
    assert seen[0][1]["tp"].startswith("00-")
    assert seen[1][1]["filter"] == ["remote.", "server."]
    assert seen[2][1] == {}


def test_stats_filter_prunes_metrics(served):
    from repro.remote.client import fetch_stats
    srv = served["server"]
    with _open(served) as rf:
        rf.read_branch("Jet_pt")                    # ensure server.* exists
    bare = fetch_stats(srv.host, srv.port)
    bare_keys = set(bare["metrics"]["counters"])
    assert any(not k.startswith("server.") for k in bare_keys)

    body = fetch_stats(srv.host, srv.port, filter="server.")
    for kind in ("counters", "gauges", "hists"):
        for k in body["metrics"].get(kind, {}):
            assert k.startswith("server."), k
    assert any(k.startswith("server.reads")
               for k in body["metrics"]["counters"])

    # a prefix list unions (each poll itself bumps server.requests, so
    # compare as a superset), and an unmatched prefix yields nothing
    body2 = fetch_stats(srv.host, srv.port, filter=["server.", "nosuch."])
    keys2 = set(body2["metrics"]["counters"])
    assert keys2 >= {k for k in bare_keys if k.startswith("server.")}
    assert all(k.startswith("server.") for k in keys2)
    body3 = fetch_stats(srv.host, srv.port, filter="nosuch.")
    assert body3["metrics"]["counters"] == {}


def test_stats_heat_key_opt_in(served):
    from repro.remote.client import fetch_stats
    srv = served["server"]
    with _open(served) as rf:
        rf.read_branch("Jet_pt")
    assert "heat" not in fetch_stats(srv.host, srv.port)   # bare: absent
    body = fetch_stats(srv.host, srv.port, heat=True)
    hot = [rec for rec in body["heat"].values()
           if "Jet_pt" in rec["branches"]]
    assert hot and hot[0]["branches"]["Jet_pt"]["reads"] >= 1


# ---------------------------------------------------------------------------
# generation staleness (the PR-5 bugfix)
# ---------------------------------------------------------------------------

def _write_two_generations(tmp_path):
    p = str(tmp_path / "gen.bskt")
    arr1 = np.arange(4096, dtype=np.int64)
    arr2 = arr1 * 3 + 1
    write_arrays(p, {"x": arr1},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1),
                 target_basket_bytes=4096)
    return p, arr1, arr2


def test_bfile_pread_raises_on_replaced_file(tmp_path):
    p, arr1, arr2 = _write_two_generations(tmp_path)
    f = BasketFile(p)
    np.testing.assert_array_equal(f.read_branch("x"), arr1)
    write_arrays(p, {"x": arr2},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1),
                 target_basket_bytes=4096)      # atomic replace
    with pytest.raises(fdcache.StaleFileError):
        f.read_branch("x")
    f.close()
    np.testing.assert_array_equal(BasketFile(p).read_branch("x"), arr2)


def test_prefetch_reader_raises_on_replaced_file(tmp_path):
    p, arr1, arr2 = _write_two_generations(tmp_path)
    f = BasketFile(p)
    r = PrefetchReader(f, "x", ahead=0, workers=0)
    np.testing.assert_array_equal(r.read_all(), arr1)
    write_arrays(p, {"x": arr2},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1),
                 target_basket_bytes=4096)
    r2 = PrefetchReader(f, "x", ahead=0, workers=0)  # stale TOC, new inode
    with pytest.raises(fdcache.StaleFileError):
        r2.read_all()
    r.close()
    r2.close()
    f.close()


def test_server_flips_generation_on_replace(served, tmp_path):
    td = served["dir"]
    p = str(td / "flip.bskt")
    write_arrays(p, {"x": np.arange(1000, dtype=np.int32)},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1))
    url = served["server"].url("flip.bskt")
    rf1 = RemoteBasketFile(url)
    np.testing.assert_array_equal(rf1.read_branch("x"),
                                  np.arange(1000, dtype=np.int32))
    write_arrays(p, {"x": np.arange(1000, 2000, dtype=np.int32)},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1))
    # the old client's generation is now stale: the server refuses rather
    # than serving baskets sliced with the old TOC
    with pytest.raises(RuntimeError, match="stale generation"):
        rf1.fetch_wire("x", [0])
    rf1.close()
    rf2 = RemoteBasketFile(url)                # fresh catalog: new data
    assert rf2.generation != rf1.generation
    np.testing.assert_array_equal(rf2.read_branch("x"),
                                  np.arange(1000, 2000, dtype=np.int32))
    rf2.close()


def test_fdcache_generation_api(tmp_path):
    p = str(tmp_path / "g.bin")
    with open(p, "wb") as f:
        f.write(b"RBKT0000" * 4)
    g1 = fdcache.generation(p)
    assert fdcache.pread(p, 0, 4, expect=g1) == b"RBKT"
    os.replace(p + "", p)                      # same inode: still fresh
    assert fdcache.generation(p) == g1
    with open(p + ".new", "wb") as f:
        f.write(b"x" * 32)
    os.replace(p + ".new", p)
    assert fdcache.generation(p) != g1
    with pytest.raises(fdcache.StaleFileError):
        fdcache.pread(p, 0, 4, expect=g1)


# ---------------------------------------------------------------------------
# idempotent close (the other PR-5 bugfix)
# ---------------------------------------------------------------------------

def test_bfile_close_idempotent_and_releases_fd(tmp_path):
    p = str(tmp_path / "c.bskt")
    write_arrays(p, {"x": np.arange(64, dtype=np.int32)},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1))
    f = BasketFile(p, prefetch=2)
    f.read_branch("x")
    f.close()
    f.close()                                  # second close: no-op
    # the fd cache entry is gone: a fresh read reopens cleanly
    fdcache.invalidate(p)
    with BasketFile(p) as f2:
        assert f2.read_branch("x").size == 64
    f2.close()


def test_writer_close_idempotent(tmp_path):
    p = str(tmp_path / "w.bskt")
    w = BasketWriter(p)
    w.write_branch("x", np.arange(10, dtype=np.int32))
    w.close()
    w.close()                                  # no-op
    w.abort()                                  # after close: no-op
    assert BasketFile(p).read_branch("x").size == 10
    w2 = BasketWriter(str(tmp_path / "w2.bskt"))
    w2.abort()
    w2.abort()                                 # double abort: no-op
    w2.close()                                 # close after abort: no-op
    assert not os.path.exists(str(tmp_path / "w2.bskt"))


def test_remote_and_server_close_idempotent(served):
    rf = _open(served)
    rf.read_branch("run")
    rf.close()
    rf.close()
    srv = BasketServer(str(served["dir"]), workers=0)
    srv.start()
    srv.close()
    srv.close()
    # bound but never served: close() must not block on shutdown()
    srv2 = BasketServer(str(served["dir"]), workers=0)
    srv2.close()


# ---------------------------------------------------------------------------
# concurrency soak
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire", [None, "auto"])
def test_eight_client_soak(served, wire):
    local = {n: a for n, a in served["events"].items()}
    names = list(local)
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            cache = TieredCache(mem_bytes=1 << 20, disk_bytes=1 << 20)
            with _open(served, wire=wire, cache=cache,
                       batch_baskets=4) as rf:
                for _ in range(6):
                    name = names[rng.integers(len(names))]
                    np.testing.assert_array_equal(rf.read_branch(name),
                                                  local[name])
                n = len(local["Jet_pt"])
                lo = int(rng.integers(0, n - 1))
                hi = int(rng.integers(lo + 1, n))
                np.testing.assert_array_equal(
                    rf.read_entries("Jet_pt", lo, hi), local["Jet_pt"][lo:hi])
            cache.close()
        except Exception as e:   # noqa: BLE001 - surfaced below
            errors.append((seed, e))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors


# ---------------------------------------------------------------------------
# URL parsing, pipeline integration, CLI
# ---------------------------------------------------------------------------

def test_parse_format_url():
    assert P.parse_url("repro://h:9147/a/b.bskt") == ("h", 9147, "a/b.bskt")
    assert P.format_url("h", 9147, "/a/b.bskt") == "repro://h:9147/a/b.bskt"
    for bad in ["http://h:1/x", "repro://h/x", "repro://h:1", "repro://:1/x"]:
        with pytest.raises(ValueError):
            P.parse_url(bad)


def test_token_pipeline_over_repro_urls(tmp_path):
    from repro.data.pipeline import TokenPipeline, write_token_shards
    paths = [str(tmp_path / f"s{i}.bskt") for i in range(2)]
    write_token_shards(paths, vocab=500, tokens_per_shard=20_000)
    with BasketServer(str(tmp_path), workers=2) as srv:
        srv.start()
        urls = [srv.url(os.path.basename(p)) for p in paths]
        pl_r = TokenPipeline(urls, batch=2, seq_len=64)
        pl_l = TokenPipeline(paths, batch=2, seq_len=64)
        try:
            for _ in range(4):
                br, bl = next(pl_r), next(pl_l)
                np.testing.assert_array_equal(br["tokens"], bl["tokens"])
                np.testing.assert_array_equal(br["targets"], bl["targets"])
        finally:
            pl_r.close()
            pl_l.close()


@pytest.mark.slow
def test_cli_serves_directory(tmp_path):
    write_event_file(str(tmp_path / "e.bskt"), n_events=200)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.remote", str(tmp_path), "--port", "0",
         "--workers", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, env=env, text=True)
    try:
        line = proc.stdout.readline().strip()
        assert line.startswith("serving ")
        hostport = line.rsplit(" on ", 1)[1]
        with RemoteBasketFile(f"repro://{hostport}/e.bskt") as rf:
            assert rf.read_branch("run").size == 200
    finally:
        proc.terminate()
        proc.wait(timeout=10)
