"""MoE dispatch: dense-reference equivalence, capacity-drop semantics,
custom-vjp gradient correctness (the scatter-free formulation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.moe import moe_ffn, moe_specs
from repro.models.specs import init_params


def _setup(K=2, cf=8.0, E=4):
    cfg = ModelConfig(name="m", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                      d_head=16, d_ff=64, vocab=64, n_experts=E,
                      experts_per_token=K, d_ff_expert=48, capacity_factor=cf)
    p = init_params(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (3, 16, 32))
    return cfg, p, x


def _dense_reference(cfg, p, x):
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    g, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    g = g / g.sum(-1, keepdims=True)

    def expert(e, xt):
        return (jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])) @ p["w_down"][e]

    ref = np.zeros(x.shape)
    B, S, _ = x.shape
    for b in range(B):
        for s in range(S):
            ref[b, s] = sum(float(g[b, s, k]) * np.asarray(expert(int(idx[b, s, k]), x[b, s]))
                            for k in range(cfg.experts_per_token))
    return ref


@pytest.mark.parametrize("K", [1, 2])
def test_matches_dense_reference_no_drops(K):
    cfg, p, x = _setup(K=K)
    out, aux = moe_ffn(p, x, cfg)
    ref = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4)
    assert float(aux["lb_loss"]) > 0


def test_capacity_drops_reduce_output_norm():
    cfg_hi, p, x = _setup(cf=8.0)
    cfg_lo, _, _ = _setup(cf=0.25)
    out_hi, _ = moe_ffn(p, x, cfg_hi)
    out_lo, _ = moe_ffn(p, x, cfg_lo)
    # dropped tokens produce zero expert output -> smaller norm
    assert float(jnp.linalg.norm(out_lo)) < float(jnp.linalg.norm(out_hi))
    assert np.isfinite(np.asarray(out_lo)).all()


def test_custom_vjp_grads_match_fd():
    cfg, p, x = _setup()
    w = jax.random.normal(jax.random.key(2), x.shape)

    def loss(x_, p_):
        o, _ = moe_ffn(p_, x_, cfg)
        return jnp.sum(o * w)

    gx = jax.grad(loss, argnums=0)(x, p)
    gp = jax.grad(loss, argnums=1)(x, p)
    eps = 1e-3
    rng = np.random.default_rng(0)
    for _ in range(5):
        b, s, d_ = rng.integers(3), rng.integers(16), rng.integers(32)
        fd = (loss(x.at[b, s, d_].add(eps), p)
              - loss(x.at[b, s, d_].add(-eps), p)) / (2 * eps)
        assert abs(float(fd) - float(gx[b, s, d_])) < 2e-2 * max(1, abs(float(fd)))
    for name in ("w_gate", "w_up", "w_down", "router"):
        ix = tuple(rng.integers(s) for s in p[name].shape)
        delta = np.zeros(p[name].shape)
        delta[ix] = eps
        fd = float((loss(x, {**p, name: p[name] + delta})
                    - loss(x, {**p, name: p[name] - delta})) / (2 * eps))
        assert abs(fd - float(gp[name][ix])) < 2e-2 * max(1, abs(fd)), name


def test_shared_expert_path():
    cfg, p, x = _setup()
    import dataclasses
    cfg2 = dataclasses.replace(cfg, shared_expert=True)
    p2 = init_params(moe_specs(cfg2), jax.random.key(0))
    out, _ = moe_ffn(p2, x, cfg2)
    assert np.isfinite(np.asarray(out)).all()


def test_load_balance_loss_uniform_is_one():
    """With perfectly uniform routing the Switch lb loss equals 1."""
    cfg, p, x = _setup(K=1, E=4)
    # router with zero weights -> uniform probs; top-1 ties break by index,
    # so ce is degenerate; instead check lb >= 1 (minimum at uniform)
    out, aux = moe_ffn(p, x, cfg)
    assert float(aux["lb_loss"]) >= 0.99
