"""Training semantics: learning, accumulation equivalence, compressed
gradients, LR schedule, clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.train import (init_train_state, make_train_step, warmup_cosine,
                         clip_by_global_norm, adamw_init, adamw_update)


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_head=16, d_ff=128, vocab=128,
                      remat="none")
    return Model(cfg)


def _batch(model, key=7, B=4, S=32):
    tok = jax.random.randint(jax.random.key(key), (B, S), 0, model.cfg.vocab)
    return {"tokens": tok, "targets": jnp.roll(tok, -1, axis=1)}


def test_overfits_fixed_batch(tiny):
    state = init_train_state(tiny, jax.random.key(0))
    step = jax.jit(make_train_step(tiny, peak_lr=1e-2, warmup=5, total_steps=60))
    batch = _batch(tiny)
    first = last = None
    for _ in range(30):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first - 1.0, (first, last)


def test_accum_matches_single_batch_grads(tiny):
    """accum=2 over two half-batches == one full batch (same update)."""
    batch = _batch(tiny, B=4)
    s0 = init_train_state(tiny, jax.random.key(0))
    step1 = jax.jit(make_train_step(tiny, peak_lr=1e-3, warmup=1,
                                    total_steps=10, clip_norm=1e9))
    s1, _ = step1(s0, batch)
    s0b = init_train_state(tiny, jax.random.key(0))
    step2 = jax.jit(make_train_step(tiny, peak_lr=1e-3, warmup=1,
                                    total_steps=10, accum=2, clip_norm=1e9))
    b2 = {k: v.reshape(2, 2, *v.shape[1:]) for k, v in batch.items()}
    s2, _ = step2(s0b, b2)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


def test_compressed_grads_still_learn(tiny):
    state = init_train_state(tiny, jax.random.key(0), compress_grads=True)
    step = jax.jit(make_train_step(tiny, peak_lr=1e-2, warmup=5,
                                   total_steps=60, compress_grads=True))
    batch = _batch(tiny)
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0
    # error-feedback buffers are being used (nonzero)
    err_norm = sum(float(jnp.abs(e.astype(jnp.float32)).sum())
                   for e in jax.tree.leaves(state.err))
    assert err_norm > 0


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0, warmup=10,
                               total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert abs(max(lrs) - 1.0) < 0.1
    assert lrs[-1] < 0.2
    assert lrs[-1] >= 0.099  # min_ratio floor


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0, "b": jnp.ones((5,)) * -100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5
    assert float(norm) > 100


def test_adamw_decoupled_weight_decay():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.zeros((4,))}
    p2, _ = adamw_update(grads, opt, params, lr=0.1, weight_decay=0.5)
    # zero grads: update is pure decay p -= lr*wd*p
    np.testing.assert_allclose(np.asarray(p2["w"]), 0.95, rtol=1e-5)


def test_metrics_contract(tiny):
    state = init_train_state(tiny, jax.random.key(0))
    step = jax.jit(make_train_step(tiny))
    _, m = step(state, _batch(tiny))
    for k in ("loss", "xent", "accuracy", "grad_norm", "lr", "tokens"):
        assert k in m and np.isfinite(float(m[k])), k
