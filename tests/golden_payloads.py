"""Deterministic payloads shared by the golden-blob generator and the
golden-format regression tests (tests/test_vectorized_codecs.py).

The blobs under tests/golden/ were written by the PRE-vectorization codecs
(PR 1 tree); these payload definitions must never change, or the stored
blobs stop corresponding to them.
"""

from __future__ import annotations

import numpy as np


def payloads() -> dict[str, bytes]:
    rng = np.random.default_rng(20260730)
    text = bytes(rng.integers(97, 105, 40_000, dtype=np.uint8))
    offsets = (0x01000000 + np.cumsum(rng.integers(1, 5, 8_000))).astype(">u4")
    return {
        "empty": b"",
        "one": b"R",
        "tiny": b"ROOT I/O",
        "runs": b"\x00" * 7001 + b"\xff" * 999,
        "text": text,
        "random": bytes(rng.integers(0, 256, 30_000, dtype=np.uint8)),
        "offsets": offsets.tobytes(),
        "repeats": (b"basket/branch/entry;" * 2048)[:-3],
        "single_sym": b"\x2a" * 4096,
    }


def dict_prefix() -> bytes:
    rng = np.random.default_rng(7)
    return bytes(rng.integers(97, 105, 2_000, dtype=np.uint8)) + b"suffix-common-tail"
