"""repro.repair — parity sidecars, in-place heal, scrubber, anti-entropy.

Covers the PR 8 self-healing guarantees:

* parity is a **sidecar**: container bytes with ``parity=k`` are
  bit-identical to the pre-PR golden container;
* the sidecar format itself is golden-pinned (``tests/golden/
  parity_pr8.parity``) and heals a rotted copy of the golden container;
* heal is **byte-identical** across precond × codec combos (fuzzed);
* the scrubber resumes after a simulated restart and its cursor refuses
  a rewritten container;
* ``recover_container`` falls back to the parity sidecar's TOC mirror
  when a torn container has no write journal;
* ``CheckpointManager.restore()`` heals a rotted latest step, and falls
  back to the previous known-good step when the latest is unhealable.
"""

import json
import os
import shutil
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.core.bfile import (BasketFile, BasketWriter, CorruptBasketError,
                              read_arrays, recover_container, write_arrays)
from repro.core.codec import CompressionConfig
from repro.fault import rot_container
from repro.io import fdcache
from repro.repair import (ParityError, ParitySidecar, diff_catalogs,
                          parity_path, scrub_container)
from repro.repair.scrub import cursor_path

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


def _golden_tree(rng):
    # the exact PR 2 golden corpus (tests/golden/container_pr2.bskt)
    f = rng.standard_normal(40_000).astype(np.float32)
    off = np.cumsum(rng.integers(1, 9, 30_000)).astype(np.int64)
    tok = rng.integers(0, 255, 50_000).astype(np.uint8)
    return f, off, tok


def _write_golden(path, parity=0):
    rng = np.random.default_rng(42)
    f, off, tok = _golden_tree(rng)
    with BasketWriter(path, parity=parity) as w:
        w.write_branch("f", f, CompressionConfig("lz4", 1, "bitshuffle4"),
                       32 * 1024)
        w.write_branch("off", off,
                       CompressionConfig("repro-deflate", 5, "delta8+shuffle8"),
                       64 * 1024)
        w.write_branch("tok", tok, CompressionConfig("lz4", 6, "none"),
                       16 * 1024)
        w.write_branch("scalar", np.float64(3.25),
                       CompressionConfig("none", 0, "none"))
        w.write_branch("empty", np.zeros((0, 3), np.int32),
                       CompressionConfig("lz4", 1, "shuffle4"))
    return f, off, tok


# ---------------------------------------------------------------------------
# parity is a sidecar: container bytes are golden-pinned
# ---------------------------------------------------------------------------

def test_parity_container_bytes_unchanged(tmp_path):
    """``BasketWriter(parity=4)`` must produce the exact pre-PR golden
    container bytes — parity lives in the sidecar, never the format."""
    p = str(tmp_path / "c.bskt")
    _write_golden(p, parity=4)
    golden = open(os.path.join(GOLDEN, "container_pr2.bskt"), "rb").read()
    assert open(p, "rb").read() == golden
    sc = ParitySidecar.load(parity_path(p))
    assert sc.k == 4
    sc.check_stamp(len(golden), _toc_bytes(p))      # stamp binds these bytes


def _toc_bytes(path):
    with open(path, "rb") as f:
        f.seek(-16, os.SEEK_END)
        toc_len = int.from_bytes(f.read(8), "little")
        f.seek(-16 - toc_len, os.SEEK_END)
        return f.read(toc_len)


def test_golden_parity_sidecar_blob(tmp_path):
    """The sidecar bytes for the golden corpus are themselves pinned:
    format drift (stripe map, header compression, trailer) breaks replay
    of every sidecar in the fleet."""
    p = str(tmp_path / "c.bskt")
    _write_golden(p, parity=4)
    blob = open(parity_path(p), "rb").read()
    golden = os.path.join(GOLDEN, "parity_pr8.parity")
    if not os.path.exists(golden):       # first run on a new checkout
        with open(golden, "wb") as f:
            f.write(blob)
    with open(golden, "rb") as f:
        assert f.read() == blob, \
            "parity sidecar bytes drifted from tests/golden/" \
            "parity_pr8.parity — the sidecar format changed"
    # and the golden sidecar must still parse and describe the container
    sc = ParitySidecar.load(golden)
    assert sc.k == 4 and sc.stripes and sc.branches.keys() == \
        {"f", "off", "tok", "scalar", "empty"}


def test_golden_sidecar_heals_golden_container(tmp_path):
    """Copy the pre-PR golden container next to the pinned sidecar, rot
    it, and heal back to the golden bytes — cross-PR end-to-end."""
    p = str(tmp_path / "c.bskt")
    golden_c = os.path.join(GOLDEN, "container_pr2.bskt")
    golden_s = os.path.join(GOLDEN, "parity_pr8.parity")
    if not os.path.exists(golden_s):
        pytest.skip("golden sidecar not generated yet")
    shutil.copyfile(golden_c, p)
    shutil.copyfile(golden_s, parity_path(p))
    damaged = rot_container(p, seed=11, every=5)     # k=4: <=1 per stripe
    assert damaged
    fdcache.invalidate(p)
    rng = np.random.default_rng(42)
    f, off, tok = _golden_tree(rng)
    with BasketFile(p, heal="auto") as bf:
        np.testing.assert_array_equal(bf.read_branch("f"), f)
        np.testing.assert_array_equal(bf.read_branch("off"), off)
        np.testing.assert_array_equal(bf.read_branch("tok"), tok)
        assert bf.heal_stats["healed"] >= 1
        assert bf.heal_stats["failed"] == 0
    # the scrub heals the baskets no read touched (scalar/empty branches)
    rep = scrub_container(p)
    assert not rep["unhealable"] and rep["completed"]
    assert open(p, "rb").read() == open(golden_c, "rb").read()
    fdcache.invalidate(p)


# ---------------------------------------------------------------------------
# heal byte-identity, fuzzed across precond x codec
# ---------------------------------------------------------------------------

# (codec, precond, dtype) — preconds paired with an itemsize they accept
_COMBOS = [
    ("none", "none", np.int32),
    ("zlib", "shuffle4", np.float32),
    ("lz4", "bitshuffle4", np.int32),
    ("repro-deflate", "delta8+shuffle8", np.int64),
    ("zlib", "delta8+shuffle8", np.int64),
    ("lz4", "none", np.uint8),
]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, len(_COMBOS) - 1), st.integers(0, 2**31 - 1),
       st.integers(2, 5))
def test_heal_byte_identity_fuzz(combo, seed, k):
    """Any single rotted basket per stripe heals back to the exact
    pre-rot container bytes, for every precond x codec combo."""
    algo, precond, dtype = _COMBOS[combo]
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 1 << 16, 3_000).astype(dtype) \
        if np.issubdtype(dtype, np.integer) \
        else rng.standard_normal(3_000).astype(dtype)
    cfg = CompressionConfig(algo, 1, precond)
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "c.bskt")
        with BasketWriter(p, parity=k) as w:
            w.write_branch("x", arr, cfg, 2048)
        pristine = open(p, "rb").read()
        damaged = rot_container(p, seed=seed, every=k + 1)
        fdcache.invalidate(p)
        with BasketFile(p, heal="auto") as bf:
            np.testing.assert_array_equal(bf.read_branch("x"), arr)
            assert bf.heal_stats["healed"] == len(damaged)
            assert bf.heal_stats["failed"] == 0
        assert open(p, "rb").read() == pristine
        fdcache.invalidate(p)


def test_unhealable_two_damaged_stripe_members(tmp_path):
    """Two rotted members of one stripe defeat single parity: the read
    must raise CorruptBasketError, never serve reconstructed garbage."""
    p = str(tmp_path / "c.bskt")
    rng = np.random.default_rng(3)
    write_arrays(p, {"x": rng.integers(0, 99, 4_000).astype(np.int64)},
                 cfg_for=lambda n, a: CompressionConfig("none", 0, "none"),
                 target_basket_bytes=2048, parity=4)
    damaged = rot_container(p, seed=5, every=1, max_baskets=2)
    assert len(damaged) == 2            # stripe 0, members 0 and 1
    fdcache.invalidate(p)
    with BasketFile(p, heal="auto") as bf:
        with pytest.raises(CorruptBasketError):
            bf.read_branch("x")
        assert bf.heal_stats["failed"] >= 1


def test_rot_container_deterministic(tmp_path):
    p = str(tmp_path / "c.bskt")
    rng = np.random.default_rng(7)
    write_arrays(p, {"x": rng.standard_normal(4_000).astype(np.float32)},
                 cfg_for=lambda n, a: CompressionConfig("none", 0, "none"),
                 target_basket_bytes=1024)
    pristine = open(p, "rb").read()
    a = rot_container(p, seed=13, every=3)
    shutil.copyfile(p, p + ".copy")     # re-rot the pristine bytes
    with open(p, "wb") as f:
        f.write(pristine)
    b = rot_container(p, seed=13, every=3)
    assert a == b and a
    assert open(p, "rb").read() == open(p + ".copy", "rb").read()
    fdcache.invalidate(p)


# ---------------------------------------------------------------------------
# scrubber: resume after restart, stale cursor on rewrite
# ---------------------------------------------------------------------------

def _scrub_corpus(path, seed=17):
    rng = np.random.default_rng(seed)
    arrays = {
        "a": rng.integers(0, 1 << 20, 6_000).astype(np.int64),
        "b": rng.standard_normal(6_000).astype(np.float32),
    }
    write_arrays(path, arrays,
                 cfg_for=lambda n, a: CompressionConfig("none", 0, "none"),
                 target_basket_bytes=1024, parity=4)
    return arrays


def test_scrub_resume_after_restart(tmp_path):
    """A killed scrubber (simulated with ``max_baskets``) resumes from
    its persisted cursor and still finds + heals damage past the cut."""
    p = str(tmp_path / "c.bskt")
    arrays = _scrub_corpus(p)
    with BasketFile(p) as bf:
        total = sum(len(bf.branches[n]["baskets"])
                    for n in bf.branch_names())
    assert total > 20
    damaged = rot_container(p, seed=23, every=5)     # k=4 stripes
    assert damaged
    fdcache.invalidate(p)

    first = scrub_container(p, max_baskets=10)       # "restart" here
    assert first["baskets"] == 10 and not first["completed"]
    assert os.path.exists(cursor_path(p))

    second = scrub_container(p)
    assert second["resumed"] and second["completed"]
    assert first["baskets"] + second["baskets"] == total
    assert first["healed"] + second["healed"] == len(damaged)
    assert not first["unhealable"] and not second["unhealable"]
    for name, arr in arrays.items():
        np.testing.assert_array_equal(read_arrays(p)[name], arr)

    third = scrub_container(p)          # completed cursor: fresh full pass
    assert third["completed"] and not third["resumed"]
    assert third["corrupt"] == 0 and third["baskets"] == total
    fdcache.invalidate(p)


def test_scrub_cursor_stale_after_rewrite(tmp_path):
    """A rewritten container (new content stamp) must invalidate the old
    cursor — resuming mid-file over different bytes would skip baskets."""
    p = str(tmp_path / "c.bskt")
    _scrub_corpus(p, seed=17)
    partial = scrub_container(p, max_baskets=8)
    assert not partial["completed"] and os.path.exists(cursor_path(p))
    fdcache.invalidate(p)
    _scrub_corpus(p, seed=99)           # rewrite: different bytes
    fdcache.invalidate(p)
    rep = scrub_container(p)
    assert not rep["resumed"] and rep["completed"]
    fdcache.invalidate(p)


def test_scrub_reports_torn_container(tmp_path):
    p = str(tmp_path / "c.bskt")
    with open(p, "wb") as f:
        f.write(b"RBKTv001partial")
    rep = scrub_container(p)
    assert "error" in rep and not rep["completed"]


# ---------------------------------------------------------------------------
# recover_container: parity TOC mirror as the boundary fallback
# ---------------------------------------------------------------------------

def test_recover_container_from_parity_sidecar(tmp_path):
    """A torn container with no write journal recovers through the
    parity sidecar's TOC mirror; without either it refuses loudly."""
    p = str(tmp_path / "c.bskt")
    rng = np.random.default_rng(31)
    arr = rng.integers(0, 1 << 10, 5_000).astype(np.int64)
    write_arrays(p, {"x": arr},
                 cfg_for=lambda n, a: CompressionConfig("none", 0, "none"),
                 target_basket_bytes=2048, parity=4)
    blob = open(p, "rb").read()
    torn = str(tmp_path / "torn.bskt")
    with open(torn, "wb") as f:
        f.write(blob[: int(len(blob) * 0.6)])        # TOC + tail lost
    shutil.copyfile(parity_path(p), parity_path(torn))
    rep = recover_container(torn)
    assert rep["baskets_kept"] > 0
    got = read_arrays(rep["out_path"])["x"]
    rows = rep["branches"]["x"]
    assert rows > 0
    np.testing.assert_array_equal(got, arr[:rows])
    os.remove(parity_path(torn))        # now neither journal nor parity
    from repro.core.bfile import TruncatedContainerError
    with pytest.raises(TruncatedContainerError):
        recover_container(torn)


# ---------------------------------------------------------------------------
# checkpoint restore: heal in place, else fall back a step
# ---------------------------------------------------------------------------

def _ckpt_tree(rng):
    return {"w": rng.standard_normal((64, 33)).astype(np.float32),
            "step_ids": np.arange(500, dtype=np.int64)}


def test_checkpoint_restore_heals_rotted_step(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=3, parity=2)
    rng = np.random.default_rng(8)
    tree = _ckpt_tree(rng)
    mgr.save(1, tree, extra_meta={"step": 1}, wait=True)
    dp = mgr._data_path(1)
    assert os.path.exists(parity_path(dp))
    damaged = rot_container(dp, seed=3, every=3)     # k=2: <=1 per stripe
    assert damaged
    fdcache.invalidate(dp)
    got, meta = mgr.restore()
    np.testing.assert_array_equal(got["w"], tree["w"])
    np.testing.assert_array_equal(got["step_ids"], tree["step_ids"])
    assert meta["step"] == 1
    fdcache.invalidate(dp)


def test_checkpoint_restore_falls_back_to_previous_step(tmp_path, caplog):
    """An unhealable latest step costs a few steps of retraining, never
    the run: restore() walks back to the previous known-good step."""
    import logging
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=3, parity=2)
    rng = np.random.default_rng(9)
    t1, t2 = _ckpt_tree(rng), _ckpt_tree(rng)
    mgr.save(1, t1, extra_meta={"step": 1}, wait=True)
    mgr.save(2, t2, extra_meta={"step": 2}, wait=True)
    dp2 = mgr._data_path(2)
    with open(dp2, "r+b") as f:          # unhealable: trailer sheared off
        f.truncate(40)
    fdcache.invalidate(dp2)
    with caplog.at_level(logging.WARNING, logger="repro.checkpoint"):
        got, meta = mgr.restore()
    assert meta["step"] == 1
    np.testing.assert_array_equal(got["w"], t1["w"])
    assert any("falling back" in r.message for r in caplog.records)
    # explicit step= means "this step or nothing"
    with pytest.raises(Exception):
        mgr.restore(step=2)
    fdcache.invalidate(dp2)


# ---------------------------------------------------------------------------
# anti-entropy plumbing (pure functions; the socket path is exercised by
# tests/test_fault.py soaks and benchmarks/fig_heal.py)
# ---------------------------------------------------------------------------

def _cat(entries):
    # minimal CATALOG/TOC shape: {branch: {"baskets": [{"meta": {...}}]}}
    return {br: {"baskets": [{"meta": m} for m in ms]}
            for br, ms in entries.items()}


def test_diff_catalogs_flags_divergence():
    good = {"checksum": 1, "orig_len": 8, "entry_start": 0, "entry_count": 2}
    bad = dict(good, checksum=2)
    a = _cat({"x": [good, good]})
    b = _cat({"x": [good, bad]})
    diffs = diff_catalogs({"a": a, "b": b})
    assert [(d["branch"], d["index"]) for d in diffs] == [("x", 1)]
    assert diff_catalogs({"a": a, "b": _cat({"x": [good, good]})}) == []
    # a replica missing a branch shows as None, not a crash
    diffs = diff_catalogs({"a": a, "b": _cat({})})
    assert {d["keys"]["b"] for d in diffs} == {None}


def test_parity_sidecar_refuses_rewritten_container(tmp_path):
    p = str(tmp_path / "c.bskt")
    rng = np.random.default_rng(12)
    write_arrays(p, {"x": rng.standard_normal(2_000).astype(np.float32)},
                 cfg_for=lambda n, a: CompressionConfig("none", 0, "none"),
                 target_basket_bytes=2048, parity=2)
    sc = ParitySidecar.load(parity_path(p))
    sc.check_stamp(os.path.getsize(p), _toc_bytes(p))
    with pytest.raises(ParityError):
        sc.check_stamp(os.path.getsize(p) + 1, _toc_bytes(p))
    with pytest.raises(ParityError):
        sc.check_stamp(os.path.getsize(p), _toc_bytes(p) + b"x")
