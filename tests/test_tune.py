"""repro.tune (PR 4): deterministic sampler, Pareto selection on synthetic
cost tables, drift-triggered re-tune, header persistence round-trips,
tuned-vs-static byte identity, and the tune= paths through the writer,
checkpointer, merger, and token-shard pipeline."""

import os
import types

import numpy as np
import pytest

from repro.core.bfile import BasketFile, BasketWriter, write_arrays
from repro.core.codec import CompressionConfig
from repro.core.policy import PROFILES, choose, precond_for_array
from repro.tune import (OBJECTIVES, Decision, Objective, TrialResult, Tuner,
                        byte_entropy, default_candidates, load_decisions,
                        pareto_front, resolve_objective, sample_offsets,
                        select, stratified_sample)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def test_sampler_deterministic(rng):
    buf = rng.integers(0, 256, 1 << 20, dtype=np.uint8).tobytes()
    a = stratified_sample(buf, itemsize=8, target_bytes=1 << 16)
    b = stratified_sample(buf, itemsize=8, target_bytes=1 << 16)
    assert a.tobytes() == b.tobytes()
    assert a.nbytes <= 1 << 16


def test_sampler_small_buffer_is_whole_buffer(rng):
    buf = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    s = stratified_sample(buf, target_bytes=1 << 16)
    assert s.tobytes() == buf


def test_sampler_covers_head_and_tail():
    # head marker 0xAA, tail marker 0xBB, zeros between: a head-only
    # sampler would never see 0xBB
    buf = np.zeros(1 << 20, np.uint8)
    buf[:4096] = 0xAA
    buf[-4096:] = 0xBB
    s = stratified_sample(buf, target_bytes=1 << 15)
    assert 0xAA in s and 0xBB in s


def test_sampler_window_alignment():
    starts, w = sample_offsets(10_000_000, itemsize=8,
                               target_bytes=1 << 16, windows=8)
    assert all(s % 8 == 0 for s in starts)
    assert w % 8 == 0
    assert starts == sorted(set(starts))
    assert len(starts) == 8
    # stratified: first window at the head, last reaches near the tail
    assert starts[0] == 0
    assert starts[-1] + w > 10_000_000 - 16


def test_byte_entropy_bounds(rng):
    assert byte_entropy(b"\x00" * 4096) == 0.0
    h = byte_entropy(rng.integers(0, 256, 1 << 16, dtype=np.uint8))
    assert 7.9 < h <= 8.0


# ---------------------------------------------------------------------------
# cost model: Pareto + objectives on synthetic tables
# ---------------------------------------------------------------------------

def _t(algo, level, precond, ratio, comp_mbps, decomp_mbps, orig=1 << 20):
    return TrialResult(algo=algo, level=level, precond=precond,
                       orig_len=orig, comp_len=int(orig / ratio),
                       comp_s=orig / (comp_mbps * 1e6),
                       decomp_s=orig / (decomp_mbps * 1e6))


SYNTH = [
    _t("lzma", 6, "shuffle8", ratio=8.0, comp_mbps=3, decomp_mbps=20),
    _t("zstd", 8, "shuffle8", ratio=6.0, comp_mbps=80, decomp_mbps=400),
    _t("zstd", 4, "shuffle8", ratio=5.0, comp_mbps=200, decomp_mbps=450),
    _t("lz4", 1, "shuffle8", ratio=3.0, comp_mbps=400, decomp_mbps=900),
    # dominated: worse than zstd-4 on every axis
    _t("zlib", 6, "none", ratio=4.0, comp_mbps=30, decomp_mbps=120),
]


def test_pareto_front_drops_dominated():
    front = pareto_front(SYNTH)
    assert len(front) == 4
    assert all(t.algo != "zlib" for t in front)


def test_select_pure_objectives():
    assert select(SYNTH, "min_bytes").algo == "lzma"
    assert select(SYNTH, "max_write_tput").algo == "lz4"
    assert select(SYNTH, "max_read_tput").algo == "lz4"


def test_select_blends_pick_interior_points():
    # production: ratio-bound but not at any cost -> zstd-8 beats lzma
    # once decode speed carries 0.25 weight against lzma's 20 MB/s
    assert select(SYNTH, "production").level == 8
    # checkpoint: write-often -> high write weight pulls toward zstd-4
    assert select(SYNTH, "checkpoint") == select(SYNTH, OBJECTIVES["checkpoint"])


def test_select_deterministic_on_exact_ties():
    a = _t("zlib", 1, "none", ratio=2.0, comp_mbps=100, decomp_mbps=100)
    b = _t("zlib", 2, "none", ratio=2.0, comp_mbps=100, decomp_mbps=100)
    assert select([a, b], "min_bytes") is select([b, a], "min_bytes")


def test_resolve_objective_errors_and_dicts():
    with pytest.raises(ValueError, match="min_bytes"):
        resolve_objective("not_an_objective")
    with pytest.raises(ValueError, match="ratio"):
        resolve_objective({"speed": 1.0})
    custom = resolve_objective({"ratio": 0.5, "read": 1.0})
    assert isinstance(custom, Objective) and custom.w_read == 1.0
    with pytest.raises(TypeError):
        resolve_objective(3.14)


def test_trial_result_json_roundtrip():
    t = SYNTH[0]
    assert TrialResult.from_json(t.to_json()) == t
    d = Decision(trial=t, objective="min_bytes", sample_entropy=3.5,
                 n_candidates=12)
    d2 = Decision.from_json(d.to_json())
    assert d2.trial == t and d2.source == "persisted"
    assert d2.objective == "min_bytes"


# ---------------------------------------------------------------------------
# policy satellites
# ---------------------------------------------------------------------------

def test_offset_like_monotone_prefix_nonmonotone_tail(rng):
    # the pre-fix sampler looked at the first 4096 elements only: this
    # array is monotone there but random for 98% of its length
    head = np.arange(8192, dtype=np.int64)
    tail = rng.integers(0, 1000, 500_000).astype(np.int64)
    arr = np.concatenate([head, tail])
    assert precond_for_array(arr) == "shuffle8"          # not delta!
    assert precond_for_array(np.cumsum(np.ones(500_000, np.int64))) \
        == "delta8+shuffle8"
    # non-monotone head, monotone tail: still mostly monotone overall? no —
    # windows average ~1/8 monotone, stays shuffle
    assert precond_for_array(np.concatenate([tail, head])) == "shuffle8"


def test_choose_unknown_profile_raises_value_error():
    with pytest.raises(ValueError) as ei:
        choose("x", np.zeros(64, np.float32), "prodcution")
    msg = str(ei.value)
    assert "prodcution" in msg
    for prof in PROFILES:
        assert prof in msg


# ---------------------------------------------------------------------------
# tuner core
# ---------------------------------------------------------------------------

_FAST = [("zlib", 1, "none"), ("zlib", 1, "shuffle8"),
         ("zlib", 6, "delta8+shuffle8")]


def _offsets(rng, n=200_000):
    return np.cumsum(rng.integers(1, 9, n)).astype(np.int64)


def test_small_branch_falls_back_to_policy(rng):
    t = Tuner("checkpoint", candidates=_FAST)
    arr = rng.standard_normal(128).astype(np.float32)
    cfg = t.config_for("tiny", arr)
    assert cfg == choose("tiny", arr, t.fallback_profile)
    assert t.stats["fallback"] == 1 and t.stats["trials"] == 0


def test_decision_cached_and_reused(rng):
    t = Tuner("min_bytes", candidates=_FAST)
    arr = _offsets(rng)
    c1 = t.config_for("off", arr)
    c2 = t.config_for("off", arr)
    assert c1 == c2
    assert t.stats["tuned"] == 1 and t.stats["reused"] == 1
    assert t.stats["trials"] == len(_FAST)
    # measurement-driven: delta+shuffle wins min_bytes on offset data
    assert c1.precond == "delta8+shuffle8"


def test_default_candidates_cover_profiles_and_prune(rng):
    arr = _offsets(rng)
    ratio_cands = default_candidates(arr, OBJECTIVES["min_bytes"])
    write_cands = default_candidates(arr, OBJECTIVES["max_write_tput"])
    read_cands = default_candidates(arr, OBJECTIVES["max_read_tput"])
    algos_r = {(a, lv) for a, lv, _ in ratio_cands}
    algos_w = {(a, lv) for a, lv, _ in write_cands}
    algos_d = {(a, lv) for a, lv, _ in read_cands}
    assert ("lzma", 6) in algos_r          # ratio-bound keeps the archive
    assert ("lzma", 6) not in algos_w      # throughput-bound prunes it
    assert not any(a == "lz4" for a, _ in algos_r)  # no entropy stage: out
    assert not any(a == "lz4" for a, _ in algos_w)  # too slow to write
    assert ("lz4", 1) in algos_d           # decode-bound keeps fast lz4...
    assert ("lz4", 6) not in algos_d       # ...but not HC (same decoder)
    assert all(lv < 4 for _, lv in algos_w)         # high levels pruned
    assert algos_w                          # the fast C tier survives
    preconds = {pc for _, _, pc in ratio_cands}
    assert {"none", "shuffle8", "delta8+shuffle8"} <= preconds


def test_ratio_drift_triggers_retune(rng):
    t = Tuner("min_bytes", candidates=_FAST, drift_min_baskets=2,
              drift_ratio=0.25, drift_entropy=1e9)
    arr = _offsets(rng)
    t.config_for("off", arr)
    ref = t.decisions["off"].trial.ratio
    assert ref > 2.0
    # observed baskets suddenly incompressible -> EWMA collapses to ~1
    for _ in range(4):
        t.observe("off", types.SimpleNamespace(orig_len=1 << 20,
                                               comp_len=1 << 20))
    t.config_for("off", arr)
    assert t.stats["retuned"] == 1
    # after re-tune the drift history is reset: immediate reuse again
    t.config_for("off", arr)
    assert t.stats["reused"] == 1


def test_entropy_drift_triggers_retune(rng):
    t = Tuner("min_bytes", candidates=_FAST, drift_entropy=2.0)
    lo = np.zeros(200_000, np.int64)            # ~0 bits/byte
    hi = rng.integers(-2**62, 2**62, 200_000).astype(np.int64)  # ~8
    t.config_for("b", lo)
    t.config_for("b", hi)
    assert t.stats["retuned"] == 1
    # stable data does not re-tune
    t.config_for("b", hi)
    assert t.stats["reused"] == 1


def test_observe_accepts_toc_dict_metas():
    t = Tuner("min_bytes", candidates=_FAST)
    t.observe("x", {"orig_len": 100, "comp_len": 50})
    assert t._drift["x"].ewma == pytest.approx(2.0)


def test_budget_cut_finalists_remeasured_at_full_sample(rng):
    # a ridiculously small budget forces every trial onto its 1/8 probe;
    # probe-sized ratios are not comparable to full-sample ratios, so the
    # fairness pass must re-measure the finalists on the full sample
    t = Tuner("min_bytes", candidates=_FAST, trial_budget_s=1e-9)
    arr = _offsets(rng)
    t.config_for("off", arr)
    full = t._sample(arr).size
    assert t.decisions["off"].trial.orig_len == full


def test_load_skips_malformed_persisted_decisions():
    t = Tuner("min_bytes", candidates=_FAST)
    t.load({"bad": {"algo": "zlib"},                       # missing fields
            "worse": {"algo": "zlib", "level": "high"},
            "good": Decision(trial=SYNTH[0], objective="min_bytes",
                             sample_entropy=1.0).to_json()})
    assert set(t.decisions) == {"good"}


def test_concurrent_branch_tuning(rng):
    import threading as _th
    t = Tuner("min_bytes", candidates=_FAST)
    arrays = {f"b{i}": _offsets(rng, 120_000) for i in range(4)}
    errs = []

    def tune_one(name, arr):
        try:
            t.config_for(name, arr)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [_th.Thread(target=tune_one, args=kv) for kv in arrays.items()]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs
    assert set(t.decisions) == set(arrays)
    # all four branches share one signature: concurrent tuning must still
    # pay exactly ONE trial matrix (same-sig tuning serializes, the
    # waiters land on the signature cache)
    assert t.stats["trials"] == len(_FAST)


def test_drift_retune_bypasses_signature_cache(rng):
    t = Tuner("min_bytes", candidates=_FAST, drift_min_baskets=2,
              drift_ratio=0.25, drift_entropy=1e9)
    arr = _offsets(rng)
    t.config_for("off", arr)
    assert t.stats["trials"] == len(_FAST)
    for _ in range(4):
        t.observe("off", types.SimpleNamespace(orig_len=1 << 20,
                                               comp_len=1 << 20))
    # the fresh sample fingerprints to the same entropy bucket, but a
    # drift-triggered re-tune must re-measure, not resurrect the stale
    # decision from the signature cache
    t.config_for("off", arr)
    assert t.stats["retuned"] == 1
    assert t.stats["trials"] == 2 * len(_FAST)


def test_budget_probe_is_stratified():
    from repro.io.engine import _trial_task
    # head window incompressible, the rest zeros: a head-only probe would
    # report ratio ~1; a stratified probe must see the compressible body
    head = np.frombuffer(np.random.default_rng(7).bytes(8192), np.uint8)
    sample = np.concatenate([head, np.zeros(8192 * 7, np.uint8)])
    orig, comp, _c, _d = _trial_task(sample, ("zlib", 6, "none", None),
                                     budget_s=1e-9)
    assert orig < sample.size               # probe path taken
    assert orig / comp > 2.0                # saw the compressible 7/8


def test_signature_sharing_measures_once(rng):
    # two weight planes with the same dtype/statistics: one trial matrix
    t = Tuner("checkpoint", candidates=_FAST)
    a = rng.standard_normal(100_000).astype(np.float32)
    b = rng.standard_normal(100_000).astype(np.float32)
    ca = t.config_for("layer0.w", a)
    cb = t.config_for("layer1.w", b)
    assert ca == cb
    assert t.stats["trials"] == len(_FAST)      # measured once
    assert t.stats["shared"] == 1
    assert t.decisions["layer1.w"].source == "shared"
    # a different-signature branch still measures its own matrix
    t.config_for("off", _offsets(rng))
    assert t.stats["trials"] == 2 * len(_FAST)
    # sharing off: every branch measures
    t2 = Tuner("checkpoint", candidates=_FAST, share_signatures=False)
    t2.config_for("layer0.w", a)
    t2.config_for("layer1.w", b)
    assert t2.stats["trials"] == 2 * len(_FAST)


def test_engine_parallel_trials_match_candidate_space(rng):
    from repro.io.engine import CompressionEngine
    arr = _offsets(rng)
    with CompressionEngine(2) as eng:
        t = Tuner("min_bytes", candidates=_FAST, engine=eng)
        cfg = t.config_for("off", arr)
    assert t.stats["trials"] == len(_FAST)
    assert (cfg.algo, cfg.level, cfg.precond) in _FAST


# ---------------------------------------------------------------------------
# header persistence + reuse without re-measurement
# ---------------------------------------------------------------------------

def test_header_persistence_roundtrip(tmp_path, rng):
    p = str(tmp_path / "t.bskt")
    t = Tuner("min_bytes", candidates=_FAST)
    arr = _offsets(rng)
    write_arrays(p, {"off": arr, "tiny": np.arange(8, dtype=np.int32)},
                 tuner=t)
    with BasketFile(p) as f:
        np.testing.assert_array_equal(f.read_branch("off"), arr)
        dec = f.tuning_decisions()
        # tuned branch persisted; the fallback (too-small) branch is not
        assert set(dec) == {"off"}
        assert dec["off"]["objective"] == "min_bytes"
        assert dec["off"]["precond"] == "delta8+shuffle8"
    assert load_decisions(p) == dec

    # re-open: seeded tuner reuses the decision with zero trials run
    t2 = Tuner.from_file(p)
    assert t2.objective.name == "min_bytes"
    cfg = t2.config_for("off", arr)
    assert t2.stats["trials"] == 0 and t2.stats["reused"] == 1
    assert (cfg.algo, cfg.level, cfg.precond) == \
        (dec["off"]["algo"], dec["off"]["level"], dec["off"]["precond"])


def test_persisted_decision_redone_under_new_objective(tmp_path, rng):
    p = str(tmp_path / "t.bskt")
    arr = _offsets(rng)
    write_arrays(p, {"off": arr}, tuner=Tuner("min_bytes", candidates=_FAST))
    t2 = Tuner.from_file(p, objective="max_read_tput")
    t2.candidates = _FAST
    t2.config_for("off", arr)
    assert t2.stats["reused"] == 0      # objective changed: must re-measure
    assert t2.stats["tuned"] + t2.stats["retuned"] == 1


def test_untuned_file_has_empty_tuning(tmp_path, rng):
    p = str(tmp_path / "plain.bskt")
    write_arrays(p, {"x": rng.standard_normal(1000).astype(np.float32)})
    with BasketFile(p) as f:
        assert f.tuning_decisions() == {}
    assert load_decisions(p) == {}


def test_streaming_chunk_path_tunes_from_first_chunk(tmp_path, rng):
    from repro.core.basket import split_array
    p = str(tmp_path / "s.bskt")
    arr = _offsets(rng)
    t = Tuner("min_bytes", candidates=_FAST)
    with BasketWriter(p, tuner=t) as w:
        w.write_branch_chunks("off", dtype=arr.dtype.str, shape=arr.shape,
                              chunks=split_array(arr, 1 << 18))
    assert t.stats["tuned"] == 1
    with BasketFile(p) as f:
        np.testing.assert_array_equal(f.read_branch("off"), arr)
        assert "off" in f.tuning_decisions()


# ---------------------------------------------------------------------------
# tuned-vs-static byte identity
# ---------------------------------------------------------------------------

def test_tuned_baskets_byte_identical_when_static_config_wins(tmp_path, rng):
    """When the tuner's decision equals the static config, the basket
    stream must be bit-for-bit what the static path writes (same payloads,
    same metas, same offsets) — tuning must never perturb the data plane."""
    arr = _offsets(rng)
    static = ("zlib", 6, "delta8+shuffle8")
    pt, ps = str(tmp_path / "t.bskt"), str(tmp_path / "s.bskt")
    write_arrays(pt, {"off": arr}, tuner=Tuner("min_bytes",
                                               candidates=[static]))
    write_arrays(ps, {"off": arr},
                 cfg_for=lambda n, a: CompressionConfig(*static))
    with BasketFile(pt) as a, BasketFile(ps) as b:
        ba, bb = a.branches["off"]["baskets"], b.branches["off"]["baskets"]
        assert len(ba) == len(bb)
        for i in range(len(ba)):
            assert ba[i]["meta"] == bb[i]["meta"]
            assert ba[i]["offset"] == bb[i]["offset"]
            assert a.read_basket_payload("off", i) == \
                b.read_basket_payload("off", i)
        assert a.compressed_bytes() == b.compressed_bytes()
    # whole data region (pre-TOC) identical; only the TOC differs (it
    # carries the persisted decision)
    blob_t, blob_s = open(pt, "rb").read(), open(ps, "rb").read()
    end = ba[-1]["offset"] + ba[-1]["meta"]["comp_len"]
    assert blob_t[:end] == blob_s[:end]


# ---------------------------------------------------------------------------
# integration: checkpointer, merger, token shards
# ---------------------------------------------------------------------------

def _state(rng, kb=512):
    n = (kb << 10) // 8
    return {"w": rng.standard_normal(n // 2).astype(np.float32).reshape(-1, 64),
            "opt": {"off": np.cumsum(rng.integers(1, 9, n // 2)).astype(np.int64)},
            "step": np.int64(7)}


def test_save_pytree_objective_roundtrip(tmp_path, rng):
    from repro.checkpoint import load_pytree, save_pytree
    tree = _state(rng)
    p = str(tmp_path / "ck.bskt")
    stats = save_pytree(p, tree, objective="checkpoint")
    assert stats["branches"] == 3       # w, opt.off, step
    flat, _meta = load_pytree(p)
    np.testing.assert_array_equal(flat["w"], tree["w"])
    np.testing.assert_array_equal(flat["opt.off"], tree["opt"]["off"])
    with BasketFile(p) as f:
        dec = f.tuning_decisions()
    assert {"w", "opt.off"} <= set(dec)     # big branches tuned + persisted


def test_save_pytree_producers_merger_tune(tmp_path, rng):
    from repro.checkpoint import load_pytree, save_pytree
    tree = _state(rng)
    p = str(tmp_path / "ckp.bskt")
    t = Tuner("max_read_tput", candidates=_FAST)
    save_pytree(p, tree, producers=2, tuner=t)
    flat, _meta = load_pytree(p)
    np.testing.assert_array_equal(flat["opt.off"], tree["opt"]["off"])
    with BasketFile(p) as f:
        assert {"w", "opt.off"} <= set(f.tuning_decisions())


def test_manager_reuses_decisions_across_steps_and_reopen(tmp_path, rng):
    from repro.checkpoint import CheckpointManager
    tree = _state(rng)
    mgr = CheckpointManager(str(tmp_path), tune=True)
    mgr._tuner.candidates = _FAST
    mgr.save(1, tree, wait=True)
    trials_after_first = mgr._tuner.stats["trials"]
    assert trials_after_first > 0
    mgr.save(2, tree, wait=True)
    assert mgr._tuner.stats["trials"] == trials_after_first   # all reused
    got, _ = mgr.restore(2)
    np.testing.assert_array_equal(got["w"], tree["w"])

    # a fresh manager (process restart) seeds from the latest header:
    # step 3 runs zero trials
    mgr2 = CheckpointManager(str(tmp_path), tune=True)
    mgr2._tuner.candidates = _FAST
    mgr2.save(3, tree, wait=True)
    assert mgr2._tuner.stats["trials"] == 0
    assert mgr2._tuner.stats["reused"] > 0


def test_write_token_shards_tune_once_per_corpus(tmp_path):
    from repro.data.pipeline import write_token_shards
    from repro.tune import Tuner as _T
    paths = [str(tmp_path / f"s{i}.bskt") for i in range(3)]
    t = _T("max_read_tput", candidates=_FAST)
    write_token_shards(paths, vocab=1000, tokens_per_shard=64_000, tuner=t)
    assert t.stats["tuned"] == 1            # first shard measures...
    assert t.stats["reused"] == 2           # ...the rest reuse
    for p in paths:
        with BasketFile(p) as f:
            assert f.read_branch("tokens").size == 64_000
            assert "tokens" in f.tuning_decisions()


def test_basket_writer_objective_kwarg(tmp_path, rng):
    p = str(tmp_path / "o.bskt")
    arr = _offsets(rng, 100_000)
    with BasketWriter(p, objective="max_read_tput") as w:
        assert w._tuner is not None
        w._tuner.candidates = _FAST
        w.write_branch("off", arr)
    with BasketFile(p) as f:
        np.testing.assert_array_equal(f.read_branch("off"), arr)
        assert "off" in f.tuning_decisions()
