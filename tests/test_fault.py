"""repro.fault + the failure-hardened remote/storage tier (DESIGN.md §14).

Covers the ISSUE-7 acceptance surface:

* torn-container matrix: truncate a journaled container at every
  structural boundary (mid-basket, mid-TOC, inside the trailer, inside
  the header) — open raises the structured ``TruncatedContainerError``
  and ``recover_container`` salvages exactly the baskets preceding the
  tear, verified against the original bytes;
* the torn-write property end-to-end: SIGKILL a writer subprocess
  mid-save and assert readers get the old generation, the new
  generation, or a structured recovery — never silently wrong bytes;
* the satellite bugfixes: a mid-write failure aborts (tmp unlinked,
  ``close()`` raises once then no-ops) instead of committing a partial
  container; a dead peer raises typed ``RemoteTimeout`` instead of an
  untyped hang;
* deterministic fault plans (same seed + traffic = same faults) and the
  chaos proxy applying them: garble / drop / reset retried to success;
* failover (dead endpoint in the pool), hedged reads (stalled replica
  loses the race), corrupt-basket quarantine with cross-replica
  re-fetch, server load-shedding, idle reaping, drain-then-close;
* every robustness path counted: ``remote.retries{reason}``,
  ``remote.hedge{outcome}``, ``server.shed``, ``bfile.corrupt_baskets``.
"""

import json
import os
import shutil
import signal
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core.bfile import (BasketFile, BasketWriter, CorruptBasketError,
                              TruncatedContainerError, recover_container)
from repro.core.codec import CompressionConfig
from repro.fault import ChaosProxy, FaultPlan, FaultRule, parse_rule, \
    pread_fault_hook
from repro.io import fdcache
from repro.remote import (BasketServer, EndpointPool, RemoteBasketFile,
                          RemoteConnectError, RemoteTimeout, ServerBusy,
                          TieredCache)
from repro.remote import protocol as P

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _counter(name, **labels):
    return obs.REGISTRY.counter(name, **labels).value


@pytest.fixture(autouse=True)
def _no_fault_hook():
    """Every test starts and ends with a clean pread path."""
    prev = fdcache.set_fault_hook(None)
    yield
    fdcache.set_fault_hook(prev)


def _write_container(path, rows=3000, journal=True, algo="none", level=0):
    """Two-branch container with deterministic content and many small
    baskets.  ``algo='none'`` keeps payload bytes == raw bytes, so a
    single flipped payload byte is exactly one ChecksumError (a garbled
    *compressed* stream can fail anywhere in the codec instead)."""
    a = np.arange(rows, dtype=np.int64)
    b = (np.arange(rows, dtype=np.float32) * 0.5).reshape(rows)
    cfg = CompressionConfig(algo, level)
    w = BasketWriter(str(path), journal=journal)
    w.write_branch("a", a, cfg, target_basket_bytes=4096)
    w.write_branch("b", b, cfg, target_basket_bytes=4096)
    w.close()
    return {"a": a, "b": b}


def _structure(path):
    """(basket offsets+lengths per branch, toc_start, toc_len, size)."""
    size = os.path.getsize(path)
    with BasketFile(str(path)) as f:
        baskets = {n: [(bb["offset"], bb["meta"]["comp_len"],
                        bb["meta"]["entry_count"])
                       for bb in f.branches[n]["baskets"]]
                   for n in f.branch_names()}
    with open(path, "rb") as fh:
        fh.seek(-16, os.SEEK_END)
        toc_len = int.from_bytes(fh.read(8), "little")
    return baskets, size - 16 - toc_len, toc_len, size


def _truncate_copy(tmp_path, src, cut, tag):
    dst = str(tmp_path / f"torn-{tag}.bskt")
    shutil.copyfile(src, dst)
    shutil.copyfile(str(src) + ".journal", dst + ".journal")
    with open(dst, "r+b") as fh:
        fh.truncate(cut)
    return dst


# ---------------------------------------------------------------------------
# torn containers: detection + recovery
# ---------------------------------------------------------------------------

def test_truncation_matrix(tmp_path):
    src = str(tmp_path / "whole.bskt")
    arrays = _write_container(src)
    baskets, toc_start, toc_len, size = _structure(src)
    n_total = len(baskets["a"]) + len(baskets["b"])
    assert len(baskets["a"]) >= 3 and len(baskets["b"]) >= 3

    cuts = {
        "header": 5,
        "mid-first-basket": baskets["a"][0][0] + baskets["a"][0][1] // 2,
        "mid-later-basket": baskets["b"][1][0] + 1,
        "mid-toc": toc_start + toc_len // 2,
        "in-trailer": size - 8,          # magic half gone -> bad trailer
        "no-trailer": toc_start,         # whole TOC+trailer missing
    }
    for tag, cut in cuts.items():
        torn = _truncate_copy(tmp_path, src, cut, tag)
        with pytest.raises(TruncatedContainerError):
            BasketFile(torn)

        if tag == "header":
            with pytest.raises(TruncatedContainerError,
                               match="nothing to salvage"):
                recover_container(torn)
            continue
        rep = recover_container(torn)
        out = rep["out_path"]
        assert rep["baskets_kept"] + rep["baskets_lost"] == n_total
        with BasketFile(out) as rf:
            for name in rf.branch_names():
                got = rf.read_branch(name)
                np.testing.assert_array_equal(got, arrays[name][:len(got)])
        if tag in ("mid-toc", "in-trailer", "no-trailer"):
            # every basket precedes the tear: full salvage
            assert rep["baskets_kept"] == n_total
            assert rep["branches"]["a"] == len(arrays["a"])
            assert rep["branches"]["b"] == len(arrays["b"])
        elif tag == "mid-first-basket":
            assert rep["branches"].get("a", 0) == 0
        elif tag == "mid-later-basket":
            # branch a wholly before the tear, b cut at basket 1
            assert rep["branches"]["a"] == len(arrays["a"])
            assert 0 < rep["branches"]["b"] < len(arrays["b"])


def test_recover_needs_journal(tmp_path):
    src = str(tmp_path / "nojournal.bskt")
    _write_container(src, journal=False)
    torn = str(tmp_path / "torn.bskt")
    shutil.copyfile(src, torn)
    with open(torn, "r+b") as fh:
        fh.truncate(os.path.getsize(torn) - 20)
    with pytest.raises(TruncatedContainerError, match="journal"):
        BasketFile.recover(torn)


def test_journal_is_a_sidecar_not_format(tmp_path):
    """journal=True must not change the container bytes (golden-bytes
    invariant: the journal is recovery metadata, never format)."""
    p1, p2 = str(tmp_path / "j.bskt"), str(tmp_path / "nj.bskt")
    _write_container(p1, journal=True)
    _write_container(p2, journal=False)
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        assert f1.read() == f2.read()
    assert os.path.exists(p1 + ".journal")
    assert not os.path.exists(p2 + ".journal")


def test_killed_writer_leaves_old_or_new_never_torn(tmp_path):
    path = str(tmp_path / "gen.bskt")
    v1 = np.zeros(200_000, dtype=np.int64)
    w = BasketWriter(path, journal=True)
    w.write_branch("a", v1, CompressionConfig("zlib", 1))
    w.close()

    script = (
        "import sys, numpy as np\n"
        f"sys.path.insert(0, {SRC!r})\n"
        "from repro.core.bfile import BasketWriter\n"
        "from repro.core.codec import CompressionConfig\n"
        "w = BasketWriter(sys.argv[1], journal=True)\n"
        "arr = np.arange(3_000_000, dtype=np.int64)\n"
        "w.write_branch('a', arr, CompressionConfig('zlib', 6),\n"
        "               target_basket_bytes=64 * 1024)\n"
        "w.close()\n")
    proc = subprocess.Popen([sys.executable, "-c", script, path])
    time.sleep(0.15)
    proc.send_signal(signal.SIGKILL)
    proc.wait()

    # the committed path is always openable: old generation or new
    with BasketFile(path) as f:
        got = f.read_branch("a")
    v2 = np.arange(3_000_000, dtype=np.int64)
    assert (got.size == v1.size and (got == v1).all()) \
        or (got.size == v2.size and (got == v2).all())

    # a leftover tmp is salvageable up to the tear (or structurally empty)
    tmp = path + ".tmp"
    if os.path.exists(tmp) and os.path.getsize(tmp) > 8:
        rep = recover_container(tmp, str(tmp_path / "salvaged.bskt"))
        rows = rep["branches"].get("a", 0)
        if rows:
            with BasketFile(rep["out_path"]) as f:
                np.testing.assert_array_equal(f.read_branch("a"), v2[:rows])


# ---------------------------------------------------------------------------
# satellite: mid-write failure aborts instead of committing
# ---------------------------------------------------------------------------

def test_failed_write_aborts_and_close_is_idempotent(tmp_path):
    path = str(tmp_path / "fail.bskt")

    def chunks():
        yield (0, 512, np.arange(512, dtype=np.int64))
        raise RuntimeError("producer died")

    w = BasketWriter(path, journal=True)
    with pytest.raises(RuntimeError, match="producer died"):
        w.write_branch_chunks("a", dtype="<i8", shape=[1024],
                              chunks=chunks())
    with pytest.raises(RuntimeError, match="failed mid-stream"):
        w.close()
    # aborted: no tmp, no committed file, no stale journal; close no-ops
    assert not os.path.exists(path)
    assert not os.path.exists(path + ".tmp")
    assert not os.path.exists(path + ".journal")
    w.close()
    w.abort()


def test_context_manager_aborts_on_exception(tmp_path):
    path = str(tmp_path / "ctx.bskt")
    with pytest.raises(ValueError, match="boom"):
        with BasketWriter(path) as w:
            w.write_branch("a", np.arange(64, dtype=np.int64))
            raise ValueError("boom")
    assert not os.path.exists(path)
    assert not os.path.exists(path + ".tmp")


# ---------------------------------------------------------------------------
# local corruption: structured quarantine
# ---------------------------------------------------------------------------

def test_local_corrupt_basket_error_is_structured(tmp_path):
    path = str(tmp_path / "c.bskt")
    _write_container(path)
    before = _counter("bfile.corrupt_baskets")
    fdcache.set_fault_hook(pread_fault_hook(match="c.bskt", kind="garble"))
    with BasketFile(path) as f:
        with pytest.raises(CorruptBasketError) as ei:
            f.read_branch("a")
    e = ei.value
    assert e.branch == "a" and e.index >= 0 and e.offset >= 8
    assert e.path.endswith("c.bskt")
    assert "branch='a'" in str(e)
    assert _counter("bfile.corrupt_baskets") > before
    fdcache.set_fault_hook(None)
    with BasketFile(path) as f:        # undamaged underneath: reads fine
        assert f.read_branch("a")[-1] == 2999


def test_pread_short_hook_raises_eof(tmp_path):
    path = str(tmp_path / "s.bskt")
    _write_container(path)
    fdcache.set_fault_hook(pread_fault_hook(match="s.bskt", kind="short",
                                            max_fires=1))
    with BasketFile(path) as f:
        with pytest.raises(EOFError):
            f.read_basket_payload("a", 0)


# ---------------------------------------------------------------------------
# fault plans: determinism
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic():
    rules = [FaultRule("garble", p=0.3, direction="s2c")]
    runs = []
    for _ in range(2):
        plan = FaultPlan(rules, seed=42)
        runs.append([bool(plan.decide(conn_id=0, direction="s2c",
                                      frame_no=i)) for i in range(200)])
    assert runs[0] == runs[1]
    n = sum(runs[0])
    assert 20 < n < 100                 # p=0.3 over 200 frames
    other = FaultPlan(rules, seed=43)
    assert [bool(other.decide(conn_id=0, direction="s2c", frame_no=i))
            for i in range(200)] != runs[0]


def test_fault_plan_triggers():
    plan = FaultPlan([FaultRule("drop", verb="readv", direction="c2s",
                                every=3, max_fires=2)], seed=0)
    fired = [bool(plan.decide(conn_id=1, direction="c2s", verb="readv",
                              frame_no=i)) for i in range(1, 13)]
    assert fired == [False, False, True, False, False, True,
                     False, False, False, False, False, False]
    assert plan.counts() == {"drop": 2}
    assert not plan.decide(conn_id=1, direction="s2c", verb="readv",
                           frame_no=3)
    assert not plan.decide(conn_id=1, direction="c2s", verb="ping",
                           frame_no=3)


def test_parse_rule():
    r = parse_rule("delay:verb=readv,ms=100,p=0.5,dir=s2c,max=3")
    assert r.kind == "delay" and r.verb == "readv"
    assert r.delay_s == pytest.approx(0.1) and r.p == 0.5
    assert r.direction == "s2c" and r.max_fires == 3
    assert parse_rule("reset").kind == "reset"
    with pytest.raises(ValueError):
        parse_rule("explode")
    with pytest.raises(ValueError):
        parse_rule("drop:banana=1")


# ---------------------------------------------------------------------------
# client failure semantics
# ---------------------------------------------------------------------------

def _dead_port():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def test_dead_peer_raises_typed_timeout():
    """Satellite bugfix: a peer that accepts and never answers used to
    hang the client in an untyped blocking recv."""
    lsock = socket.socket()
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    held = []
    t = threading.Thread(
        target=lambda: held.append(lsock.accept()[0]), daemon=True)
    t.start()
    try:
        t0 = time.monotonic()
        with pytest.raises(RemoteTimeout):
            RemoteBasketFile(host="127.0.0.1",
                             port=lsock.getsockname()[1],
                             path="x.bskt", timeout=0.3, retries=0)
        assert time.monotonic() - t0 < 3.0
    finally:
        lsock.close()
        for s in held:
            s.close()


def test_unreachable_raises_connect_error():
    with pytest.raises(RemoteConnectError):
        RemoteBasketFile(host="127.0.0.1", port=_dead_port(),
                         path="x.bskt", timeout=0.5, retries=0)


def test_endpoint_pool_rotation_and_cooldown():
    pool = EndpointPool(["h1:1", "h2:2", "h3:3"], cooldown=30.0)
    assert [pool.pick() for _ in range(3)] == \
        [("h1", 1), ("h2", 2), ("h3", 3)]
    pool.report(("h2", 2), ok=False)
    picks = [pool.pick() for _ in range(4)]
    assert ("h2", 2) not in picks          # cooled down, skipped
    assert ("h2", 2) == pool.pick(exclude={("h1", 1), ("h3", 3)})
    pool.report(("h2", 2), ok=True)
    assert ("h2", 2) in [pool.pick() for _ in range(3)]
    assert len(pool.healthy()) == 3


def test_pool_failover_dead_replica(tmp_path):
    _write_container(str(tmp_path / "d.bskt"))
    with BasketServer(str(tmp_path), workers=0) as srv:
        srv.start()
        before = _counter("remote.retries", reason="connect")
        with RemoteBasketFile(
                path="d.bskt",
                endpoints=[("127.0.0.1", _dead_port()),
                           (srv.host, srv.port)],
                timeout=1.0, retries=3, backoff=0.01) as rf:
            got = rf.read_branch("a")
        assert got[-1] == 2999
        assert _counter("remote.retries", reason="connect") > before


# ---------------------------------------------------------------------------
# chaos proxy: injected wire faults retried to success
# ---------------------------------------------------------------------------

@pytest.fixture()
def chaos_env(tmp_path):
    arrays = _write_container(str(tmp_path / "x.bskt"))
    with BasketServer(str(tmp_path), workers=0) as srv:
        srv.start()
        yield {"dir": tmp_path, "server": srv, "arrays": arrays}


def _via_proxy(env, plan, **kw):
    proxy = ChaosProxy(env["server"].host, env["server"].port, plan)
    rf = RemoteBasketFile(host=proxy.host, port=proxy.port, path="x.bskt",
                          wire=None, timeout=1.0, retries=4,
                          backoff=0.01, **kw)
    return proxy, rf


@pytest.mark.parametrize("rule,reason", [
    (FaultRule("garble", direction="s2c", verb="readv", max_fires=1),
     "frame"),
    (FaultRule("drop", direction="s2c", verb="readv", max_fires=1),
     "timeout"),
    (FaultRule("reset", direction="c2s", verb="readv", max_fires=1),
     None),
    (FaultRule("short", direction="s2c", verb="readv", max_fires=1),
     None),
])
def test_chaos_fault_retried_to_success(chaos_env, rule, reason):
    plan = FaultPlan([rule], seed=7)
    before = _counter("remote.retries", reason=reason) if reason else None
    proxy, rf = _via_proxy(chaos_env, plan)
    try:
        with rf:
            np.testing.assert_array_equal(rf.read_branch("a"),
                                          chaos_env["arrays"]["a"])
        assert plan.counts().get(rule.kind) == 1   # the fault did happen
        if reason:
            assert _counter("remote.retries", reason=reason) > before
    finally:
        proxy.close()


def test_chaos_delay_is_survivable(chaos_env):
    plan = FaultPlan([FaultRule("delay", direction="s2c", verb="readv",
                                delay_s=0.2, every=2)], seed=1)
    proxy, rf = _via_proxy(chaos_env, plan)
    try:
        with rf:
            np.testing.assert_array_equal(rf.read_branch("b"),
                                          chaos_env["arrays"]["b"])
        assert plan.counts().get("delay", 0) >= 1
    finally:
        proxy.close()


# ---------------------------------------------------------------------------
# hedged reads
# ---------------------------------------------------------------------------

def test_hedge_beats_stalled_replica(chaos_env):
    env = chaos_env
    plan = FaultPlan([FaultRule("delay", direction="s2c", verb="readv",
                                delay_s=0.4)], seed=3)
    proxy = ChaosProxy(env["server"].host, env["server"].port, plan)
    wins_before = _counter("remote.hedge", outcome="win")
    try:
        with RemoteBasketFile(
                path="x.bskt",
                endpoints=[(proxy.host, proxy.port),
                           (env["server"].host, env["server"].port)],
                wire=None, timeout=5.0, retries=2, backoff=0.01,
                hedge=0.05) as rf:
            t0 = time.monotonic()
            got = rf.read_branch("a")
            dt = time.monotonic() - t0
        np.testing.assert_array_equal(got, env["arrays"]["a"])
        assert _counter("remote.hedge", outcome="win") > wins_before
        # without hedging every batch would eat the full 0.4s stall
        assert dt < 0.4 * 2
    finally:
        proxy.close()


# ---------------------------------------------------------------------------
# corrupt-basket quarantine: cross-replica re-fetch
# ---------------------------------------------------------------------------

def test_remote_corruption_refetched_from_replica(tmp_path):
    dir_a, dir_b = tmp_path / "ra", tmp_path / "rb"
    dir_a.mkdir(), dir_b.mkdir()
    arrays = _write_container(str(dir_a / "r.bskt"))
    shutil.copyfile(str(dir_a / "r.bskt"), str(dir_b / "r.bskt"))
    # replica A's disk is rotting: every basket pread garbled
    fdcache.set_fault_hook(pread_fault_hook(match=str(dir_a), kind="garble"))
    before = _counter("remote.retries", reason="corrupt")
    with BasketServer(str(dir_a), workers=0) as sa, \
            BasketServer(str(dir_b), workers=0) as sb:
        sa.start(), sb.start()
        with RemoteBasketFile(
                path="r.bskt",
                endpoints=[(sa.host, sa.port), (sb.host, sb.port)],
                wire=None, timeout=2.0, retries=2, backoff=0.01,
                cache=TieredCache(mem_bytes=1 << 20)) as rf:
            np.testing.assert_array_equal(rf.read_branch("a"), arrays["a"])
            np.testing.assert_array_equal(
                rf.read_entries("b", 10, 50), arrays["b"][10:50])
    assert _counter("remote.retries", reason="corrupt") > before


def test_all_replicas_corrupt_raises_structured(tmp_path):
    _write_container(str(tmp_path / "r2.bskt"))
    fdcache.set_fault_hook(pread_fault_hook(match=str(tmp_path),
                                            kind="garble"))
    with BasketServer(str(tmp_path), workers=0) as srv:
        srv.start()
        with RemoteBasketFile(host=srv.host, port=srv.port, path="r2.bskt",
                              wire=None, timeout=2.0, retries=1,
                              backoff=0.01) as rf:
            with pytest.raises(CorruptBasketError) as ei:
                rf.read_basket_raw("a", 2)
    assert ei.value.branch == "a" and ei.value.index == 2


def test_tiered_cache_drop():
    c = TieredCache(mem_bytes=1 << 20)
    c.put_decoded(("k",), b"xyz")
    assert c.get_decoded(("k",)) == b"xyz"
    c.drop(("k",))
    assert c.get_decoded(("k",)) is None
    c.close()


# ---------------------------------------------------------------------------
# server degradation: shed, idle reap, drain
# ---------------------------------------------------------------------------

def _raw_conn(srv, timeout=5.0):
    s = socket.create_connection((srv.host, srv.port), timeout=timeout)
    return s, s.makefile("rb", buffering=0)


def test_server_sheds_when_saturated(tmp_path):
    _write_container(str(tmp_path / "l.bskt"))
    # one slow pread (0.6s) pins the single execution slot
    fdcache.set_fault_hook(pread_fault_hook(
        match=str(tmp_path), kind="delay", delay_s=0.6, max_fires=1))
    shed_before = _counter("server.shed")
    with BasketServer(str(tmp_path), workers=0, max_inflight=1,
                      admit_queue=0) as srv:
        srv.start()
        body = {"path": "l.bskt", "generation": None,
                "baskets": [["a", 0]], "wire": None}
        s1, r1 = _raw_conn(srv)
        s2, r2 = _raw_conn(srv)
        try:
            s1.sendall(P.pack_frame(P.REQ_READV, body))
            time.sleep(0.2)            # s1 is now inside the slow pread
            s2.sendall(P.pack_frame(P.REQ_READV, body))
            ftype, b2, _ = P.read_frame(r2)
            assert ftype == P.RESP_BUSY
            assert b2["error"] == "busy" and b2["retry_after_s"] > 0
            ftype, _, _ = P.read_frame(r1)     # slot holder still answers
            assert ftype == P.RESP_READV
            # shed client retries after the suggested delay and succeeds
            s2.sendall(P.pack_frame(P.REQ_READV, body))
            ftype, _, _ = P.read_frame(r2)
            assert ftype == P.RESP_READV
        finally:
            s1.close(), s2.close()
    assert _counter("server.shed") > shed_before


def test_client_retries_through_shedding(tmp_path):
    """Eight clients through a max_inflight=1 server: RESP_BUSY sheds are
    retried (jittered, server-suggested delay) until every read lands."""
    arrays = _write_container(str(tmp_path / "m.bskt"))
    with BasketServer(str(tmp_path), workers=0, max_inflight=1,
                      admit_queue=0) as srv:
        srv.start()
        errs = []

        def worker():
            try:
                with RemoteBasketFile(host=srv.host, port=srv.port,
                                      path="m.bskt", wire=None,
                                      timeout=5.0, busy_retries=40,
                                      backoff=0.01) as rf:
                    np.testing.assert_array_equal(rf.read_branch("a"),
                                                  arrays["a"])
            except Exception as e:     # surfaced via the errs list
                errs.append(e)

        ts = [threading.Thread(target=worker) for _ in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=60)
        assert errs == []


def test_idle_connections_reaped(tmp_path):
    _write_container(str(tmp_path / "i.bskt"))
    before = _counter("server.idle_closed")
    with BasketServer(str(tmp_path), workers=0, idle_timeout=0.3) as srv:
        srv.start()
        s, r = _raw_conn(srv)
        try:
            s.sendall(P.pack_frame(P.REQ_PING, {}))
            assert P.read_frame(r)[0] == P.RESP_PING
            time.sleep(0.8)            # exceed idle_timeout, then probe
            with pytest.raises((EOFError, P.ProtocolError, OSError)):
                P.read_frame(r)
        finally:
            s.close()
    assert _counter("server.idle_closed") > before


def test_drain_finishes_inflight_requests(tmp_path):
    _write_container(str(tmp_path / "dr.bskt"))
    fdcache.set_fault_hook(pread_fault_hook(
        match=str(tmp_path), kind="delay", delay_s=0.5, max_fires=1))
    srv = BasketServer(str(tmp_path), workers=0, drain_timeout=5.0)
    srv.start()
    results = []

    def reader():
        with RemoteBasketFile(host=srv.host, port=srv.port, path="dr.bskt",
                              wire=None, timeout=5.0, retries=0) as rf:
            results.append(rf.read_basket_raw("a", 0))

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.2)                    # the slow request is in flight
    srv.close()                        # drain: must NOT cut it off
    t.join(timeout=10)
    assert len(results) == 1 and len(results[0]) > 0


# ---------------------------------------------------------------------------
# protocol: RESP_BUSY frames
# ---------------------------------------------------------------------------

def test_resp_busy_roundtrip():
    import io
    frame = P.pack_frame(P.RESP_BUSY, {"error": "busy",
                                       "retry_after_s": 0.05})
    ftype, body, payload = P.read_frame(io.BytesIO(frame))
    assert ftype == P.RESP_BUSY
    assert body == {"error": "busy", "retry_after_s": 0.05}
    assert payload == b""


def test_server_busy_error_carries_retry_after():
    e = ServerBusy("server busy", retry_after=0.25)
    assert e.retry_after == 0.25
