"""Zero-copy data plane (PR 3): decompress-into roundtrips, golden
byte-identity regressions, shm slab transport, fd cache, streamed
checkpoint staging."""

import hashlib
import json
import logging
import os
import threading
import time

import numpy as np
import pytest

from repro.core import CompressionConfig
from repro.core.basket import (BasketMeta, basket_rows, join_baskets,
                               pack_basket, split_array, unpack_basket,
                               unpack_basket_into)
from repro.core.bfile import BasketFile, BasketWriter, write_arrays
from repro.io.engine import CompressionEngine

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")

PRECONDS = ["none", "shuffle4", "bitshuffle4", "bitshuffle2", "delta4",
            "zigzag8", "delta8+shuffle8", "delta4+bitshuffle4"]
ALGOS = [("none", 0), ("zlib", 5), ("lz4", 1), ("zstd", 3),
         ("repro-deflate", 5)]


# ---------------------------------------------------------------------------
# decompress-into: every precond × codec, exact/oversized/misaligned outputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,level", ALGOS)
@pytest.mark.parametrize("precond", PRECONDS)
def test_unpack_into_matrix(rng, algo, level, precond):
    for size in (0, 1, 7, 4096, 10_007):
        data = rng.integers(0, 256, size, dtype=np.uint8).tobytes()
        cfg = CompressionConfig(algo, level, precond)
        payload, meta = pack_basket(data, cfg)
        payload = bytes(payload)
        assert unpack_basket(payload, meta) == data
        # exact-size ndarray destination
        out = np.empty(size, np.uint8)
        assert unpack_basket_into(payload, meta, out) == size
        assert out.tobytes() == data
        # oversized + misaligned memoryview destination
        big = bytearray(size + 11)
        mv = memoryview(big)[3:3 + size]
        unpack_basket_into(payload, meta, mv)
        assert bytes(mv) == data
        assert bytes(big[:3]) == b"\x00" * 3 and bytes(big[3 + size:]) == b"\x00" * 8


def test_unpack_into_rejects_noncontiguous(rng):
    """A strided destination would make reshape(-1) copy and silently
    orphan the decode — must be rejected, not half-honored."""
    data = rng.integers(0, 256, 140, dtype=np.uint8).tobytes()[:140]
    payload, meta = pack_basket(data[:140], CompressionConfig("none", 0, "none"))
    out = np.zeros((70, 4), np.uint8)[:, :2]        # non-contiguous, 140 B
    with pytest.raises(ValueError, match="contiguous"):
        unpack_basket_into(bytes(payload), meta, out)
    from repro.core.precond import undo_precond_into
    with pytest.raises(ValueError, match="contiguous"):
        undo_precond_into("shuffle4", data, out, len(data))


def test_unpack_into_too_small_and_readonly(rng):
    data = rng.integers(0, 256, 1000, dtype=np.uint8).tobytes()
    payload, meta = pack_basket(data, CompressionConfig("zlib", 5, "shuffle4"))
    with pytest.raises(ValueError, match="too small"):
        unpack_basket_into(bytes(payload), meta, bytearray(999))
    with pytest.raises(ValueError, match="read-only"):
        unpack_basket_into(bytes(payload), meta, memoryview(bytes(1000)))


def test_unpack_into_verifies_checksum(rng):
    data = rng.integers(0, 256, 1000, dtype=np.uint8).tobytes()
    payload, meta = pack_basket(data, CompressionConfig("none", 0, "none"))
    bad = bytearray(bytes(payload))
    bad[500] ^= 0xFF
    with pytest.raises(ValueError, match="checksum"):
        unpack_basket_into(bytes(bad), meta, bytearray(1000))


@settings(max_examples=60, deadline=None)
@given(st.binary(min_size=0, max_size=5000),
       st.sampled_from(PRECONDS),
       st.sampled_from(ALGOS),
       st.integers(min_value=0, max_value=7))
def test_unpack_into_fuzz(data, precond, algo_level, pad):
    algo, level = algo_level
    payload, meta = pack_basket(data, CompressionConfig(algo, level, precond))
    big = bytearray(len(data) + pad + 5)
    mv = memoryview(big)[pad:pad + len(data)]
    unpack_basket_into(bytes(payload), meta, mv)
    assert bytes(mv) == data


# ---------------------------------------------------------------------------
# buffer-protocol pack path + zero-copy split
# ---------------------------------------------------------------------------

def test_pack_accepts_buffer_protocol(rng):
    data = rng.integers(0, 256, 9999, dtype=np.uint8).tobytes()
    arr = np.frombuffer(data, np.uint8)
    for algo, level in ALGOS:
        cfg = CompressionConfig(algo, level, "shuffle4")
        pb, mb = pack_basket(data, cfg)
        pv, mv_ = pack_basket(memoryview(arr), cfg)
        pa, ma = pack_basket(arr, cfg)
        assert bytes(pb) == bytes(pv) == bytes(pa)
        assert mb == mv_ == ma


def test_split_array_yields_views(rng):
    arr = rng.standard_normal((1000, 3)).astype(np.float32)
    parts = list(split_array(arr, target_basket_bytes=4096))
    assert len(parts) > 1
    assert sum(c for _, c, _ in parts) == 1000
    # chunks are zero-copy views of the source array's memory
    total = 0
    for start, count, buf in parts:
        assert isinstance(buf, memoryview)
        total += buf.nbytes
        assert bytes(buf) == arr[start:start + count].tobytes()
    assert total == arr.nbytes


def test_basket_rows_matches_split_array(rng):
    for shape, dt in [((1000, 3), np.float32), ((17,), np.int64),
                      ((5, 4096), np.uint8), ((100000,), np.float64)]:
        arr = np.zeros(shape, dt)
        for target in (4096, 1 << 16, 1 << 20):
            parts = list(split_array(arr, target))
            rows = basket_rows(shape, np.dtype(dt).itemsize, target)
            assert parts[0][1] == min(rows, shape[0])


def test_join_baskets_single_allocation_parity(rng):
    arr = rng.integers(0, 1000, (500, 4)).astype(np.int32)
    parts = [bytes(c) for _, _, c in split_array(arr, 2048)]
    out = join_baskets(parts, arr.dtype.str, arr.shape)
    np.testing.assert_array_equal(out, arr)
    with pytest.raises(ValueError):
        join_baskets(parts[:-1], arr.dtype.str, arr.shape)


# ---------------------------------------------------------------------------
# golden regressions: bytes written before this PR must be reproduced
# exactly, and must decode unchanged through the new read plane
# ---------------------------------------------------------------------------

def _golden_tree(rng):
    f = rng.standard_normal(40_000).astype(np.float32)
    off = np.cumsum(rng.integers(1, 9, 30_000)).astype(np.int64)
    tok = rng.integers(0, 255, 50_000).astype(np.uint8)
    return f, off, tok


def test_golden_container_byte_identical(tmp_path):
    """The exact write calls that produced tests/golden/container_pr2.bskt
    (PR 2 tree) must still produce those bytes."""
    man = json.load(open(os.path.join(GOLDEN, "container_manifest.json")))
    rng = np.random.default_rng(42)
    f, off, tok = _golden_tree(rng)
    p = str(tmp_path / "c.bskt")
    with BasketWriter(p) as w:
        w.write_branch("f", f, CompressionConfig("lz4", 1, "bitshuffle4"), 32 * 1024)
        w.write_branch("off", off, CompressionConfig("repro-deflate", 5, "delta8+shuffle8"), 64 * 1024)
        w.write_branch("tok", tok, CompressionConfig("lz4", 6, "none"), 16 * 1024)
        w.write_branch("scalar", np.float64(3.25), CompressionConfig("none", 0, "none"))
        w.write_branch("empty", np.zeros((0, 3), np.int32), CompressionConfig("lz4", 1, "shuffle4"))
    blob = open(p, "rb").read()
    assert hashlib.sha256(blob).hexdigest() == man["container_pr2.bskt"]
    assert blob == open(os.path.join(GOLDEN, "container_pr2.bskt"), "rb").read()


def test_golden_container_decodes(tmp_path):
    rng = np.random.default_rng(42)
    f, off, tok = _golden_tree(rng)
    with BasketFile(os.path.join(GOLDEN, "container_pr2.bskt")) as g:
        np.testing.assert_array_equal(g.read_branch("f"), f)
        np.testing.assert_array_equal(g.read_branch("off", workers=4), off)
        np.testing.assert_array_equal(g.read_branch("tok"), tok)
        assert g.read_branch("scalar") == np.float64(3.25)
        assert g.read_branch("empty").shape == (0, 3)
    with BasketFile(os.path.join(GOLDEN, "container_pr2.bskt"),
                    workers=2, prefetch=4) as g:
        np.testing.assert_array_equal(g.read_branch("off"), off)


def test_golden_ckpt_byte_identical_all_modes(tmp_path):
    """producers=1 checkpoint bytes: gather and stream staging, serial and
    parallel workers, must all equal the PR 2 golden."""
    from repro.checkpoint import save_pytree
    man = json.load(open(os.path.join(GOLDEN, "container_manifest.json")))
    rng = np.random.default_rng(42)
    _golden_tree(rng)   # advance the stream exactly as the generator did
    tree = {"w": rng.standard_normal((300, 257)).astype(np.float32),
            "emb": {"table": rng.integers(0, 1 << 20, 70_000).astype(np.int64)},
            "step": np.int64(123)}
    for staging in ("gather", "stream"):
        for workers in (0, 4):
            p = str(tmp_path / f"{staging}{workers}.bskt")
            save_pytree(p, tree, profile="analysis", workers=workers,
                        staging=staging)
            h = hashlib.sha256(open(p, "rb").read()).hexdigest()
            assert h == man["ckpt_pr2.bskt"], (staging, workers)


def test_golden_codec_blobs_decode_into():
    """The PR-1-era codec blobs under tests/golden/ must decode through the
    decompress-into path as well."""
    from golden_payloads import payloads
    man = json.load(open(os.path.join(GOLDEN, "manifest.json")))
    pay = payloads()
    checked = 0
    for name, meta in man.items():
        if meta.get("kind") not in ("lz4", "codec") or meta.get("dict") \
                or "dict" in name:
            continue
        blob = open(os.path.join(GOLDEN, name + ".bin"), "rb").read()
        data = pay[meta["payload"]]
        algo = meta.get("algo", "lz4")
        precond = meta.get("precond", "none")
        from repro.core.precond import apply_precond
        stored = apply_precond(precond, data) if precond != "none" else data
        bm = BasketMeta(algo=algo, level=meta.get("level", 1), precond=precond,
                        orig_len=len(data), stored_len=len(stored),
                        comp_len=len(blob),
                        checksum=__import__("zlib").adler32(data) & 0xFFFFFFFF)
        out = bytearray(len(data) + 3)
        mv = memoryview(out)[1:1 + len(data)]
        unpack_basket_into(blob, bm, mv)
        assert bytes(mv) == data
        checked += 1
    assert checked >= 5


# ---------------------------------------------------------------------------
# fd cache
# ---------------------------------------------------------------------------

def test_fdcache_pread_and_replace(tmp_path):
    from repro.io import fdcache
    p = str(tmp_path / "f.bin")
    open(p, "wb").write(b"A" * 100)
    assert fdcache.pread(p, 10, 5) == b"AAAAA"
    # replace the file (what BasketWriter's atomic commit does): the cached
    # fd points at the unlinked inode and must be revalidated
    tmp = p + ".tmp"
    open(tmp, "wb").write(b"B" * 100)
    os.replace(tmp, p)
    assert fdcache.pread(p, 10, 5) == b"BBBBB"
    with pytest.raises(EOFError):
        fdcache.pread(p, 98, 5)
    fdcache.invalidate(p)


def test_basketfile_close_releases_fd(tmp_path, rng):
    """close() must drop this path's cached fd so a deleted container's
    inode isn't pinned until LRU eviction."""
    from repro.io import fdcache
    p = str(tmp_path / "rel.bskt")
    write_arrays(p, {"x": rng.standard_normal(1000).astype(np.float32)})
    with BasketFile(p) as f:
        f.read_branch("x")
        with fdcache._lock:
            assert p in fdcache._entries
    with fdcache._lock:
        assert p not in fdcache._entries


def test_fdcache_checkout_survives_invalidate(tmp_path):
    """An fd checked out for a read must not be closed under the reader by
    a concurrent invalidate (refcounted retirement)."""
    from repro.io import fdcache
    p = str(tmp_path / "race.bin")
    open(p, "wb").write(b"X" * 64)
    e = fdcache._checkout(p)
    fdcache.invalidate(p)           # marks dead; must NOT close yet
    assert e.dead and e.refs == 1
    assert os.pread(e.fd, 4, 0) == b"XXXX"   # fd still alive for the reader
    fdcache._checkin(e)             # last reader closes
    assert e.refs == 0


def test_fdcache_concurrent_reads(tmp_path):
    from repro.io import fdcache
    p = str(tmp_path / "c.bin")
    data = bytes(range(256)) * 64
    open(p, "wb").write(data)
    errs = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(200):
                off = int(rng.integers(0, len(data) - 32))
                assert fdcache.pread(p, off, 32) == data[off:off + 32]
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs


def test_parallel_unpack_uses_single_open(tmp_path, rng):
    """Behavioral check: reads work when the path is opened once and
    pread-shared across worker threads."""
    arr = rng.standard_normal(200_000).astype(np.float32)
    p = str(tmp_path / "b.bskt")
    write_arrays(p, {"x": arr}, lambda n, a: CompressionConfig("zlib", 1),
                 target_basket_bytes=32 * 1024)
    with BasketFile(p, workers=8) as f:
        np.testing.assert_array_equal(f.read_branch("x"), arr)


# ---------------------------------------------------------------------------
# engine: drain semantics, shm transport
# ---------------------------------------------------------------------------

def _slow_fail_chunks():
    yield 0, 1, b"\x00" * 100_000
    yield 1, 1, b"\x01" * 100_000
    yield 2, 1, b"\x02" * 100_000
    yield 3, 1, b"\x03" * 100_000


def test_map_ordered_drains_failures_on_close(caplog):
    """Closing the pack_stream generator early must drain (and log) a
    worker that fails after the consumer stopped listening — not abandon
    it silently."""
    bad_cfg = CompressionConfig("zlib", 5)

    class Boom(Exception):
        pass

    def chunks():
        yield 0, 1, b"ok" * 50_000
        yield 1, 1, b"ok" * 50_000
        yield 2, 1, b"ok" * 50_000

    with CompressionEngine(workers=2, inline_bytes=0) as eng:
        import repro.io.engine as engine_mod
        orig = engine_mod._pack_task

        calls = {"n": 0}

        def flaky(raw, fields, start, count, tp=None):
            calls["n"] += 1
            if start >= 1:
                time.sleep(0.05)
                raise Boom("worker died late")
            return orig(raw, fields, start, count, tp)

        engine_mod._pack_task = flaky
        try:
            stream = eng.pack_stream(chunks(), bad_cfg)
            with caplog.at_level(logging.WARNING, logger="repro.io"):
                first = next(stream)     # schedules the rest in flight
                assert first[0] == 0
                stream.close()           # consumer walks away
        finally:
            engine_mod._pack_task = orig
    assert any("teardown" in r.message for r in caplog.records)


def test_shm_transport_byte_identity(tmp_path, rng):
    """lz4 routes to the process pool; slab transport, pickle fallback and
    serial must emit identical files."""
    arr = rng.standard_normal(60_000).astype(np.float32)
    cfg = CompressionConfig("lz4", 1, "shuffle4")
    blobs = {}
    for tag, (workers, shm) in {"serial": (0, False), "shm": (4, "auto"),
                                "pickle": (4, False)}.items():
        p = str(tmp_path / f"{tag}.bskt")
        with CompressionEngine(workers, shm=shm, inline_bytes=0) as eng:
            with BasketWriter(p, engine=eng) as w:
                w.write_branch("x", arr, cfg, 16 * 1024)
        blobs[tag] = open(p, "rb").read()
    assert blobs["serial"] == blobs["shm"] == blobs["pickle"]


def test_shm_identity_codec_roundtrip(tmp_path, rng):
    """level-0 'none' through the slab transport: payload aliases the slab
    (the `payload is raw` shortcut)."""
    arr = rng.integers(0, 255, 300_000).astype(np.uint8)
    p = str(tmp_path / "n.bskt")
    cfg = CompressionConfig("repro-deflate", 0, "none")   # routes pure-python
    with CompressionEngine(2, shm="auto", inline_bytes=0) as eng:
        with BasketWriter(p, engine=eng) as w:
            w.write_branch("x", arr, cfg, 64 * 1024)
    with BasketFile(p) as f:
        np.testing.assert_array_equal(f.read_branch("x"), arr)


def test_shm_unpack_processes(tmp_path, rng):
    arr = np.cumsum(rng.integers(1, 7, 150_000)).astype(np.int64)
    p = str(tmp_path / "u.bskt")
    write_arrays(p, {"x": arr}, lambda n, a: CompressionConfig("lz4", 1, "delta8"),
                 target_basket_bytes=64 * 1024)
    from repro.io.prefetch import PrefetchReader
    with CompressionEngine(2, shm="auto", unpack_processes=True) as eng:
        with BasketFile(p) as f:
            r = PrefetchReader(f, "x", engine=eng, ahead=2)
            np.testing.assert_array_equal(r.read_all(), arr)
            np.testing.assert_array_equal(r.read_entries(1000, 90_000),
                                          arr[1000:90_000])
            r.close()


def test_slab_pool_bounds_and_reuse():
    from repro.io import shmem
    if not shmem.available():
        pytest.skip("no shared memory on this platform")
    pool = shmem.SlabPool(slab_bytes=4096, max_outstanding=2)
    a = pool.try_acquire(100)
    b = pool.try_acquire(100)
    assert a is not None and b is not None
    assert pool.try_acquire(100) is None        # cap reached -> fallback
    pool.release(a)
    c = pool.try_acquire(100)
    assert c is a                               # recycled, not remapped
    pool.release(b)
    pool.release(c)
    pool.close()
    with pytest.raises(RuntimeError):
        pool.acquire(10)


# ---------------------------------------------------------------------------
# streamed checkpoint staging
# ---------------------------------------------------------------------------

def _state(rng, mb=2):
    n = (mb << 20) // 8
    return {
        "w": rng.standard_normal(n // 2).astype(np.float32).reshape(-1, 64),
        "opt": {"m": rng.standard_normal(n // 2).astype(np.float32)},
        "off": np.cumsum(rng.integers(1, 9, n // 4)).astype(np.int64),
        "step": np.int64(77),
    }


def test_stream_vs_gather_byte_identity_host(tmp_path, rng):
    from repro.checkpoint import save_pytree
    tree = _state(rng)
    pa, pb = str(tmp_path / "a"), str(tmp_path / "b")
    save_pytree(pa, tree, staging="gather", workers=0)
    save_pytree(pb, tree, staging="stream", workers=4)
    assert open(pa, "rb").read() == open(pb, "rb").read()


def test_stream_vs_gather_byte_identity_device(tmp_path, rng):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.checkpoint import save_pytree
    tree = {"a": jnp.asarray(rng.standard_normal((4000, 100)).astype(np.float32)),
            "b": jnp.asarray(rng.standard_normal(120_000).astype(np.float32)).astype(jnp.bfloat16),
            "c": jnp.asarray(np.cumsum(rng.integers(1, 5, 300_000)).astype(np.int64)),
            "s": jnp.int32(3)}
    pa, pb = str(tmp_path / "a"), str(tmp_path / "b")
    save_pytree(pa, tree, staging="gather")
    save_pytree(pb, tree, staging="stream")
    assert open(pa, "rb").read() == open(pb, "rb").read()


def test_stream_roundtrip_with_template_and_manager(tmp_path, rng):
    jax = pytest.importorskip("jax")
    from repro.checkpoint import CheckpointManager
    tree = _state(rng, mb=1)
    mgr = CheckpointManager(str(tmp_path), keep=2, workers=2)
    mgr.save(1, tree, wait=True)
    mgr.save(2, tree, wait=True, snapshot=True)   # old gather-first path
    assert mgr.latest_step() == 2
    template = {"w": None if False else tree["w"], "opt": {"m": tree["opt"]["m"]},
                "off": tree["off"], "step": tree["step"]}
    got, meta = mgr.restore(2, template=template)
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])
    np.testing.assert_array_equal(np.asarray(got["off"]), tree["off"])
    # both saves wrote identical data bytes (stream == snapshot+stream)
    d1 = open(os.path.join(str(tmp_path), "ckpt-00000001.bskt"), "rb").read()
    d2 = open(os.path.join(str(tmp_path), "ckpt-00000002.bskt"), "rb").read()
    assert d1 == d2


def test_manager_gc_with_fdcache(tmp_path, rng):
    from repro.checkpoint import CheckpointManager
    tree = {"x": rng.standard_normal(10_000).astype(np.float32)}
    mgr = CheckpointManager(str(tmp_path), keep=1)
    for s in (1, 2, 3):
        mgr.save(s, tree, wait=True)
        mgr.restore(s)          # populates the fd cache for the data file
    assert mgr.steps() == [3]
    assert len([f for f in os.listdir(str(tmp_path)) if f.endswith(".bskt")]) == 1


def test_load_pytree_shardings_device_put_per_branch(tmp_path, rng):
    jax = pytest.importorskip("jax")
    from repro.checkpoint import load_pytree, save_pytree
    tree = {"w": rng.standard_normal((64, 32)).astype(np.float32)}
    p = str(tmp_path / "s.bskt")
    save_pytree(p, tree)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    got, _ = load_pytree(p, template={"w": None if False else tree["w"]},
                         shardings={"w": sh})
    assert isinstance(got["w"], jax.Array)
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])


# ---------------------------------------------------------------------------
# scatter reads
# ---------------------------------------------------------------------------

def test_read_entries_scatter_parity(tmp_path, rng):
    arr = np.arange(40_000, dtype=np.int64).reshape(-1, 2)
    p = str(tmp_path / "e.bskt")
    write_arrays(p, {"x": arr}, lambda n, a: CompressionConfig("zlib", 3, "shuffle8"),
                 target_basket_bytes=8192)
    with BasketFile(p) as f:
        for a, b in [(0, 5), (1234, 5678), (0, 20_000), (19_990, 20_000)]:
            np.testing.assert_array_equal(f.read_entries("x", a, b), arr[a:b])
    with BasketFile(p, workers=2, prefetch=3) as f:
        for a, b in [(3, 9), (100, 15_000), (0, 20_000)]:
            np.testing.assert_array_equal(f.read_entries("x", a, b), arr[a:b])
        np.testing.assert_array_equal(f.read_branch("x"), arr)
