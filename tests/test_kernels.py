"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), swept over
shapes/dtypes, plus the host<->device agreement loop: the numpy
preconditioners in repro.core.precond must produce byte-identical output
to the device kernels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precond as hostp
from repro.kernels import ops, ref
from repro.kernels import bitshuffle as bs, byteshuffle as bys, delta as dl, qpack as qp

DTYPES = [jnp.uint8, jnp.int8, jnp.int32, jnp.float32, jnp.float16, jnp.bfloat16]
SIZES = [8, 64, 1000, 4096, 8192 + 64]


def _bytes_of(x):
    return np.frombuffer(np.asarray(x).tobytes(), np.uint8)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
def test_bitshuffle_matches_ref_and_roundtrips(dtype, n, rng):
    if n % 8:
        n -= n % 8
    x = jnp.asarray(rng.integers(0, 200, n)).astype(dtype)
    item = x.dtype.itemsize
    y = ops.bitshuffle_bytes(x, interpret=True)
    mat = _bytes_of(x).reshape(-1, item)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(ref.bitshuffle_ref(jnp.asarray(mat))))
    back = ops.bitunshuffle_bytes(y, x.dtype, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
def test_byteshuffle_matches_ref_and_roundtrips(dtype, n, rng):
    x = jnp.asarray(rng.integers(0, 200, n)).astype(dtype)
    item = x.dtype.itemsize
    y = ops.byteshuffle_bytes(x, interpret=True)
    mat = _bytes_of(x).reshape(-1, item)
    np.testing.assert_array_equal(np.asarray(y), mat.T)
    back = ops.byteunshuffle_bytes(y, x.dtype, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("n", [16, 1000, 4096, 10000])
def test_delta_matches_ref_and_roundtrips(n, rng):
    x = jnp.asarray(np.cumsum(rng.integers(1, 9, n)).astype(np.uint32))
    d = ops.delta_u32(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(ref.delta_ref(x)))
    back = ops.undelta_u32(d, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("shape", [(8, 128), (256, 384), (1000, 64)])
def test_qpack_matches_ref(shape, rng):
    g = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    q, s, orig = ops.quantize_int8(g, interpret=True)
    qr, sr = ref.qpack_ref(g)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    out = ops.dequantize_int8(q, s, orig, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.qunpack_ref(qr, sr)), rtol=1e-6)
    # quantization error bounded by scale/2 per element
    err = np.abs(np.asarray(out) - np.asarray(g))
    bound = np.asarray(sr) * 0.5 + 1e-7
    assert (err <= bound + 1e-6).all()


def test_qpack_zero_rows():
    g = jnp.zeros((4, 64), jnp.float32)
    q, s, orig = ops.quantize_int8(g, interpret=True)
    assert np.all(np.asarray(q) == 0)
    out = ops.dequantize_int8(q, s, orig, interpret=True)
    assert np.all(np.asarray(out) == 0)


# ---------------------------------------------------------------------------
# host (numpy precond) <-> device (pallas) agreement — closes the loop so a
# tensor preconditioned on device decompresses with the host pipeline.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 4096])
def test_host_device_bitshuffle_agree(n, rng):
    arr = rng.standard_normal(n).astype(np.float32)
    host_bytes = hostp.apply_precond("bitshuffle4", arr.tobytes())
    dev = ops.bitshuffle_bytes(jnp.asarray(arr), interpret=True)
    assert np.asarray(dev).tobytes() == host_bytes


@pytest.mark.parametrize("n", [64, 4096])
def test_host_device_byteshuffle_agree(n, rng):
    arr = rng.integers(0, 1 << 30, n).astype(np.uint32)
    host_bytes = hostp.apply_precond("shuffle4", arr.tobytes())
    dev = ops.byteshuffle_bytes(jnp.asarray(arr), interpret=True)
    assert np.asarray(dev).tobytes() == host_bytes


def test_blockspec_grid_paths(rng):
    """Multi-block grids agree with single-block (BlockSpec indexing)."""
    x = jnp.asarray(rng.integers(0, 255, (16384, 4)), dtype=jnp.uint8)
    one = bs.bitshuffle(x, block_n=16384, interpret=True)
    many = bs.bitshuffle(x, block_n=2048, interpret=True)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(many))
    y1 = bys.byteshuffle(x, block_n=16384, interpret=True)
    y2 = bys.byteshuffle(x, block_n=4096, interpret=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
