"""Distributed lowering invariants, run in subprocesses so the fake-device
XLA flag never leaks into this process (smoke tests must see 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_tiny_train_step_sharded_end_to_end():
    """A reduced arch trains ONE REAL step on a 4x2 mesh and the loss is
    finite — exercising param/opt/batch shardings with actual data."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced
        from repro.models import Model
        from repro.train import init_train_state, make_train_step
        from repro.parallel import ParallelismConfig, param_shardings, opt_shardings, batch_shardings
        from repro.parallel.actctx import activation_context
        from repro.train.step import TrainState

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("qwen3-8b"))
        model = Model(cfg)
        pcfg = ParallelismConfig(zero3=True)
        state = init_train_state(model, jax.random.key(0))
        psh = param_shardings(model, mesh, pcfg)
        osh = opt_shardings(model, mesh, pcfg)
        rep = NamedSharding(mesh, P())
        ssh = TrainState(params=psh, opt={"m": osh, "v": osh, "count": rep}, step=rep, err=None)
        tok = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)
        batch = {"tokens": tok, "targets": jnp.roll(tok, -1, 1)}
        bsh = batch_shardings(mesh, batch)
        step = make_train_step(model, peak_lr=1e-3)
        with mesh, activation_context(mesh):
            f = jax.jit(step, in_shardings=(ssh, bsh), out_shardings=(ssh, rep), donate_argnums=(0,))
            state2, m = f(state, batch)
        assert np.isfinite(float(m["loss"]))
        print("LOSS", float(m["loss"]))
    """)
    assert "LOSS" in out


@pytest.mark.slow
def test_decode_cache_time_sharding_flash_pattern():
    """Time-sharded KV cache decode emits only small all-reduces (the
    flash-decode pattern) and never gathers the cache."""
    out = _run("""
        import jax, jax.numpy as jnp, re, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, ShapeSpec
        from repro.models import Model
        from repro.parallel import ParallelismConfig, param_shardings, cache_shardings
        from repro.parallel.actctx import activation_context
        import dataclasses

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(reduced(get_config("qwen3-8b")), n_kv_heads=2, n_heads=4)
        # kv=2 < model=4 -> time sharding kicks in
        model = Model(cfg)
        pcfg = ParallelismConfig()
        params = model.abstract(dtype=jnp.bfloat16)
        psh = param_shardings(model, mesh, pcfg)
        cache = model.init_cache(8, 64, abstract=True)
        csh = cache_shardings(model, mesh, pcfg, cache)
        # verify the time dim got the model axis
        leaf_sh = jax.tree.leaves(csh)[0]
        assert "model" in str(leaf_sh.spec[2]), leaf_sh.spec
        tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
        rep = NamedSharding(mesh, P())
        with mesh, activation_context(mesh):
            c = jax.jit(model.decode_step,
                        in_shardings=(psh, csh, NamedSharding(mesh, P("data", None)), rep),
                        out_shardings=(NamedSharding(mesh, P("data", None)), csh),
                        donate_argnums=(1,)).lower(
                params, cache, tok, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        txt = c.as_text()
        ags = [l for l in txt.splitlines() if "all-gather(" in l and "bf16" in l]
        # no all-gather of a (*, 64, kv, dh)-sized cache tensor
        big = [l for l in ags if ",64," in l.split("all-gather")[0]]
        print("BIGGATHERS", len(big))
    """)
    assert "BIGGATHERS 0" in out


@pytest.mark.slow
def test_multipod_mesh_lowering():
    """The 3-axis (pod, data, model) mesh lowers a reduced train step —
    the same code path the 512-chip dry-run uses."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced, SHAPES, ShapeSpec
        from repro.launch.specs import build_cell, parallelism_for
        from repro.parallel.actctx import activation_context
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = reduced(get_config("gemma2-9b"))
        shape = ShapeSpec("t", 64, 8, "train")
        cell = build_cell(cfg, shape, mesh, parallelism_for(cfg))
        with mesh, activation_context(mesh):
            c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings,
                        donate_argnums=cell.donate_argnums).lower(*cell.args).compile()
        print("MEM", c.memory_analysis().temp_size_in_bytes > 0)
    """)
    assert "MEM True" in out


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    """Save on a 2-device mesh, restore onto a 8-device mesh (re-shard)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_pytree, load_pytree
        m2 = jax.make_mesh((2, 1), ("data", "model"))
        tree = {"w": jax.device_put(jnp.arange(128.0).reshape(16, 8),
                                    NamedSharding(m2, P("data", None)))}
        td = tempfile.mkdtemp()
        save_pytree(os.path.join(td, "c.bskt"), tree)
        m8 = jax.make_mesh((4, 2), ("data", "model"))
        sh = {"w": NamedSharding(m8, P("data", "model"))}
        got, _ = load_pytree(os.path.join(td, "c.bskt"), template=tree, shardings=sh)
        assert got["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
        print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out
