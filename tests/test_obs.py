"""repro.obs: registry semantics, snapshot folding, tracing, STATS verb.

What's pinned here:

* counters are exact under thread contention (per-metric locks);
* histogram bucket edges (the fixed log2 layout every snapshot shares);
* ``snapshot(reset=True)`` is a *delta* — merging two consecutive deltas
  equals one total (the worker-folding idempotence property);
* ``CompressionEngine`` pack telemetry survives all three transports
  (thread pool, process pool over pickle, process pool over shm slabs)
  via :meth:`collect_obs`;
* the Chrome trace export byte-layout (golden file) and span semantics;
* the RBSP ``STATS`` verb round-trip: generation stamp, server stats,
  per-branch read counters, canonical-JSON metrics, trace drain;
* the ``REPRO_OBS`` off path costs a no-op instrument, and a loose
  on-vs-off overhead smoke (the tight 2% gate is benchmarks/fig_obs.py,
  which measures best-of-reps; here we only catch gross regressions).
"""

from __future__ import annotations

import json
import os
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import metrics as M
from repro.obs import trace as T

GOLDEN_TRACE = os.path.join(os.path.dirname(__file__), "golden",
                            "trace_pr6.json")


@pytest.fixture
def reg():
    return M.Registry()


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------

def test_key_roundtrip():
    key = M.format_key("server.reads", {"path": "f.bskt", "branch": "x"})
    assert key == "server.reads{branch=x,path=f.bskt}"   # sorted labels
    name, labels = M.parse_key(key)
    assert name == "server.reads"
    assert labels == {"branch": "x", "path": "f.bskt"}
    assert M.parse_key("plain") == ("plain", {})
    assert M.format_key("plain") == "plain"


def test_kind_mismatch_raises(reg):
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


# ---------------------------------------------------------------------------
# counters / gauges under contention
# ---------------------------------------------------------------------------

def test_concurrent_counter_exact(reg):
    c = reg.counter("hits", worker="t")
    threads = [threading.Thread(target=lambda: [c.inc() for _ in range(10_000)])
               for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 80_000
    assert reg.snapshot()["counters"]["hits{worker=t}"] == 80_000


def test_gauge_inc_dec(reg):
    g = reg.gauge("depth")
    g.set(5)
    g.inc(2)
    g.dec()
    assert g.value == 6
    snap = reg.snapshot(reset=True)
    assert snap["gauges"]["depth"] == 6
    # gauges are levels, not deltas: reset keeps them
    assert reg.snapshot()["gauges"]["depth"] == 6


# ---------------------------------------------------------------------------
# histogram bucket layout
# ---------------------------------------------------------------------------

def test_bucket_edges():
    assert M.bucket_index(0.0) == 0
    assert M.bucket_index(-3.0) == 0
    assert M.bucket_index(2.0 ** -33) == 0       # underflow
    assert M.bucket_index(2.0 ** -32) == 1       # first finite bucket
    assert M.bucket_index(1.0) == 33
    assert M.bucket_index(1.999) == 33
    assert M.bucket_index(2.0) == 34
    assert M.bucket_index(2.0 ** 62) == 95
    assert M.bucket_index(2.0 ** 63) == 95       # overflow clamps
    assert M.bucket_index(float("1e300")) == 95
    lo, hi = M.bucket_bounds(33)
    assert (lo, hi) == (1.0, 2.0)
    assert M.bucket_bounds(0)[0] == 0.0
    # every positive double lands in the bucket whose bounds contain it
    for v in (1e-9, 0.37, 1.0, 7.0, 1e6):
        i = M.bucket_index(v)
        lo, hi = M.bucket_bounds(i)
        assert lo <= v < hi or i in (0, M.N_BUCKETS - 1)


def test_histogram_observe_and_quantile(reg):
    h = reg.histogram("lat_s")
    for v in [0.001] * 98 + [4.0] * 2:
        h.observe(v)
    assert h.count == 100
    assert h.sum == pytest.approx(0.098 + 8.0)
    p50, p99 = h.quantile(0.50), h.quantile(0.99)
    lo, hi = M.bucket_bounds(M.bucket_index(0.001))
    assert lo <= p50 <= hi
    assert p99 >= 2.0                            # lands in the 4.0 bucket
    assert h.quantile(0.0) >= 0.0
    assert M.quantile_from_buckets({}, 0.5) == 0.0


def test_histogram_timer(reg):
    h = reg.histogram("t_s")
    with h.time():
        pass
    assert h.count == 1 and h.sum >= 0.0


# ---------------------------------------------------------------------------
# snapshot / merge: the worker-folding protocol
# ---------------------------------------------------------------------------

def test_snapshot_reset_is_delta_and_merge_is_idempotent(reg):
    parent = M.Registry()
    reg.counter("n").inc(7)
    reg.histogram("h").observe(1.5)
    d1 = reg.snapshot(reset=True)
    reg.counter("n").inc(3)
    d2 = reg.snapshot(reset=True)
    d3 = reg.snapshot(reset=True)                # nothing new
    for d in (d1, d2, d3):
        parent.merge(d)
    snap = parent.snapshot()
    assert snap["counters"]["n"] == 10           # 7 + 3, nothing twice
    assert snap["hists"]["h"]["count"] == 1
    assert d3["counters"]["n"] == 0


def test_merge_through_json(reg):
    """Snapshots survive the wire (canonical JSON) byte-exactly."""
    reg.counter("c", a="1").inc(5)
    reg.gauge("g").set(2.5)
    reg.histogram("h").observe(0.25)
    snap = json.loads(json.dumps(reg.snapshot(), sort_keys=True))
    other = M.Registry()
    other.merge(snap)
    assert other.snapshot() == reg.snapshot()


# ---------------------------------------------------------------------------
# enable gate
# ---------------------------------------------------------------------------

def test_disabled_returns_null_instrument():
    prev = obs.set_enabled(False)
    try:
        assert obs.counter("nope") is M.NULL
        assert obs.gauge("nope") is M.NULL
        assert obs.histogram("nope") is M.NULL
        obs.counter("nope").inc()                # all no-ops
        with obs.histogram("nope").time():
            pass
        with obs.trace.span("nope"):
            pass
    finally:
        obs.set_enabled(prev)
    assert obs.enabled() == prev


# ---------------------------------------------------------------------------
# engine transports: thread pool, process+pickle, process+shm
# ---------------------------------------------------------------------------

def _pack_some(algo: str, **engine_kw):
    """Pack a >inline_bytes buffer through an engine and return the delta
    of this process's registry counters for that algo."""
    from repro.core.codec import CompressionConfig
    from repro.io.engine import CompressionEngine

    raw = np.arange(32_768, dtype=np.int64).tobytes()    # 256 KiB
    key = M.format_key("engine.pack.bytes_in", {"algo": algo})
    before = obs.snapshot()["counters"].get(key, 0)
    with CompressionEngine(**engine_kw) as eng:
        cfg = CompressionConfig(algo, 1, "none")
        out = list(eng.pack_stream([(0, 32_768, raw)], cfg))
        assert len(out) == 1
        # close() folds process-pool workers' deltas via collect_obs()
    return obs.snapshot()["counters"].get(key, 0) - before


def test_engine_obs_thread_transport():
    assert _pack_some("zlib", workers=2) >= 262_144


def test_engine_obs_process_pickle_transport():
    assert _pack_some("repro-deflate", workers=1, shm=False) >= 262_144


def test_engine_obs_process_shm_transport():
    # shm="auto" uses the slab transport where available and falls back to
    # pickle otherwise — the telemetry must fold back either way
    assert _pack_some("repro-deflate", workers=1, shm="auto") >= 262_144


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_span_records_event_and_error():
    T.clear()
    with T.span("ok.op", cat="test", k=1):
        pass
    with pytest.raises(ValueError):
        with T.span("bad.op", cat="test"):
            raise ValueError("boom")
    evs = {e["name"]: e for e in T.drain()}
    assert evs["ok.op"]["ph"] == "X" and evs["ok.op"]["args"] == {"k": 1}
    assert evs["ok.op"]["dur"] >= 0.0
    assert evs["bad.op"]["args"]["error"] == "ValueError"
    assert T.drain() == []                       # drain popped everything


def test_ring_is_bounded():
    T.clear()
    T.set_capacity(8)
    try:
        for i in range(20):
            T.instant(f"e{i}")
        names = [e["name"] for e in T.events()]
        assert names == [f"e{i}" for i in range(12, 20)]   # newest kept
    finally:
        T.set_capacity(65536)
        T.clear()


def test_chrome_trace_golden(tmp_path):
    """The export byte-layout is pinned: a fixed synthetic event list must
    serialize identically forever (Perfetto compatibility contract)."""
    evs = [
        {"name": "ckpt.save", "cat": "ckpt", "ph": "X", "ts": 10.0,
         "dur": 120.5, "pid": 4242, "tid": 101,
         "args": {"path": "a.bskt", "branches": 3}},
        {"name": "server.pread", "cat": "server", "ph": "X", "ts": 40.0,
         "dur": 15.25, "pid": 4242, "tid": 102},
        {"name": "mark", "cat": "repro", "ph": "i", "s": "t", "ts": 200.0,
         "pid": 4242, "tid": 101},
    ]
    out = str(tmp_path / "trace.json")
    n = T.export_chrome(out, events=evs)
    assert n == 3
    got = open(out).read()
    doc = json.loads(got)
    assert [e["ph"] for e in doc["traceEvents"]] == ["M", "M", "X", "X", "i"]
    assert doc["displayTimeUnit"] == "ms"
    if not os.path.exists(GOLDEN_TRACE):         # first run: write golden
        with open(GOLDEN_TRACE, "w") as f:
            f.write(got)
    assert got == open(GOLDEN_TRACE).read(), (
        "Chrome trace export drifted from tests/golden/trace_pr6.json; "
        "if the change is intentional, delete the golden and rerun")


def test_export_drains_live_ring(tmp_path):
    T.clear()
    with T.span("live.op"):
        pass
    out = str(tmp_path / "live.json")
    assert T.export_chrome(out) == 1
    assert T.events() == []                      # export consumed the ring
    doc = json.loads(open(out).read())
    assert any(e["name"] == "live.op" for e in doc["traceEvents"])


# ---------------------------------------------------------------------------
# RBSP STATS round-trip
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stats_server(tmp_path_factory):
    from repro.core.bfile import write_arrays
    from repro.core.codec import CompressionConfig
    from repro.remote import BasketServer

    td = tmp_path_factory.mktemp("obs_remote")
    rng = np.random.default_rng(3)
    write_arrays(str(td / "f.bskt"),
                 {"energy": rng.standard_normal(60_000).astype(np.float32),
                  "pid": rng.integers(0, 9, 60_000).astype(np.int32)},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1, "shuffle"),
                 target_basket_bytes=16 * 1024)
    with BasketServer(str(td), workers=2) as srv:
        srv.start()
        yield srv


def test_stats_verb_roundtrip(stats_server):
    from repro.remote import RemoteBasketFile
    from repro.remote.client import fetch_stats

    srv = stats_server
    with RemoteBasketFile(srv.url("f.bskt"), wire=None) as rf:
        rf.read_branch("energy")
        rf.read_branch("energy")
        rf.read_branch("pid")
        body = rf.server_stats()
    assert body["pid"] > 0 and body["uptime_s"] >= 0.0
    assert body["server"]["requests"] >= 1
    gen0 = body["gen"]

    body2 = fetch_stats(srv.host, srv.port)
    assert body2["gen"] > gen0                   # generation-stamped
    counters = body2["metrics"]["counters"]
    reads = {M.parse_key(k)[1]["branch"]: v for k, v in counters.items()
             if M.parse_key(k)[0] == "server.reads"}
    assert reads.get("energy", 0) >= 2 * reads.get("pid", 1)
    hists = body2["metrics"]["hists"]
    readv = hists.get("server.request_s{verb=readv}")
    assert readv and readv["count"] >= 1
    # the whole body is canonical-JSON serializable (the wire contract)
    json.dumps(body2, sort_keys=True)


def test_stats_verb_trace_drain(stats_server):
    from repro.remote.client import fetch_stats

    srv = stats_server
    with T.span("marker.op", cat="test"):
        pass
    body = fetch_stats(srv.host, srv.port, trace=True)
    names = {e["name"] for e in body["trace_events"]}
    assert "marker.op" in names                  # loopback: shared ring
    body2 = fetch_stats(srv.host, srv.port, trace=True)
    # each event crosses the wire exactly once (drain, not copy)
    assert "marker.op" not in {e["name"] for e in body2.get("trace_events",
                                                            [])}


def test_stats_errors_labeled_by_verb(stats_server):
    import socket

    from repro.remote import protocol as P

    srv = stats_server
    key = "server.errors{verb=readv}"
    before = obs.snapshot()["counters"].get(key, 0)
    with socket.create_connection((srv.host, srv.port), timeout=5) as s:
        rfile = s.makefile("rb")
        s.sendall(P.pack_frame(P.REQ_READV, {"path": "no/such.bskt"}))
        t, _body, _payload = P.read_frame(rfile)
        assert t == P.RESP_ERROR
    assert obs.snapshot()["counters"].get(key, 0) == before + 1


# ---------------------------------------------------------------------------
# overhead smoke (loose; the tight 2% gate is benchmarks/fig_obs.py)
# ---------------------------------------------------------------------------

def test_overhead_smoke(tmp_path):
    import time

    from repro.checkpoint.manager import load_pytree, save_pytree

    tree = {"w": np.arange(200_000, dtype=np.float32)}
    path = str(tmp_path / "t.bskt")

    def workload():
        save_pytree(path, tree, workers=0)
        load_pytree(path, workers=0)

    workload()                                   # warm
    def best(fn, reps=3):
        t = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            t = min(t, time.perf_counter() - t0)
        return t

    prev = obs.set_enabled(False)
    try:
        t_off = best(workload)
    finally:
        obs.set_enabled(prev)
    t_on = best(workload)
    # gross-regression guard only: CI machines are noisy, so the budget
    # here is 1.5x + 200ms, not the benchmark's 2%
    assert t_on <= t_off * 1.5 + 0.2, (t_on, t_off)
