"""Data pipeline + checkpoint fault-tolerance invariants."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_pytree, load_pytree
from repro.core.bfile import BasketFile
from repro.data import TokenPipeline, write_token_shards, make_events, write_event_file
from repro.models import Model, ModelConfig
from repro.train import init_train_state


@pytest.fixture(scope="module")
def shards(tmp_path_factory):
    td = tmp_path_factory.mktemp("shards")
    paths = [str(td / f"s{i}.bskt") for i in range(3)]
    write_token_shards(paths, vocab=512, tokens_per_shard=20_000, seed=1)
    return paths


def test_pipeline_deterministic(shards):
    a = TokenPipeline(shards, batch=4, seq_len=64, seed=5)
    b = TokenPipeline(shards, batch=4, seq_len=64, seed=5)
    for _ in range(4):
        np.testing.assert_array_equal(next(a)["tokens"], next(b)["tokens"])
    a.close(); b.close()


def test_pipeline_restart_exact(shards):
    p = TokenPipeline(shards, batch=4, seq_len=64, seed=5)
    for _ in range(5):
        next(p)
    st = p.state_dict()
    nxt = next(p)["tokens"]
    p.close()
    q = TokenPipeline(shards, batch=4, seq_len=64, seed=5)
    q.load_state_dict(st)
    np.testing.assert_array_equal(next(q)["tokens"], nxt)
    q.close()


def test_pipeline_host_disjoint(shards):
    mine = [TokenPipeline(shards, batch=2, seq_len=32, host_id=h, n_hosts=3).my_paths
            for h in range(3)]
    assert not (set(mine[0]) & set(mine[1]))
    assert set(mine[0]) | set(mine[1]) | set(mine[2]) == set(shards)


def test_pipeline_targets_shifted(shards):
    p = TokenPipeline(shards, batch=2, seq_len=32)
    b = next(p)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    p.close()


def test_event_file_fig6_structure(tmp_path, rng):
    ev = write_event_file(str(tmp_path / "e.bskt"), n_events=500, seed=2)
    f = BasketFile(str(tmp_path / "e.bskt"))
    assert np.all(np.diff(ev["Jet_offsets"]) >= 0)
    # the offsets branch must compress far better than the float branches
    assert f.compression_ratio("Jet_offsets") > 3 * f.compression_ratio("Jet_pt")


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------

def _state_tree():
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_head=16, d_ff=64, vocab=64)
    m = Model(cfg)
    st = init_train_state(m, jax.random.key(0))
    return {"params": st.params, "opt": st.opt, "step": st.step, "err": st.err}


def test_save_restore_exact(tmp_path):
    tree = _state_tree()
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, tree, extra_meta={"data_cursor": {"epoch": 1, "file_idx": 2}},
             wait=True)
    got, meta = mgr.restore(template=tree)
    assert meta["data_cursor"]["file_idx"] == 2
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_roundtrip(tmp_path):
    tree = {"w": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16)}
    save_pytree(str(tmp_path / "b.bskt"), tree)
    got, _ = load_pytree(str(tmp_path / "b.bskt"), template=tree)
    assert got["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_retention_and_latest(tmp_path):
    tree = {"x": jnp.arange(10)}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, wait=True)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_truncated_checkpoint_ignored(tmp_path):
    tree = {"x": jnp.arange(100)}
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, tree, wait=True)
    mgr.save(2, tree, wait=True)
    # corrupt step 2's data file (simulated crash mid-write + bad rename)
    p = mgr._data_path(2)
    blob = open(p, "rb").read()
    open(p, "wb").write(blob[: len(blob) // 2])
    got, _ = mgr.restore(step=1, template=tree)
    np.testing.assert_array_equal(np.asarray(got["x"]), np.arange(100))
    with pytest.raises(ValueError):
        mgr.restore(step=2, template=tree)


def test_elastic_reshard_device_put(tmp_path):
    """Restore with explicit shardings (single-device here; the mesh case
    is exercised in test_distributed.py)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    sh = {"w": NamedSharding(mesh, P("data", None))}
    save_pytree(str(tmp_path / "e.bskt"), tree)
    got, _ = load_pytree(str(tmp_path / "e.bskt"), template=tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_checkpoint_compression_wins(tmp_path):
    tree = _state_tree()
    stats = save_pytree(str(tmp_path / "c.bskt"), tree)
    assert stats["comp"] < stats["raw"]
