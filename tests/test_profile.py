"""repro.obs v3: sampling profiler, memory watermarks, flight recorder.

What's pinned here (DESIGN.md §17):

* the wall-clock sampler attributes a synthetic hot function to its
  enclosing span (``span:<name>`` fold prefix + trace-id table);
* collapsed-stack and speedscope exports are well-formed (frame indices
  in range, weights sum to the sample total);
* ``drain``/``ingest`` fold counts exactly and take the max of
  watermark peaks — the process-pool / PROF-fetch transport;
* ``mem_phase`` records RSS and tracemalloc peaks when armed and is a
  shared no-op otherwise;
* ``set_enabled(False)`` fully disables the stack: ``start()`` refuses,
  a running sampler skips its ticks, ``mem_phase`` is null, the flight
  ticker records nothing;
* concurrent ``trace.drain()`` vs ``trace.ingest()`` neither loses nor
  duplicates events (the worker-folding race, satellite of §16);
* process-pool workers' samples fold back through ``collect_obs()``;
* the flight recorder dumps once per death, chains the previous
  excepthook (exit status preserved), survives SIGTERM with the default
  disposition, and its bundle renders via ``obstat --postmortem``;
* the RBSP PROF verb round-trips start/status/fetch/stop against a live
  server, and ``STATS profile=true`` carries the watch-section summary;
* tools/benchdiff.py --json emits per-series verdicts; tools/heatmap.py
  merges multi-replica targets.
"""

from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import flight as F
from repro.obs import metrics as M
from repro.obs import profile as P
from repro.obs import trace as T

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
REPO = os.path.dirname(SRC)


def _spin_for(seconds: float) -> int:
    acc = 1
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        for _ in range(10_000):
            acc = (acc * 1103515245 + 12345) & 0xFFFFFFFF
    return acc


@pytest.fixture(autouse=True)
def _clean_profiler():
    """Every test starts and ends with the profiler stopped and empty —
    module state is process-global and must not leak across tests."""
    P.stop()
    P.reset()
    yield
    P.stop()
    P.reset()
    M.set_enabled(True)


# ---------------------------------------------------------------------------
# the sampler: hot-function plurality + span attribution
# ---------------------------------------------------------------------------

def test_sampler_attributes_hot_function_to_span():
    assert P.start(hz=250) is True
    try:
        with T.span("t.hot", root=True):
            _spin_for(0.4)
    finally:
        P.stop()
    doc = P.drain()
    assert doc["samples"] >= 5
    # judge plurality among the span-attributed folds only: the full test
    # suite leaves idle daemon threads (servers, flushers) whose blocked
    # frames are legitimately sampled too — a wall-clock profiler sees
    # every thread, but only this test's thread runs under t.hot
    hot = {k: v for k, v in doc["folds"].items()
           if k.startswith("span:t.hot;")}
    assert hot, "no sample attributed to span:t.hot"
    self_c = P.self_counts({"folds": hot})
    top = max(self_c, key=self_c.get)
    assert "_spin_for" in top, f"hot function not top self frame: {top}"
    # ...and the span's minted trace id landed in the attribution table
    assert len(doc["span_traces"].get("t.hot", "")) == 32


def test_profiler_restart_and_status():
    assert P.start(hz=11) is True
    st = P.status()
    assert st["active"] and st["hz"] == 11 and st["mem"] is None
    assert P.start(hz=23) is True         # restart with new settings
    assert P.status()["hz"] == 23
    P.stop()
    st = P.status()
    assert not st["active"] and st["hz"] == 0.0 and not P.active()


def test_span_push_pop_balanced_even_when_started_mid_span():
    """A profiler started inside an open span must not pop what was never
    pushed — the _prof flag is latched at span entry."""
    tid = threading.get_ident()
    with T.span("t.outer"):
        P.start(hz=5)
        with T.span("t.inner"):
            assert [n for n, _ in P._span_stacks.get(tid, [])] == ["t.inner"]
        P.stop()
    assert P._span_stacks.get(tid, []) == []


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_collapsed_and_speedscope_shapes():
    doc = {"folds": {"a;b": 3, "a;c": 1, "span:x;a;b": 2}, "samples": 6}
    assert P.collapsed(doc) == "a;b 3\na;c 1\nspan:x;a;b 2\n"
    ss = P.speedscope(doc, name="t")
    assert ss["$schema"].endswith("file-format-schema.json")
    (prof,) = ss["profiles"]
    assert prof["type"] == "sampled" and prof["endValue"] == 6
    assert sum(prof["weights"]) == 6
    nframes = len(ss["shared"]["frames"])
    assert all(0 <= ix < nframes for s in prof["samples"] for ix in s)
    # stacks decode back to the folds
    names = [f["name"] for f in ss["shared"]["frames"]]
    decoded = {";".join(names[ix] for ix in s): w
               for s, w in zip(prof["samples"], prof["weights"])}
    assert decoded == doc["folds"]


def test_self_counts_aggregates_leaf_frames():
    doc = {"folds": {"a;leaf": 3, "b;x;leaf": 2, "c;other": 1}}
    assert P.self_counts(doc) == {"leaf": 5, "other": 1}


# ---------------------------------------------------------------------------
# drain/ingest: the pool / PROF transport
# ---------------------------------------------------------------------------

def test_drain_ingest_folds_counts_and_maxes_watermarks():
    a = {"folds": {"x;y": 3, "z": 1}, "samples": 4,
         "span_traces": {"s1": "ab" * 16},
         "watermarks": {"p": {"peak_bytes": 100, "count": 2, "src": "rss"}}}
    b = {"folds": {"x;y": 2}, "samples": 2,
         "watermarks": {"p": {"peak_bytes": 50, "count": 1, "src": "rss"}}}
    assert P.ingest(a) == 4
    assert P.ingest(b) == 2
    doc = P.snapshot()
    assert doc["samples"] == 6
    assert doc["folds"] == {"x;y": 5, "z": 1}
    assert doc["span_traces"]["s1"] == "ab" * 16
    w = doc["watermarks"]["p"]
    assert w["peak_bytes"] == 100 and w["count"] == 3   # max peak, sum count
    # junk is rejected without corrupting state
    assert P.ingest(None) == 0
    assert P.ingest("junk") == 0
    assert P.ingest({"folds": {"k": "bad", 3: 1}}) == 0
    assert P.snapshot()["samples"] == 6
    # drain empties: a sample crosses the boundary exactly once
    assert P.drain()["samples"] == 6
    assert P.snapshot() == {"version": 1, "samples": 0, "folds": {},
                            "span_traces": {}, "watermarks": {},
                            "active": False}


# ---------------------------------------------------------------------------
# memory watermarks
# ---------------------------------------------------------------------------

def test_mem_phase_null_unless_armed():
    assert P.mem_phase("t.p") is P._NULL_PHASE
    with P.mem_phase("t.p"):
        pass
    assert P.watermarks() == {}


def test_mem_phase_rss_records_peak_and_histogram():
    assert P.start(hz=1, mem=True) is True             # True == "rss"
    try:
        with P.mem_phase("t.rss"):
            arr = np.ones(4 << 20, dtype=np.uint8)     # 4 MB touched
            arr[::4096] = 2
    finally:
        P.stop()
    w = P.watermarks()["t.rss"]
    assert w["src"] == "rss" and w["count"] == 1
    assert w["peak_bytes"] > 1 << 20                   # absolute RSS: > 1 MB
    hists = obs.snapshot()["hists"]
    key = M.format_key("mem.phase_peak_bytes", {"phase": "t.rss"})
    assert hists[key]["count"] >= 1
    # disarmed again after stop()
    assert P.mem_phase("t.rss") is P._NULL_PHASE


def test_mem_phase_tracemalloc_sees_python_heap():
    import tracemalloc
    assert P.start(hz=1, mem="tracemalloc") is True
    try:
        assert tracemalloc.is_tracing()
        with P.mem_phase("t.tm"):
            blob = bytearray(8 << 20)                  # 8 MB python alloc
            blob[0] = 1
        del blob
    finally:
        P.stop()
    assert not tracemalloc.is_tracing()                # stop() tore it down
    w = P.watermarks()["t.tm"]
    assert w["src"] == "tracemalloc"
    assert w["peak_bytes"] >= 8 << 20


# ---------------------------------------------------------------------------
# the REPRO_OBS gate disables everything (satellite)
# ---------------------------------------------------------------------------

def test_disabled_gate_stops_sampler_memphase_and_flight():
    assert P.start(hz=200) is True
    M.set_enabled(False)
    try:
        time.sleep(0.05)                               # let in-flight tick end
        s0 = P.status()["samples"]
        _spin_for(0.2)
        assert P.status()["samples"] == s0             # sampler skips ticks
        assert P.start(hz=100) is False                # refuses to (re)start
        assert P.mem_phase("t.off") is P._NULL_PHASE
        rec = F.FlightRecorder()
        rec.tick()
        assert list(rec._ring) == []                   # ticker records nothing
    finally:
        M.set_enabled(True)
        P.stop()


# ---------------------------------------------------------------------------
# concurrent trace drain vs ingest (satellite): no loss, no duplication
# ---------------------------------------------------------------------------

def test_concurrent_trace_drain_vs_ingest_exact():
    T.clear()
    N_THREADS, N_EVENTS = 4, 4000                      # < ring capacity: no
    collected: list = []                               # eviction even if the
    stop = threading.Event()                           # drainer stalls

    def producer(i):
        for j in range(N_EVENTS):
            T.ingest([{"name": f"p{i}.{j}", "ph": "X", "ts": 1.0}])

    def drainer():
        while not stop.is_set():
            collected.extend(T.drain())

    threads = [threading.Thread(target=producer, args=(i,))
               for i in range(N_THREADS)]
    d = threading.Thread(target=drainer)
    d.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    d.join()
    collected.extend(T.drain())                        # the final delta
    names = [e["name"] for e in collected]
    assert len(names) == N_THREADS * N_EVENTS
    assert len(set(names)) == N_THREADS * N_EVENTS
    assert T.events() == []


# ---------------------------------------------------------------------------
# process-pool worker samples fold back
# ---------------------------------------------------------------------------

def test_worker_profile_folds_back_through_collect_obs():
    from repro.core.codec import CompressionConfig
    from repro.io.engine import CompressionEngine

    # repro-deflate is pure python: routed to the *process* pool, and
    # slow enough (~1s/MB) that a 500 Hz sampler cannot miss it
    raw = np.arange(131_072, dtype=np.int64).tobytes()
    with CompressionEngine(workers=1, shm=False) as eng:
        eng.profile_workers("start", hz=500)
        with T.span("test.root", root=True):       # tp rides into the task
            out = list(eng.pack_stream([(0, len(raw), raw)],
                                       CompressionConfig("repro-deflate", 1)))
        assert len(out) == 1
        eng.profile_workers("stop")
        eng.collect_obs()
    doc = P.snapshot()
    assert doc["samples"] > 0, "no worker samples folded back"
    assert any(k.startswith("span:engine.pack") for k in doc["folds"]), \
        "worker samples not attributed to span:engine.pack"


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_trigger_writes_loadable_bundle(tmp_path):
    obs.counter("t.flight").inc(3)
    T.clear()
    with T.span("t.flight_span", root=True):
        pass
    out = str(tmp_path / "bundle.json")
    got = F.trigger("unit-test", path=out)
    assert got == out
    doc = F.load_bundle(out)
    assert doc["kind"] == F.BUNDLE_KIND and doc["reason"] == "unit-test"
    assert doc["final_metrics"]["counters"]["t.flight"] >= 3
    assert any(e.get("name") == "t.flight_span"
               for e in doc["trace_events"])
    assert any(t.get("name") == "MainThread" for t in doc["threads"])
    # non-bundle json is rejected
    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        json.dump({"kind": "other"}, f)
    with pytest.raises(ValueError):
        F.load_bundle(bad)


def test_flight_install_idempotent_and_uninstall_restores_hook(tmp_path):
    prev_hook = sys.excepthook
    try:
        rec = F.install(dir=str(tmp_path), ticker=False)
        assert F.install() is rec                      # idempotent singleton
        assert F.recorder() is rec
        assert sys.excepthook is not prev_hook
    finally:
        F.uninstall()
    assert sys.excepthook is prev_hook
    assert F.recorder() is None


def test_flight_dumps_once_per_death(tmp_path):
    rec = F.FlightRecorder(dir=str(tmp_path))
    rec.tick()
    assert rec.dump("crash-a") is not None
    assert rec.dump("crash-b") is None                 # second death: no dump
    assert rec.dump("manual", force=True) is not None  # trigger always dumps
    assert len(list(tmp_path.glob("flight-*.json"))) == 2


def test_flight_excepthook_dumps_and_preserves_exit(tmp_path):
    script = (
        "import sys\n"
        f"sys.path.insert(0, {SRC!r})\n"
        "from repro import obs\n"
        f"obs.flight.install(dir={str(tmp_path)!r}, interval_s=0.05)\n"
        "obs.counter('t.crash').inc()\n"
        "raise KeyError('boom')\n")
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=60)
    assert r.returncode == 1                           # previous hook still ran
    assert "KeyError" in r.stderr and "boom" in r.stderr
    (bundle,) = tmp_path.glob("flight-*.json")
    doc = F.load_bundle(str(bundle))
    assert doc["reason"] == "unhandled-exception"
    assert doc["exception"]["type"] == "KeyError"
    assert doc["final_metrics"]["counters"]["t.crash"] == 1


def test_flight_sigterm_dumps_and_redelivers(tmp_path):
    script = (
        "import sys, time\n"
        f"sys.path.insert(0, {SRC!r})\n"
        "from repro import obs\n"
        f"obs.flight.install(dir={str(tmp_path)!r}, interval_s=0.05)\n"
        "print('armed', flush=True)\n"
        "time.sleep(30)\n")
    proc = subprocess.Popen([sys.executable, "-c", script],
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True)
    try:
        assert proc.stdout.readline().strip() == "armed"
        time.sleep(0.2)                                # a tick or two
        proc.send_signal(signal.SIGTERM)
        proc.wait(timeout=30)
    finally:
        proc.kill()
    assert proc.returncode == -signal.SIGTERM          # default disposition
    (bundle,) = tmp_path.glob("flight-*.json")
    assert F.load_bundle(str(bundle))["reason"] == "sigterm"


def test_obstat_postmortem_renders_bundle(tmp_path, capsys):
    from repro.obs.__main__ import main as obstat_main
    obs.counter("t.pm").inc()
    out = str(tmp_path / "pm.json")
    assert F.trigger("render-test", path=out) == out
    assert obstat_main(["--postmortem", out]) == 0
    text = capsys.readouterr().out
    assert "render-test" in text and "MainThread" in text
    assert obstat_main(["--postmortem", out, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["kind"] == F.BUNDLE_KIND


# ---------------------------------------------------------------------------
# RBSP PROF verb + STATS profile section
# ---------------------------------------------------------------------------

@pytest.fixture
def served_dir(tmp_path):
    from repro.core.bfile import write_arrays
    from repro.core.codec import CompressionConfig
    rng = np.random.default_rng(13)
    write_arrays(str(tmp_path / "ev.bskt"),
                 {"e": rng.integers(0, 99, 400_000).astype(np.int64)},
                 cfg_for=lambda n, a: CompressionConfig("zlib", 1, "delta8"),
                 target_basket_bytes=32 * 1024)
    return str(tmp_path)


def test_prof_verb_roundtrip_against_live_server(served_dir):
    from repro.remote import BasketServer, RemoteBasketFile
    from repro.remote.client import fetch_stats, request_prof
    with BasketServer(served_dir, workers=2, heat=False) as srv:
        srv.start()
        r = request_prof(srv.host, srv.port, action="start", hz=150,
                         mem=True)
        assert r["started"] is True and r["profile"]["active"]
        assert r["profile"]["hz"] == 150 and r["profile"]["mem"] == "rss"
        with RemoteBasketFile(srv.url("ev.bskt"), wire=None) as rf:
            nb = len(rf.branches["e"]["baskets"])
            t0 = time.perf_counter()
            while time.perf_counter() - t0 < 0.3:
                rf.fetch_wire("e", list(range(nb)))
        st = fetch_stats(srv.host, srv.port, profile=True)
        assert st["profile"]["active"] and "self" in st["profile"]
        doc = request_prof(srv.host, srv.port, action="fetch",
                           reset=True)["profile"]
        assert doc["samples"] > 0 and doc["folds"]
        assert "server.readv" in doc["watermarks"]     # READV under mem_phase
        # reset=True drained: a second fetch covers a disjoint window
        assert request_prof(srv.host, srv.port,
                            action="fetch")["profile"]["samples"] \
            <= doc["samples"]
        r = request_prof(srv.host, srv.port, action="stop")
        assert r["stopped"] is True and not r["profile"]["active"]


# ---------------------------------------------------------------------------
# benchdiff --json per-series verdicts (satellite)
# ---------------------------------------------------------------------------

BENCHDIFF = os.path.join(REPO, "tools", "benchdiff.py")


def _write_bench(d, pr, value, unit="MB/s"):
    doc = {"schema": 1, "benches": {"b": [
        {"bench": "b", "stage": "s", "case": "c",
         "value": value, "unit": unit, "wall_s": ""}]}}
    with open(os.path.join(d, f"BENCH_pr{pr}.json"), "w") as f:
        json.dump(doc, f)


def _benchdiff_json(d):
    r = subprocess.run([sys.executable, BENCHDIFF, "--dir", d, "--json"],
                       capture_output=True, text=True)
    return r.returncode, json.loads(r.stdout)


def test_benchdiff_json_emits_per_series_verdicts(tmp_path):
    d = str(tmp_path)
    _write_bench(d, 1, 1000.0)
    _write_bench(d, 2, 980.0)
    _write_bench(d, 3, 400.0)                          # -60% throughput
    rc, doc = _benchdiff_json(d)
    assert rc == 1
    assert doc["compared"] == 1                        # backcompat: a count
    (s,) = doc["series"]
    assert s["series"] == "b/s/c" and s["unit"] == "MB/s"
    assert s["verdict"] == "regressed" and s["direction"] == "higher"
    assert s["delta"] < -0.4 and 0 < s["band"] < 1
    assert doc["regressions"][0]["series"] == "b/s/c"
    # within the band: verdict ok, exit 0
    _write_bench(d, 3, 950.0)
    rc, doc = _benchdiff_json(d)
    assert rc == 0 and doc["series"][0]["verdict"] == "ok"
    # better than every baseline beyond the band: improved, still exit 0
    _write_bench(d, 3, 2000.0)
    rc, doc = _benchdiff_json(d)
    assert rc == 0 and doc["series"][0]["verdict"] == "improved"
    assert doc["improvements"][0]["delta"] > 0.25


# ---------------------------------------------------------------------------
# heatmap multi-replica merge (satellite)
# ---------------------------------------------------------------------------

HEATMAP = os.path.join(REPO, "tools", "heatmap.py")


def _make_replica(root, name, reads_hot):
    from repro.obs import heat as H
    os.makedirs(root, exist_ok=True)
    hl = H.HeatLog(halflife_s=3600.0)
    p = os.path.join(root, name)
    for _ in range(reads_hot):
        hl.record(p, "hot", [0], 1024)
    hl.record(p, "cold", [1], 64)
    hl.flush()


def test_heatmap_merges_replicas_and_expands_globs(tmp_path):
    _make_replica(str(tmp_path / "repA"), "ev.bskt", 30)
    _make_replica(str(tmp_path / "repB"), "ev.bskt", 10)

    def rows(*targets):
        r = subprocess.run([sys.executable, HEATMAP, *targets, "--json"],
                           cwd=str(tmp_path), capture_output=True, text=True)
        assert r.returncode == 0, r.stderr
        return json.loads(r.stdout)["rows"]

    single = rows("repA")
    assert single[0]["branch"] == "hot" and single[0]["reads"] == 30
    merged = rows("repA", "repB")
    by_branch = {r["branch"]: r for r in merged}
    assert by_branch["hot"]["reads"] == 40             # replica sum
    assert by_branch["cold"]["reads"] == 2
    assert by_branch["hot"]["heat"] > by_branch["cold"]["heat"]
    globbed = rows("rep*")                             # glob expansion
    assert [(r["branch"], r["reads"]) for r in globbed] \
        == [(r["branch"], r["reads"]) for r in merged]
    for g, m in zip(globbed, merged):                  # heat decays to "now":
        assert g["heat"] == pytest.approx(m["heat"], rel=1e-3)
