"""Checksum tiers agree bit-exactly with zlib's C implementations
(the paper's §2.1 CF-ZLIB mechanism, reproduced as vectorization)."""

import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip; the rest still run
    from _hypothesis_fallback import given, settings, st

from repro.core.checksum import (adler32_naive, adler32_vector, adler32_hw,
                                 crc32_naive, crc32_table, crc32_slice8,
                                 crc32_hw)


@settings(max_examples=40, deadline=None)
@given(st.binary(min_size=0, max_size=5000))
def test_adler32_tiers_agree(data):
    ref = zlib.adler32(data) & 0xFFFFFFFF
    assert adler32_vector(data) == ref
    assert adler32_hw(data) == ref


@settings(max_examples=15, deadline=None)
@given(st.binary(min_size=0, max_size=300))
def test_adler32_naive_agrees(data):
    assert adler32_naive(data) == (zlib.adler32(data) & 0xFFFFFFFF)


@settings(max_examples=40, deadline=None)
@given(st.binary(min_size=0, max_size=5000))
def test_crc32_tiers_agree(data):
    ref = zlib.crc32(data) & 0xFFFFFFFF
    assert crc32_table(data) == ref
    assert crc32_slice8(data) == ref
    assert crc32_hw(data) == ref


@settings(max_examples=10, deadline=None)
@given(st.binary(min_size=0, max_size=200))
def test_crc32_naive_agrees(data):
    assert crc32_naive(data) == (zlib.crc32(data) & 0xFFFFFFFF)


def test_streaming_chaining(rng):
    """Running value chaining matches one-shot (basket-by-basket use)."""
    data = bytes(rng.integers(0, 256, 10_000, dtype=np.uint8))
    a, c = 1, 0
    for i in range(0, len(data), 1000):
        chunk = data[i:i + 1000]
        a = adler32_vector(chunk, a)
        c = crc32_slice8(chunk, c)
    assert a == (zlib.adler32(data) & 0xFFFFFFFF)
    assert c == (zlib.crc32(data) & 0xFFFFFFFF)


def test_vector_block_boundaries(rng):
    """Block-sized inputs hit the vectorized path's boundary cases."""
    for n in (1 << 16, (1 << 16) + 1, (1 << 16) - 1, 3, 0):
        data = bytes(rng.integers(0, 256, n, dtype=np.uint8))
        assert adler32_vector(data) == (zlib.adler32(data) & 0xFFFFFFFF)
