"""Sharding rule engine: pure-logic tests (no multi-device needed — rules
are computed from specs and mesh shapes)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.models import Model
from repro.models.specs import ParamSpec
from repro.parallel import ParallelismConfig, logical_to_pspec
from repro.parallel.sharding import abstract_mesh, dp_spec


@pytest.fixture(scope="module")
def mesh():
    # single real device is fine: rules only read mesh SHAPE
    return abstract_mesh((16, 16), ("data", "model"))


def test_tp_divisible_dims_shard(mesh):
    pc = ParallelismConfig(zero3=False)
    sp = ParamSpec((4096, 32, 128), ("embed", "heads", "head_dim"))
    assert logical_to_pspec(sp, mesh, pc) == P(None, "model", None)
    sp = ParamSpec((4096, 12288), ("embed", "ff"))
    assert logical_to_pspec(sp, mesh, pc) == P(None, "model")
    sp = ParamSpec((151936, 4096), ("vocab", "embed"))
    assert logical_to_pspec(sp, mesh, pc) == P("model", None)


def test_non_divisible_falls_back(mesh):
    pc = ParallelismConfig(zero3=False)
    sp = ParamSpec((5120, 40, 128), ("embed", "heads", "head_dim"))
    # 40 % 16 != 0 -> no TP on heads
    assert logical_to_pspec(sp, mesh, pc) == P(None, None, None)
    sp = ParamSpec((5120, 8, 128), ("embed", "kv_heads", "head_dim"))
    assert logical_to_pspec(sp, mesh, pc) == P(None, None, None)


def test_zero3_shards_largest_divisible(mesh):
    pc = ParallelismConfig(zero3=True)
    sp = ParamSpec((5120, 40, 128), ("embed", "heads", "head_dim"))
    assert logical_to_pspec(sp, mesh, pc) == P("data", None, None)


def test_experts_fsdp(mesh):
    pc = ParallelismConfig()
    sp = ParamSpec((128, 5120, 8192), ("experts", "embed", "ff"))
    assert logical_to_pspec(sp, mesh, pc) == P("data", None, "model")


def test_each_mesh_axis_used_once(mesh):
    pc = ParallelismConfig(zero3=True)
    for arch in ("qwen3-8b", "llama4-maverick-400b-a17b", "jamba-v0.1-52b"):
        model = Model(get_config(arch))
        from repro.models.specs import tree_paths
        for path, spec in tree_paths(model.param_specs()).items():
            ps = logical_to_pspec(spec, mesh, pc)
            used = [e for e in ps if e is not None]
            assert len(used) == len(set(used)), (arch, path, ps)
            # divisibility holds wherever an axis was assigned
            for dim, ax in zip(spec.shape, tuple(ps) + (None,) * 9):
                if ax:
                    assert dim % mesh.shape[ax] == 0, (arch, path, ps)


def test_dp_spec_divisibility():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert dp_spec(mesh, 256) == ("pod", "data")
    assert dp_spec(mesh, 1) is None
    assert dp_spec(mesh, 13) is None
    single = abstract_mesh((16, 16), ("data", "model"))
    assert dp_spec(single, 128) == "data"
