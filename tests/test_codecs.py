"""Codec layer: roundtrips (incl. hypothesis), level semantics, dictionary
use, and the paper's Fig. 2/6 ordering properties."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip; the rest still run
    from _hypothesis_fallback import given, settings, st

from repro.core import (CODECS, CompressionConfig, compress, decompress,
                        train_dictionary)
from repro.core.policy import PROFILES, choose, precond_for_array

ALGOS = sorted(CODECS)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("level", [1, 5, 9])
def test_roundtrip_all_payload_kinds(algo, level, rng):
    if algo == "none":
        level = 0
    payloads = [
        b"",
        b"a",
        bytes(rng.integers(0, 256, 10_000, dtype=np.uint8)),      # random
        bytes(rng.integers(97, 105, 10_000, dtype=np.uint8)),     # text-ish
        np.cumsum(rng.integers(1, 9, 3000)).astype(">i4").tobytes(),  # offsets
        b"\x00" * 5000,                                           # runs
    ]
    for data in payloads:
        cfg = CompressionConfig(algo=algo, level=level)
        comp = compress(data, cfg)
        assert decompress(comp, len(data), cfg) == data


@settings(max_examples=30, deadline=None)
@given(data=st.binary(min_size=0, max_size=4096),
       algo=st.sampled_from(["zlib", "lz4", "zstd", "repro-deflate"]),
       level=st.integers(1, 9))
def test_roundtrip_property(data, algo, level):
    cfg = CompressionConfig(algo=algo, level=level)
    assert decompress(compress(data, cfg), len(data), cfg) == data


@settings(max_examples=20, deadline=None)
@given(data=st.binary(min_size=1, max_size=2048),
       precond=st.sampled_from(["shuffle4", "bitshuffle4", "shuffle8",
                                "delta4+shuffle4", "bitshuffle2"]))
def test_roundtrip_with_precond_property(data, precond):
    cfg = CompressionConfig(algo="zstd", level=3, precond=precond)
    assert decompress(compress(data, cfg), len(data), cfg) == data


def test_level_zero_is_passthrough():
    data = b"hello world" * 100
    cfg = CompressionConfig(algo="zlib", level=0)
    assert compress(data, cfg) == data


def test_level_monotonicity_ratio(rng):
    """Paper §2: level 9 must not compress worse than level 1 (per algo)."""
    base = bytes(rng.integers(97, 117, 2000, dtype=np.uint8)) * 30
    for algo in ("zlib", "zstd", "lzma", "lz4", "repro-deflate"):
        c1 = len(compress(base, CompressionConfig(algo=algo, level=1)))
        c9 = len(compress(base, CompressionConfig(algo=algo, level=9)))
        assert c9 <= c1 * 1.02, (algo, c1, c9)


def test_fig6_offset_array_ordering(rng):
    """The paper's Fig. 6 mechanism: a ROOT offset array is near-
    incompressible for plain LZ4, while Shuffle/BitShuffle preconditioning
    makes LZ4 beat plain-ZLIB-class ratios."""
    offsets = (0x01000000
               + np.cumsum(rng.integers(1, 5, 20_000))).astype(">u4").tobytes()
    lz4_plain = len(compress(offsets, CompressionConfig("lz4", 1)))
    lz4_shuf = len(compress(offsets, CompressionConfig("lz4", 1, "shuffle4")))
    lz4_delta = len(compress(offsets, CompressionConfig("lz4", 1, "delta4+shuffle4")))
    zlib_plain = len(compress(offsets, CompressionConfig("zlib", 6)))
    assert lz4_plain > 0.9 * len(offsets), "offsets should be ~incompressible for LZ4"
    assert lz4_shuf < 0.3 * lz4_plain
    assert lz4_delta < zlib_plain, "preconditioned LZ4 must beat plain zlib (Fig 6)"


def test_float_bitshuffle_helps(rng):
    floats = (rng.standard_normal(30_000) * 0.001).astype("<f4").tobytes()
    plain = len(compress(floats, CompressionConfig("lz4", 1)))
    bshuf = len(compress(floats, CompressionConfig("lz4", 1, "bitshuffle4")))
    assert bshuf < plain


def test_dictionary_improves_small_buffers(rng):
    samples = [bytes(rng.integers(97, 103, 300, dtype=np.uint8)) + b"suffix-common-tail"
               for _ in range(200)]
    d = train_dictionary(samples[:150], size=2048)
    cfg_nd = CompressionConfig("zstd", 3)
    cfg_d = CompressionConfig("zstd", 3, dictionary=d)
    test = samples[150:]
    plain = sum(len(compress(s, cfg_nd)) for s in test)
    withd = sum(len(compress(s, cfg_d)) for s in test)
    assert withd < plain, (withd, plain)
    for s in test[:5]:
        assert decompress(compress(s, cfg_d), len(s), cfg_d) == s


def test_dictionary_cross_codec(rng):
    """Paper §3: zstd-trained dictionaries are usable for zlib and lz4."""
    samples = [b"event{" + bytes(rng.integers(97, 101, 120, dtype=np.uint8)) + b"}"
               for _ in range(100)]
    d = train_dictionary(samples, size=1024)
    for algo in ("zlib", "lz4"):
        cfg = CompressionConfig(algo, 5, dictionary=d)
        for s in samples[:5]:
            assert decompress(compress(s, cfg), len(s), cfg) == s


def test_policy_profiles_and_heuristics(rng):
    assert {"production", "analysis", "checkpoint", "wire"} <= set(PROFILES)
    assert precond_for_array(np.zeros(64, np.float32)) == "bitshuffle4"
    assert precond_for_array(np.cumsum(np.ones(64, np.int64))).startswith("delta8")
    assert precond_for_array(rng.integers(0, 100, 64).astype(np.int32)).startswith("shuffle")
    cfg = choose("w", np.zeros(64, np.float32), "analysis")
    assert cfg.algo == "lz4" and cfg.precond == "bitshuffle4"


def test_invalid_configs_rejected():
    with pytest.raises(ValueError):
        CompressionConfig(algo="zlib", level=11)
    with pytest.raises(KeyError):
        CompressionConfig(algo="nope", level=3)
