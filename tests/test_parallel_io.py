"""Parallel I/O engine (repro.io): byte-identity of parallel writes,
multi-producer merging, decompress-ahead reads, crash atomicity."""

import os
import threading

import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.core import CompressionConfig
from repro.core.bfile import BasketFile, BasketWriter, write_arrays
from repro.data import TokenPipeline, write_token_shards
from repro.io import (BasketBuffer, BufferMerger, CompressionEngine,
                      PrefetchReader, merge_files)


@pytest.fixture
def arrays(rng):
    return {
        "f": rng.standard_normal(100_000).astype(np.float32),
        "off": np.cumsum(rng.integers(1, 7, 100_000)).astype(np.int64),
    }


def _cfg(name, arr):
    return CompressionConfig("zlib", 5, "shuffle4")


def test_parallel_write_byte_identical(tmp_path, arrays):
    """workers=1 and workers=8 must produce the same bytes as serial."""
    paths = {}
    for w in (0, 1, 8):
        p = str(tmp_path / f"w{w}.bskt")
        write_arrays(p, arrays, _cfg, target_basket_bytes=32 * 1024, workers=w)
        paths[w] = open(p, "rb").read()
    assert paths[0] == paths[1] == paths[8]
    assert len(BasketFile(str(tmp_path / "w8.bskt")).branches["f"]["baskets"]) > 1


def test_parallel_write_pure_python_codec_byte_identical(tmp_path, rng):
    """Pure-Python codecs route to the process pool; bytes still identical."""
    arr = {"x": rng.standard_normal(20_000).astype(np.float32)}
    cfg = lambda n, a: CompressionConfig("lz4", 1, "shuffle4")
    a = str(tmp_path / "a.bskt")
    b = str(tmp_path / "b.bskt")
    write_arrays(a, arr, cfg, target_basket_bytes=16 * 1024, workers=0)
    write_arrays(b, arr, cfg, target_basket_bytes=16 * 1024, workers=4)
    assert open(a, "rb").read() == open(b, "rb").read()


def test_engine_shared_across_branches(tmp_path, arrays):
    with CompressionEngine(workers=4) as eng:
        with BasketWriter(str(tmp_path / "e.bskt"), engine=eng) as w:
            for name, arr in arrays.items():
                w.write_branch(name, arr, _cfg(name, arr), 32 * 1024)
    f = BasketFile(str(tmp_path / "e.bskt"))
    for name, arr in arrays.items():
        np.testing.assert_array_equal(f.read_branch(name), arr)


def test_merger_multi_producer_roundtrip(tmp_path, rng):
    base = rng.standard_normal(50_000).astype(np.float32)
    path = str(tmp_path / "m.bskt")
    with BufferMerger(path, workers=2) as m:
        def produce(k):
            buf = m.buffer()
            buf.write_branch(f"shard{k}", base + k,
                             CompressionConfig("zlib", 3), 32 * 1024)
            m.merge(buf)
        threads = [threading.Thread(target=produce, args=(k,))
                   for k in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    f = BasketFile(path)
    assert len(f.branch_names()) == 6
    for k in range(6):
        np.testing.assert_array_equal(f.read_branch(f"shard{k}"), base + k)


def test_merger_no_recompression_preserves_payloads(tmp_path, arrays):
    """Merged payload bytes equal the buffered (pre-compressed) ones."""
    path = str(tmp_path / "nr.bskt")
    buf = BasketBuffer()
    buf.write_branch("f", arrays["f"], _cfg("f", None), 32 * 1024)
    payloads = list(buf._payloads["f"])
    with BufferMerger(path) as m:
        m.merge(buf, clear=False)
    f = BasketFile(path)
    got = [f.read_basket_payload("f", i)
           for i in range(len(f.branches["f"]["baskets"]))]
    assert got == payloads


def test_merge_files_splices_without_recompression(tmp_path, arrays, rng):
    p1, p2 = str(tmp_path / "1.bskt"), str(tmp_path / "2.bskt")
    write_arrays(p1, {"a": arrays["f"]}, _cfg, target_basket_bytes=32 * 1024)
    write_arrays(p2, {"b": arrays["off"]}, _cfg, target_basket_bytes=32 * 1024)
    out = str(tmp_path / "merged.bskt")
    merge_files(out, [p1, p2])
    f = BasketFile(out)
    np.testing.assert_array_equal(f.read_branch("a"), arrays["f"])
    np.testing.assert_array_equal(f.read_branch("b"), arrays["off"])
    assert f.compressed_bytes() == (BasketFile(p1).compressed_bytes()
                                    + BasketFile(p2).compressed_bytes())


def test_prefetch_reader_matches_eager(tmp_path, arrays):
    p = str(tmp_path / "p.bskt")
    write_arrays(p, arrays, _cfg, target_basket_bytes=16 * 1024)
    f = BasketFile(p)
    with PrefetchReader(f, "f", workers=4, ahead=3) as r:
        assert r.n_baskets() > 2
        np.testing.assert_array_equal(r.read_all(), arrays["f"])
        np.testing.assert_array_equal(r.read_entries(100, 5000),
                                      arrays["f"][100:5000])
        np.testing.assert_array_equal(r.read_entries(0, 1), arrays["f"][:1])
        assert r.read_entries(10, 10).size == 0


def test_read_all_decompresses_each_basket_once(tmp_path, arrays):
    """LRU eviction must never force re-decompression of baskets whose
    futures are already held for consumption (cache smaller than branch)."""
    p = str(tmp_path / "once.bskt")
    write_arrays(p, arrays, _cfg, target_basket_bytes=16 * 1024)
    with PrefetchReader(BasketFile(p), "f", workers=4, ahead=2,
                        cache_baskets=2) as r:
        np.testing.assert_array_equal(r.read_all(), arrays["f"])
        assert r.misses == r.n_baskets()    # each basket scheduled once
        assert r.hits == 0
        # bulk reads must not pin the whole decompressed branch
        assert len(r._cache) <= 2


def test_prefetch_cache_hits_on_rereads(tmp_path, arrays):
    p = str(tmp_path / "c.bskt")
    write_arrays(p, arrays, _cfg, target_basket_bytes=16 * 1024)
    with PrefetchReader(BasketFile(p), "off", workers=2, ahead=2) as r:
        r.read_entries(0, 4000)
        before = r.hits
        r.read_entries(0, 4000)      # same covering baskets -> LRU hits
        assert r.hits > before


def test_basketfile_prefetch_argument(tmp_path, arrays):
    p = str(tmp_path / "bf.bskt")
    write_arrays(p, arrays, _cfg, target_basket_bytes=16 * 1024)
    with BasketFile(p, workers=4, prefetch=3) as f:
        np.testing.assert_array_equal(f.read_branch("f"), arrays["f"])
        np.testing.assert_array_equal(f.read_entries("off", 777, 9999),
                                      arrays["off"][777:9999])


def test_crash_mid_write_leaves_no_valid_trailer(tmp_path, arrays):
    """A writer that dies mid-write (even after whole branches) must not
    leave anything a reader would accept — parallel path included."""
    p = str(tmp_path / "crash.bskt")
    w = BasketWriter(p, workers=4)
    w.write_branch("f", arrays["f"], _cfg("f", None), 32 * 1024)
    # crash point: branch data flushed to tmp, no close() -> no rename
    w._f.flush()
    assert not os.path.exists(p)
    torn = open(w._tmp, "rb").read()
    w.abort()
    assert not os.path.exists(w._tmp)
    # even a torn copy promoted to the final name is rejected (no trailer)
    open(p, "wb").write(torn)
    with pytest.raises(ValueError, match="truncated|magic"):
        BasketFile(p)


def test_merger_abort_is_atomic(tmp_path, arrays):
    p = str(tmp_path / "ab.bskt")
    m = BufferMerger(p)
    buf = m.buffer()
    buf.write_branch("f", arrays["f"], _cfg("f", None), 32 * 1024)
    m.merge(buf)
    m.abort()
    assert not os.path.exists(p)
    assert not os.path.exists(p + ".tmp")


def test_checkpoint_parallel_producers_roundtrip(tmp_path, rng):
    tree = {"w": {"a": rng.standard_normal((64, 32)).astype(np.float32),
                  "b": rng.integers(0, 9, 1000).astype(np.int32)},
            "step": np.int64(7), "none": None}
    ps = str(tmp_path / "serial.bskt")
    pp = str(tmp_path / "parallel.bskt")
    save_pytree(ps, tree)
    save_pytree(pp, tree, workers=2, producers=3)
    serial, _ = load_pytree(ps)
    parallel, _ = load_pytree(pp, prefetch=2)
    assert set(serial) == set(parallel)
    for k in serial:
        np.testing.assert_array_equal(serial[k], parallel[k])


def test_pipeline_readahead_matches_eager(tmp_path):
    shards = [str(tmp_path / f"s{i}.bskt") for i in range(2)]
    write_token_shards(shards, vocab=1000, tokens_per_shard=40_000, seed=3)
    def collect(**kw):
        pipe = TokenPipeline(shards, batch=4, seq_len=128, **kw)
        out = [next(pipe)["tokens"].copy() for _ in range(20)]
        pipe.close()
        return out
    eager = collect(readahead_files=0, decomp_workers=0, prefetch_baskets=0)
    ahead = collect(readahead_files=1, decomp_workers=4, prefetch_baskets=4)
    for a, b in zip(eager, ahead):
        np.testing.assert_array_equal(a, b)
