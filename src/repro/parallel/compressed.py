"""Compressed tensor-parallel reduction — the paper's wire profile applied
to on-device collectives (DESIGN.md §2.3, beyond-paper).

A row-parallel projection y @ W with the contraction dim TP-sharded needs
an all-reduce of bf16 partial sums: wire = 2*N*(k-1)/k bytes.  Here each
rank instead int8-quantizes its partial (per-token scales — qpack
semantics, same math as kernels/ref.qpack_ref), all-gathers the int8
payload + scales, and dequant-sums locally:

    wire = (N_int8 + scales)*(k-1)/k  ~=  1/4 of the bf16 all-reduce.

Intended for inference paths (prefill/decode); the quantization error is
~0.2-0.4% rms per partial (measured in tests/test_compressed_tp.py).
Requires an active activation context (repro.parallel.actctx) whose mesh
names the TP axis; silently falls back to a plain einsum + GSPMD
all-reduce otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .actctx import _CTX

__all__ = ["rowparallel_einsum_compressed"]


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: ``jax.shard_map``/``check_vma`` is the
    new spelling, ``jax.experimental.shard_map``/``check_rep`` the old one."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _quantize_rows(x):
    """Per-(…, row) int8 quantization over the last dim (qpack_ref math)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def rowparallel_einsum_compressed(y, w, out_dtype=None):
    """y: (B, S, E) with E TP-sharded; w: (E, D).  Returns (B, S, D)
    replicated over the TP axis, reduced through an int8 wire."""
    mesh = _CTX["mesh"]
    tp = _CTX["tp"]
    out_dtype = out_dtype or y.dtype
    if mesh is None or tp not in getattr(mesh, "axis_names", ()):
        return jnp.einsum("bse,ed->bsd", y, w.astype(y.dtype))
    k = mesh.shape[tp]
    B, S, E = y.shape
    D = w.shape[1]
    dp = _CTX["dp"]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if E % k or B % dp_size:
        return jnp.einsum("bse,ed->bsd", y, w.astype(y.dtype))
    dp_spec = dp if len(dp) > 1 else dp[0]

    def body(y_loc, w_loc):
        part = jnp.einsum("bse,ed->bsd", y_loc, w_loc.astype(y_loc.dtype),
                          preferred_element_type=jnp.float32)
        q, s = _quantize_rows(part)
        qg = jax.lax.all_gather(q, tp)                 # (k, b, s, D) int8
        sg = jax.lax.all_gather(s, tp)                 # (k, b, s, 1) f32
        out = jnp.einsum("kbsd,kbsu->bsd", qg.astype(jnp.float32), sg)
        return out.astype(out_dtype)

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, tp), P(tp, None)),
        out_specs=P(dp_spec, None, None),
    )(y, w)
