"""Activation-sharding context: with_sharding_constraint hooks for model code.

GSPMD resolves einsum sharding conflicts by cost model, and with ZeRO-3
weights (FSDP over "data") + batch-sharded activations it will happily
re-shard *activations* over the data axis (measured: 100s-of-GiB replicated
activation tensors at 400B scale).  Pinning every layer-boundary activation
to P(dp, ...) forces the partitioner to gather *weights* instead — the
FSDP-streaming schedule every production framework uses.

Model code calls ``constrain(x, kinds)`` with logical kinds per dim:
``"dp"`` (batch), ``"tp"`` (tensor-parallel feature dim), or None.  Without
an active context (unit tests, single-host examples) it is a no-op; the
launcher sets the context per cell.  Divisibility-gated like the param
rules.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["set_activation_context", "clear_activation_context", "constrain",
           "activation_context"]

_CTX: dict = {"mesh": None, "dp": (), "tp": None}


def set_activation_context(mesh: Mesh, tp_axis: str = "model") -> None:
    dp = tuple(a for a in mesh.axis_names if a != tp_axis)
    _CTX.update(mesh=mesh, dp=dp, tp=tp_axis)


def clear_activation_context() -> None:
    _CTX.update(mesh=None, dp=(), tp=None)


class activation_context:
    def __init__(self, mesh: Mesh, tp_axis: str = "model"):
        self.mesh, self.tp_axis = mesh, tp_axis

    def __enter__(self):
        set_activation_context(self.mesh, self.tp_axis)

    def __exit__(self, *a):
        clear_activation_context()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x, kinds) -> jax.Array:
    """kinds: tuple of 'dp' | 'tp' | None, one per dim of x (may be shorter;
    missing dims are unconstrained)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    entries = []
    for i, dim in enumerate(x.shape):
        kind = kinds[i] if i < len(kinds) else None
        ax = None
        if kind == "dp" and _CTX["dp"]:
            if dim % _axis_size(mesh, _CTX["dp"]) == 0:
                ax = _CTX["dp"] if len(_CTX["dp"]) > 1 else _CTX["dp"][0]
        elif kind == "tp" and _CTX["tp"]:
            if dim % _axis_size(mesh, _CTX["tp"]) == 0:
                ax = _CTX["tp"]
        entries.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
