"""Logical-axis -> mesh-axis sharding rules.

Every ParamSpec names its dims with logical axes; one rule table maps those
to mesh axes.  The rule engine is divisibility-aware: a rule only applies
when the dim is divisible by the mesh axis size (GSPMD would pad otherwise;
we allow padding ONLY for kv_heads, where 8-way KV on a 16-way model axis
is the intended production layout — see DESIGN.md §7).

Default layout (v5e (data=16, model=16), multi-pod adds a leading "pod" DP
axis):

  TP ("model"):   heads, kv_heads, ff, vocab, mamba d_inner, rwkv fused
                  heads, expert d_ff
  DP ("pod","data"): batch dim of every activation / input
  ZeRO-3 ("data"): MoE expert dim E (weights FSDP-gathered per layer) and,
                  when ``zero3=True``, any largest-dim of dense params
  SP:             KV-cache seq dim stays unsharded by default (hillclimb
                  variant shards it with flash-decode combine)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.specs import ParamSpec, map_logical, tree_paths

__all__ = ["ParallelismConfig", "abstract_mesh", "logical_to_pspec",
           "param_shardings", "batch_shardings", "cache_shardings",
           "opt_shardings"]


def abstract_mesh(axis_sizes, axis_names) -> "jax.sharding.AbstractMesh":
    """Version-portable AbstractMesh: newer jax takes (sizes, names), jax
    0.4.x takes a tuple of (name, size) pairs.  Rules only read mesh shape,
    so an abstract mesh is all the engine ever needs."""
    AM = jax.sharding.AbstractMesh
    try:
        return AM(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AM(tuple(zip(axis_names, axis_sizes)))


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    """Per-run parallelism policy (independent of the model config)."""
    zero3: bool = False          # FSDP dense params over "data"
    zero1_moments: bool = True   # shard optimizer moments over "data" too
    shard_kv_cache_time: bool = True  # time-shard decode caches when kv%model!=0
    experts_fsdp: bool = True    # MoE expert dim over "data" (ZeRO-3 style)
    compressed_dp: bool = False  # int8 compressed DP grad reduction (beyond-paper)


# rule table: logical axis -> preferred mesh axis (in priority order)
_TP_RULES = {
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "inner": "model",       # mamba d_inner
    "inner2": "model",      # mamba in_proj fused (2*d_inner)
    "heads_d": "model",     # rwkv fused H*D
    "experts_r": None,      # router output: small, replicated
    "embed": None,          # activations replicated over model between layers
    "embed_o": None,
    "layers": None,         # scan dim
}


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % mesh.shape[axis] == 0


def logical_to_pspec(spec: ParamSpec, mesh: Mesh, pcfg: ParallelismConfig) -> P:
    """One ParamSpec -> PartitionSpec under the rule table."""
    entries: list = []
    used = set()
    for dim, ax in zip(spec.shape, spec.axes):
        target: Optional[str] = None
        if ax == "experts" and pcfg.experts_fsdp and "data" in mesh.axis_names:
            target = "data"
        else:
            rule = _TP_RULES.get(ax)
            if rule and rule in mesh.axis_names and rule not in used:
                # strict divisibility: pjit rejects padded in_shardings, so
                # e.g. kv=8 heads or H=40 on a 16-way model axis fall back to
                # replication (decode caches re-shard over time instead; the
                # seq-parallel attention variant is the hillclimb lever).
                if _divisible(dim, mesh, rule):
                    target = rule
        if target:
            used.add(target)
        entries.append(target)
    # optional ZeRO-3 for dense params: shard the largest unsharded dim
    # over "data" (divisible only — padding a ZeRO gather wastes real bytes)
    if pcfg.zero3 and "data" in mesh.axis_names and "data" not in used \
            and "experts" not in spec.axes and len(spec.shape) >= 2:
        cands = sorted(
            (i for i, e in enumerate(entries)
             if e is None and _divisible(spec.shape[i], mesh, "data")
             and spec.axes[i] != "layers"),
            key=lambda i: -spec.shape[i])
        if cands:
            entries[cands[0]] = "data"
    return P(*entries)


def _ns(mesh, pspec):
    return NamedSharding(mesh, pspec)


def param_shardings(model, mesh: Mesh, pcfg: ParallelismConfig):
    """NamedSharding tree matching model.param_specs()."""
    return map_logical(model.param_specs(),
                       lambda s: _ns(mesh, logical_to_pspec(s, mesh, pcfg)))


def dp_spec(mesh: Mesh, dim: int):
    """The DP axes if ``dim`` divides evenly over them, else None (replicate
    — e.g. global_batch=1 long-context decode)."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if dim % size:
        return None
    return dp if len(dp) > 1 else dp[0]


def batch_shardings(mesh: Mesh, batch_tree):
    """Shard the leading (batch) dim of every input over all DP axes."""
    def one(x):
        ndim = len(x.shape)
        if not ndim:
            return _ns(mesh, P())
        return _ns(mesh, P(dp_spec(mesh, x.shape[0]), *([None] * (ndim - 1))))

    return jax.tree.map(one, batch_tree)


def cache_shardings(model, mesh: Mesh, pcfg: ParallelismConfig, cache_tree):
    """Decode-state shardings, keyed on the cache tree's own structure.

    * attention kv ("self"/"cross" -> k/v (G,B,T,KV,Dh)): batch over DP;
      kv_heads over model when divisible, otherwise the TIME dim is
      sharded over model — GSPMD then emits the flash-decode pattern
      (partial softmax + tiny all-reduces; verified, DESIGN.md §7) and the
      dynamic cache update stays sharded.
    * mamba ("ssm_state" -> conv (G,B,K-1,di) / ssm (G,B,di,n)): d_inner
      over model.
    * rwkv ("tm_state" (G,B,H,Dk,Dv)): heads over model;
      shift states (G,B,d): d over model.
    Divisibility-gated except kv_heads (see above)."""
    msize = mesh.shape["model"]

    def shard_dim(shape, i):
        return "model" if shape[i] % msize == 0 else None

    def one(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        shape = leaf.shape
        dp = dp_spec(mesh, shape[1])   # dim 1 = batch (dim 0 = scan groups)
        if "self" in keys or "cross" in keys:      # (G,B,T,KV,Dh)
            if shape[3] % msize == 0:              # kv heads shard evenly
                return _ns(mesh, P(None, dp, None, "model", None))
            if pcfg.shard_kv_cache_time and shape[2] % msize == 0:
                return _ns(mesh, P(None, dp, "model", None, None))
            return _ns(mesh, P(None, dp, None, None, None))
        if "conv" in keys:                          # (G,B,K-1,di)
            return _ns(mesh, P(None, dp, None, shard_dim(shape, 3)))
        if "ssm" in keys:                           # (G,B,di,n)
            return _ns(mesh, P(None, dp, shard_dim(shape, 2), None))
        if "tm_state" in keys:                      # (G,B,H,Dk,Dv)
            return _ns(mesh, P(None, dp, shard_dim(shape, 2), None, None))
        if len(shape) == 3:                         # shifts (G,B,d)
            return _ns(mesh, P(None, dp, shard_dim(shape, 2)))
        return _ns(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def opt_shardings(model, mesh: Mesh, pcfg: ParallelismConfig):
    """Adam moments: like params, plus ZeRO-1 sharding of the largest
    still-unsharded divisible dim over "data"."""
    def one(spec: ParamSpec):
        ps = logical_to_pspec(spec, mesh, pcfg)
        entries = list(ps) + [None] * (len(spec.shape) - len(ps))
        if pcfg.zero1_moments and "data" in mesh.axis_names \
                and "data" not in [e for e in entries if e]:
            cands = sorted(
                (i for i, e in enumerate(entries)
                 if e is None and spec.shape[i] % mesh.shape["data"] == 0),
                key=lambda i: -spec.shape[i])
            if cands:
                entries[cands[0]] = "data"
        return _ns(mesh, P(*entries))

    return map_logical(model.param_specs(), one)
