"""repro.parallel — sharding rules (DP/TP/EP/SP/ZeRO), parallelism policy,
and the compressed-collective path (paper-derived, see DESIGN.md §2)."""

from .sharding import (ParallelismConfig, param_shardings, batch_shardings,
                       cache_shardings, opt_shardings, logical_to_pspec)

__all__ = ["ParallelismConfig", "param_shardings", "batch_shardings",
           "cache_shardings", "opt_shardings", "logical_to_pspec"]
