"""Anti-entropy replica repair — converge damaged/divergent replicas.

PR 7 left cross-replica damage as a *read-side* workaround: a client
whose basket fails its checksum refetches from another replica, and
mismatched replicas raise :exc:`ReplicaMismatchError`.  The disk damage
stayed.  This module is the write-side fix:

* :func:`diff_catalogs` — compare per-basket ``(checksum, orig_len,
  entry_start)`` across replica catalogs (the same fields the client's
  compat check trusts) and name every basket where they disagree.

* :func:`repair_replica` — heal one local replica using its peers:

  1. scrub the local container (parity heals what parity can);
  2. for baskets parity could **not** heal (double-damaged stripes, no
     sidecar), pull the original payload bytes from a peer whose catalog
     checksum matches the local TOC, decode-verify, and patch them back
     in place — same inode, readers stay valid;
  3. for baskets whose *TOC metadata itself* diverges across replicas,
     pick the majority version (deterministic tie-break, so every
     replica independently converges to the same winner), pull the
     winning payloads, and rewrite the container through the PR 7
     atomic-commit path (tmp → fsync → rename → dir fsync), regenerating
     the parity sidecar if the replica had one.

Nothing is ever written that did not first decode and match the checksum
it claims — a lying peer can fail a repair, never corrupt a replica.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence

from repro.core.basket import BasketMeta, unpack_basket
from repro.core.bfile import BasketFile, BasketWriter

from .scrub import scrub_container
from .stripe import parity_path

__all__ = ["diff_catalogs", "repair_replica"]


def _counter(name: str, n: int = 1) -> None:
    try:
        from repro import obs
        obs.counter(name).inc(n)
    except Exception:
        pass


def _basket_key(meta: dict) -> tuple:
    """The content identity of one basket — what replicas must agree on.
    Offsets and wire compression are *not* identity (a replica may be
    repacked); decoded bytes are."""
    return (int(meta["checksum"]), int(meta["orig_len"]),
            int(meta["entry_start"]), int(meta["entry_count"]))


def diff_catalogs(catalogs: dict) -> list[dict]:
    """Per-basket disagreements across replica catalogs.

    ``catalogs`` maps a replica label (endpoint, path, anything hashable)
    to its ``branches`` dict (the CATALOG / TOC shape).  Returns one
    record per basket where any replica's content key differs::

        [{"branch", "index", "keys": {label: (checksum, orig_len,
          entry_start, entry_count) | None}}, ...]

    ``None`` marks a replica missing that branch/basket entirely.
    """
    all_branches: set[str] = set()
    for bs in catalogs.values():
        all_branches.update(bs)
    out = []
    for name in sorted(all_branches):
        depth = max(len(bs.get(name, {}).get("baskets", []))
                    for bs in catalogs.values())
        for i in range(depth):
            keys = {}
            for label, bs in catalogs.items():
                baskets = bs.get(name, {}).get("baskets", [])
                keys[label] = _basket_key(baskets[i]["meta"]) \
                    if i < len(baskets) else None
            if len(set(keys.values())) > 1:
                out.append({"branch": name, "index": i, "keys": keys})
    return out


def _quorum_key(keys: dict) -> tuple:
    """The winning content key: majority vote, ties broken by the
    smallest key tuple — a pure function of the vote set, so every
    replica running reconcile independently picks the same winner."""
    votes: dict[tuple, int] = {}
    for k in keys.values():
        if k is not None:
            votes[k] = votes.get(k, 0) + 1
    return min(votes, key=lambda k: (-votes[k], k))


class _Peer:
    """One remote replica: lazy client + verified payload pulls."""

    def __init__(self, host: str, port: int, path: str,
                 timeout: float):
        self.ep = (str(host), int(port))
        self.path = path
        self.timeout = timeout
        self._rf = None
        self.dead = False

    def open(self):
        if self._rf is None and not self.dead:
            from repro.remote.client import RemoteBasketFile
            try:
                # wire=None: payloads arrive as the peer's on-disk bytes,
                # exactly what gets patched/rewritten locally
                self._rf = RemoteBasketFile(
                    host=self.ep[0], port=self.ep[1], path=self.path,
                    wire=None, timeout=self.timeout, retries=3,
                    backoff=0.02)
            except Exception:
                self.dead = True
        return self._rf

    @property
    def branches(self) -> Optional[dict]:
        rf = self.open()
        return rf.branches if rf is not None else None

    def pull(self, branch: str, index: int, want_key: tuple,
             dictionary: Optional[bytes]) -> Optional[tuple[bytes, dict]]:
        """``(payload, meta_json)`` for one basket — only if this peer's
        catalog claims ``want_key`` *and* the bytes decode-verify to it."""
        rf = self.open()
        if rf is None:
            return None
        entry = rf.branches.get(branch)
        if entry is None or index >= len(entry["baskets"]):
            return None
        meta_json = entry["baskets"][index]["meta"]
        if _basket_key(meta_json) != want_key:
            return None
        try:
            pairs = rf.fetch_wire(branch, [index])
            payload, got_meta = pairs[0]
            meta = BasketMeta.from_json(got_meta)
            if _basket_key(got_meta) != want_key:
                return None
            unpack_basket(payload, meta, dictionary, verify=True)
            return bytes(payload), dict(got_meta)
        except Exception:
            return None

    def close(self) -> None:
        if self._rf is not None:
            try:
                self._rf.close()
            except Exception:
                pass
            self._rf = None


def repair_replica(local_path: str, path: str, endpoints: Sequence,
                   *, timeout: float = 10.0,
                   scrub_mbps: Optional[float] = None) -> dict:
    """Converge one local replica with its peers (see module docstring).

    ``local_path`` is the container on this host's disk; ``path`` is the
    name peers export it under (the RBSP catalog path); ``endpoints`` are
    ``(host, port)`` peers.  Returns a report::

        {"path", "scrub": {...}, "divergent", "pulled", "patched",
         "rewritten", "remaining": [[branch, index], ...], "converged"}

    ``remaining`` lists baskets still damaged after every source was
    tried — nonzero means the fleet has lost those bytes everywhere.
    """
    local_path = str(local_path)
    report = {"path": local_path, "divergent": 0, "pulled": 0,
              "patched": 0, "rewritten": False, "remaining": [],
              "converged": False}

    # 1. local scrub: parity heals what parity can, and names what it
    #    cannot (the pull list)
    scrub = scrub_container(local_path, heal=True, mbps=scrub_mbps)
    report["scrub"] = scrub
    if "error" in scrub:
        report["remaining"] = [["*", -1]]
        return report
    unhealable = [tuple(u) for u in scrub["unhealable"]]

    peers = [_Peer(h, p, path, timeout) for h, p in
             (tuple(e) for e in endpoints)]
    try:
        with BasketFile(local_path, verify=True) as bf:
            catalogs = {"local": bf.branches}
            for pr in peers:
                bs = pr.branches
                if bs is not None:
                    catalogs[pr.ep] = bs
            diverged = diff_catalogs(catalogs)
            report["divergent"] = len(diverged)
            _counter("repair.reconcile.divergent", len(diverged))

            # what each damaged/divergent basket *should* contain
            wanted: dict[tuple[str, int], tuple] = {}
            for name, i in unhealable:
                wanted[(name, i)] = _basket_key(
                    bf.branches[name]["baskets"][i]["meta"])
            losers: dict[tuple[str, int], tuple] = {}
            for d in diverged:
                key = _quorum_key(d["keys"])
                if d["keys"].get("local") != key:
                    losers[(d["branch"], d["index"])] = key
            wanted.update(losers)

            # 2. pull verified bytes for every wanted basket
            pulled: dict[tuple[str, int], tuple[bytes, dict]] = {}
            failed: list[tuple[str, int]] = []
            for (name, i), key in sorted(wanted.items()):
                entry = bf.branches.get(name, {})
                dictionary = bf._dictionary(entry) if entry else None
                got = None
                for pr in peers:
                    got = pr.pull(name, i, key, dictionary)
                    if got is not None:
                        break
                if got is None:
                    failed.append((name, i))
                else:
                    pulled[(name, i)] = got
                    report["pulled"] += 1
                    _counter("repair.reconcile.pulled")
            report["remaining"] = [list(t) for t in sorted(failed)]

            # 3a. same-metadata damage: patch in place (comp_len matches,
            #     the inode survives, open readers stay valid)
            in_place = {k: v for k, v in pulled.items() if k not in losers}
            if in_place:
                from repro.io import fdcache
                for (name, i), (payload, _meta) in sorted(in_place.items()):
                    b = bf.branches[name]["baskets"][i]
                    fdcache.patch(local_path, int(b["offset"]), payload,
                                  expect=bf.generation)
                    report["patched"] += 1
                    _counter("repair.reconcile.patched")

            # 3b. divergent metadata: the TOC itself must change — rewrite
            #     the whole container through the atomic-commit path
            to_rewrite = {k: v for k, v in pulled.items() if k in losers}
            if to_rewrite:
                k_parity = 0
                if os.path.exists(parity_path(local_path)):
                    from .stripe import ParitySidecar
                    try:
                        k_parity = ParitySidecar.load(
                            parity_path(local_path)).k
                    except Exception:
                        k_parity = 0
                with BasketWriter(local_path, parity=k_parity) as w:
                    for name in bf.branch_names():
                        entry = bf.branches[name]
                        baskets = []
                        for i, b in enumerate(entry["baskets"]):
                            if (name, i) in to_rewrite:
                                payload, meta_json = to_rewrite[(name, i)]
                            else:
                                payload = bf.read_basket_payload(name, i)
                                meta_json = b["meta"]
                            baskets.append((payload, meta_json))
                        w.write_precompressed(
                            name, dtype=entry["dtype"],
                            shape=entry["shape"],
                            config=entry["config"],
                            dictionary=entry.get("dictionary"),
                            baskets=baskets)
                report["rewritten"] = True
                _counter("repair.reconcile.rewritten")
    finally:
        for pr in peers:
            pr.close()

    # 4. the proof: a fresh scrub of the converged replica
    post = scrub_container(local_path, heal=True, mbps=scrub_mbps)
    report["post_scrub"] = post
    report["converged"] = (not report["remaining"]
                           and post.get("completed", False)
                           and not post.get("unhealable"))
    return report
