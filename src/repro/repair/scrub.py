"""Background scrubbing — find latent rot before a reader does.

:func:`scrub_container` walks every basket of one container through the
``repro.io.fdcache`` pread path (so the PR 7 disk-rot fault hook
exercises exactly what production reads exercise), decode-verifies each
against its stored adler32, and — when the container has a parity
sidecar — heals damage in place via ``BasketFile(heal="auto")``.

Two production concerns shape the API:

* **Byte-rate budget** (``mbps``): a scrubber shares spindles with live
  traffic, so it paces itself — after each basket it sleeps whatever
  keeps cumulative ``bytes / elapsed`` at or under the budget.  The
  budget counts *compressed* bytes read, which is what the device sees.

* **Resumable cursor** (``resume=True``): progress persists to a
  ``<container>.scrub`` sidecar (atomic tmp+replace, stamped with the
  container's content stamp) every few baskets, so a restarted process
  continues where the last one stopped instead of re-verifying from
  byte 0 — on a petabyte fleet a scrub pass takes days and restarts are
  routine.  A cursor stamped for different container content (the file
  was rewritten) is discarded.

:class:`Scrubber` is the server-side wrapper: a low-priority daemon
thread sweeping every ``*.bskt`` under a root, with ``status()`` /
``trigger()`` / ``scrub_now()`` hooks the RBSP ``SCRUB`` verb exposes.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

from repro.core.bfile import BasketFile, CorruptBasketError, \
    TruncatedContainerError

__all__ = ["scrub_container", "cursor_path", "Scrubber"]

MB = 1 << 20
_CURSOR_EVERY = 16          # baskets between cursor persists


def cursor_path(container_path: str) -> str:
    return str(container_path) + ".scrub"


def _counter(name: str, n: int = 1) -> None:
    try:
        from repro import obs
        obs.counter(name).inc(n)
    except Exception:
        pass


def _load_cursor(path: str, stamp: dict) -> Optional[tuple[str, int]]:
    """The persisted ``(branch, next_index)`` position, or ``None`` for a
    missing/undecodable cursor or one stamped for different content."""
    try:
        with open(cursor_path(path)) as f:
            cur = json.load(f)
    except (OSError, ValueError):
        return None
    if cur.get("stamp") != stamp or cur.get("done"):
        return None
    br, idx = cur.get("branch"), cur.get("index")
    if not isinstance(br, str) or not isinstance(idx, int):
        return None
    return br, idx


def _save_cursor(path: str, stamp: dict, branch: Optional[str], index: int,
                 done: bool = False) -> None:
    cpath = cursor_path(path)
    tmp = cpath + ".tmp"
    doc = {"stamp": stamp, "branch": branch, "index": int(index),
           "done": bool(done), "saved_at": time.time()}
    try:
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, cpath)
    except OSError:
        try:
            os.remove(tmp)
        except OSError:
            pass


def scrub_container(path: str, *, heal: bool = True,
                    mbps: Optional[float] = None, resume: bool = True,
                    max_baskets: Optional[int] = None) -> dict:
    """Verify (and optionally heal) every basket of one container.

    Returns a report::

        {"path", "baskets", "bytes", "corrupt", "healed",
         "unhealable": [[branch, index], ...], "resumed", "completed"}

    ``corrupt`` counts baskets whose first verified read failed (the
    damage the scrub *found*); ``healed`` counts those repaired in place
    from parity.  ``max_baskets`` stops early (cursor persisted, resumable
    — also how the restart test simulates a killed scrubber).  A torn
    container (unreadable TOC) is reported, not raised::

        {"path", "error": "...", "completed": False, ...}
    """
    path = str(path)
    report = {"path": path, "baskets": 0, "bytes": 0, "corrupt": 0,
              "healed": 0, "unhealable": [], "resumed": False,
              "completed": False}
    try:
        bf = BasketFile(path, heal="auto" if heal else None)
    except (TruncatedContainerError, ValueError, OSError) as e:
        report["error"] = str(e)
        return report
    t0 = time.monotonic()
    stopped = False
    with bf:
        stamp = bf._content_stamp
        names = sorted(bf.branch_names())
        start = _load_cursor(path, stamp) if resume else None
        if start is not None:
            report["resumed"] = True
        skipping = start is not None
        since_save = 0
        for name in names:
            if skipping and name != start[0]:
                continue
            baskets = bf.branches[name]["baskets"]
            first = 0
            if skipping:
                first, skipping = start[1], False
            for i in range(first, len(baskets)):
                if max_baskets is not None and \
                        report["baskets"] >= max_baskets:
                    stopped = True
                    break
                comp_len = int(baskets[i]["meta"]["comp_len"])
                healed_before = bf.heal_stats["healed"]
                ok_first = bf._try_decode(name, i) is not None
                if not ok_first:
                    report["corrupt"] += 1
                    _counter("repair.scrub.corrupt")
                    if heal:
                        try:
                            bf._heal_basket(name, i)
                        except CorruptBasketError:
                            report["unhealable"].append([name, i])
                    else:
                        report["unhealable"].append([name, i])
                report["healed"] += bf.heal_stats["healed"] - healed_before
                report["baskets"] += 1
                report["bytes"] += comp_len
                _counter("repair.scrub.baskets")
                _counter("repair.scrub.bytes", comp_len)
                since_save += 1
                if since_save >= _CURSOR_EVERY:
                    _save_cursor(path, stamp, name, i + 1)
                    since_save = 0
                if mbps:
                    # pace: sleep until cumulative rate is back under budget
                    ahead = report["bytes"] / (mbps * MB) \
                        - (time.monotonic() - t0)
                    if ahead > 0:
                        time.sleep(min(ahead, 0.5))
            if stopped:
                # persist exactly where the next run must resume (basket
                # ``i`` was not processed — the break precedes the read)
                _save_cursor(path, stamp, name, i)
                break
        if not stopped:
            _save_cursor(path, stamp, None, 0, done=True)
            report["completed"] = True
    report["healed_total"] = report["healed"]
    _counter("repair.scrub.healed", report["healed"])
    return report


class Scrubber:
    """The server's background scrub loop (one daemon thread).

    Sweeps every ``*.bskt`` under ``root`` at the byte-rate budget,
    then sleeps ``interval`` seconds and sweeps again.  Low priority by
    construction: the budget paces disk reads, and each basket holds the
    heal lock only as long as a foreground heal would.  ``trigger()``
    wakes the loop immediately (the RBSP SCRUB verb); ``status()`` is a
    JSON-safe snapshot; ``scrub_now()`` runs synchronously on the
    caller's thread (the one-shot CLI / test path)."""

    def __init__(self, root: str, *, mbps: Optional[float] = None,
                 heal: bool = True, interval: float = 30.0):
        self.root = os.path.abspath(root)
        self.mbps = mbps
        self.heal = heal
        self.interval = float(interval)
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._state = {"sweeps": 0, "containers": 0, "baskets": 0,
                       "bytes": 0, "corrupt": 0, "healed": 0,
                       "unhealable": 0, "running": False, "current": None}
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-scrubber", daemon=True)
        self._thread.start()

    def _containers(self) -> list[str]:
        out = []
        for dirpath, _dirs, files in os.walk(self.root):
            for fn in sorted(files):
                if fn.endswith(".bskt"):
                    out.append(os.path.join(dirpath, fn))
        return sorted(out)

    def _sweep(self) -> None:
        for cpath in self._containers():
            if self._stop.is_set():
                return
            with self._lock:
                self._state["current"] = os.path.relpath(cpath, self.root)
            rep = scrub_container(cpath, heal=self.heal, mbps=self.mbps)
            with self._lock:
                self._state["containers"] += 1
                for k in ("baskets", "bytes", "corrupt", "healed"):
                    self._state[k] += rep.get(k, 0)
                self._state["unhealable"] += len(rep.get("unhealable", []))
        with self._lock:
            self._state["sweeps"] += 1
            self._state["current"] = None

    def _loop(self) -> None:
        with self._lock:
            self._state["running"] = True
        while not self._stop.is_set():
            try:
                self._sweep()
            except Exception:
                pass                 # a scrub crash must never kill a server
            self._wake.wait(self.interval)
            self._wake.clear()
        with self._lock:
            self._state["running"] = False

    def trigger(self) -> None:
        """Start the next sweep now instead of after ``interval``."""
        self._wake.set()

    def scrub_now(self, path: Optional[str] = None) -> list[dict]:
        """Synchronous scrub of one container (path relative to root) or
        every container — the SCRUB verb's ``sync`` action."""
        if path is not None:
            return [scrub_container(os.path.join(self.root, path),
                                    heal=self.heal, mbps=self.mbps)]
        return [scrub_container(c, heal=self.heal, mbps=self.mbps)
                for c in self._containers()]

    def status(self) -> dict:
        with self._lock:
            return dict(self._state)

    def close(self) -> None:
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout=10)
