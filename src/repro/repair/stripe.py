"""Parity sidecars — XOR stripe protection for basket containers.

A container written with ``BasketWriter(parity=k)`` gets a
``<container>.parity`` sidecar: baskets are grouped, in write order, into
k-wide *stripes*, and each stripe's parity is the byte-wise XOR of its
member payloads (each zero-padded to the longest member).  Any **one**
damaged member of a stripe can then be reconstructed from its peers plus
the parity blob — without a second replica, without re-deriving the data.
The container's own bytes are untouched (golden-pinned): parity is a
sidecar, never part of the format.

Sidecar layout (mirrors the container's trailer convention)::

    [8B magic "RPARv001"][parity blobs...]
    [zlib(header JSON)][8B header_len][8B magic]

The header JSON is zlib-compressed (it mirrors the container's whole
branch TOC — on a well-compressed container the raw JSON alone would eat
a visible slice of the 1/k byte budget).

The header carries:

* ``k`` and the stripe map — for each stripe, its member ``(branch,
  index)`` list, the parity blob's offset/length, and an adler32 of the
  blob (a rotted parity read must fail loudly, not reconstruct garbage);
* a **generation stamp** ``{"size", "toc_adler"}`` of the committed
  container — content-derived (not inode-derived), so it stays valid for
  byte-identical replica copies and survives in-place heals, but refuses
  to describe a container that was rewritten;
* a full mirror of the container's branch TOC — the alternative boundary
  source :func:`repro.core.bfile.recover_container` uses when a torn
  container has no write journal.

Reconstruction never trusts anything it cannot verify: every peer payload
must decode and match its stored raw adler32, the parity blob must match
its stored adler32, and the reconstructed payload must decode and match
the *target's* stored adler32 before it is returned.  A stripe with two
damaged members is unhealable here (single parity) — that is what the
anti-entropy replica repair (:mod:`repro.repair.reconcile`) is for.
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Callable, Optional

import numpy as np

from repro.core.checksum import adler32_hw

__all__ = ["ParityWriter", "ParitySidecar", "parity_path", "content_stamp",
           "ParityError"]

MAGIC = b"RPARv001"


class ParityError(ValueError):
    """The parity sidecar is missing, torn, stamped for a different
    container generation, or its blobs fail their own checksums."""


def parity_path(container_path: str) -> str:
    """The sidecar path for ``container_path`` (a leftover ``*.tmp`` from
    a crashed writer shares its final path's sidecar)."""
    p = str(container_path)
    if p.endswith(".tmp"):
        p = p[:-4]
    return p + ".parity"


def content_stamp(size: int, toc_bytes: bytes) -> dict:
    """The content-derived generation stamp binding a sidecar to the
    container bytes it describes.  Derived from the committed file size
    and the TOC's adler32 — identical for byte-identical replicas, and
    unchanged by an in-place basket heal (which restores original bytes),
    but different for any rewritten/re-tuned container."""
    return {"size": int(size), "toc_adler": int(adler32_hw(toc_bytes))}


def _xor_into(acc: bytearray, payload) -> None:
    """acc[:len(payload)] ^= payload, growing ``acc`` as needed."""
    buf = np.frombuffer(payload, dtype=np.uint8)
    if buf.size > len(acc):
        acc.extend(b"\0" * (buf.size - len(acc)))
    a = np.frombuffer(acc, dtype=np.uint8)
    a[:buf.size] ^= buf


class ParityWriter:
    """Accumulates k-wide XOR stripes while a container streams out.

    ``add`` is called once per basket payload in container write order;
    completed stripes spool to ``path + ".tmp"`` immediately (one stripe
    accumulator of memory, never the whole parity set), and ``commit``
    writes the header trailer and atomically renames the sidecar into
    place — called only *after* the container itself commits, so a crash
    can never leave a sidecar describing bytes that were never published.
    """

    def __init__(self, path: str, k: int = 8):
        if int(k) < 2:
            raise ValueError(f"parity stripe width must be >= 2, got {k}")
        self.path = str(path)
        self.k = int(k)
        self._tmp = self.path + ".tmp"
        self._f = open(self._tmp, "wb")
        self._f.write(MAGIC)
        self._stripes: list[dict] = []
        self._members: list[list] = []      # current stripe's (branch, idx)
        self._acc = bytearray()
        self._closed = False

    def add(self, branch: str, index: int, payload) -> None:
        """Fold one basket payload into the current stripe."""
        _xor_into(self._acc, payload)
        self._members.append([str(branch), int(index)])
        if len(self._members) >= self.k:
            self._flush_stripe()

    def _flush_stripe(self) -> None:
        if not self._members:
            return
        blob = bytes(self._acc)
        off = self._f.tell()
        self._f.write(blob)
        self._stripes.append({"off": off, "len": len(blob),
                              "adler": int(adler32_hw(blob)),
                              "members": self._members})
        self._members = []
        self._acc = bytearray()

    def commit(self, branches: dict, stamp: dict, container: str) -> None:
        """Seal the sidecar: flush the partial tail stripe, append the
        header (stripe map + TOC mirror + stamp), fsync, atomic rename."""
        if self._closed:
            return
        self._flush_stripe()
        header = {
            "container": os.path.basename(container),
            "k": self.k,
            "stamp": dict(stamp),
            "stripes": self._stripes,
            "branches": branches,
        }
        try:
            hj = zlib.compress(
                json.dumps(header, sort_keys=True).encode(), 6)
            self._f.write(hj)
            self._f.write(len(hj).to_bytes(8, "little"))
            self._f.write(MAGIC)
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            os.replace(self._tmp, self.path)
        except BaseException:
            self.abort()
            raise
        self._closed = True
        from repro.core.bfile import _fsync_dir
        _fsync_dir(os.path.dirname(os.path.abspath(self.path)))

    def abort(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._f.close()
        except OSError:
            pass
        try:
            os.remove(self._tmp)
        except OSError:
            pass


class ParitySidecar:
    """Parsed sidecar: the stripe map plus verified parity blob access.

    Loading parses only the trailer header; parity blobs are pread on
    demand through ``repro.io.fdcache`` (so the same staleness/fault
    machinery that covers basket reads covers parity reads)."""

    def __init__(self, path: str, header: dict):
        self.path = str(path)
        self.k = int(header["k"])
        self.stamp = dict(header.get("stamp") or {})
        self.container = header.get("container", "")
        self.stripes = header["stripes"]
        self.branches = header.get("branches") or {}
        self._by_member: dict[tuple[str, int], int] = {}
        for si, s in enumerate(self.stripes):
            for br, idx in s["members"]:
                self._by_member[(str(br), int(idx))] = si
        self._lock = threading.Lock()

    @classmethod
    def load(cls, path: str) -> "ParitySidecar":
        """Parse the sidecar trailer; raises :class:`ParityError` for a
        missing, torn, or undecodable sidecar."""
        try:
            size = os.path.getsize(path)
        except OSError as e:
            raise ParityError(f"{path}: no parity sidecar ({e})") from None
        try:
            with open(path, "rb") as f:
                if f.read(8) != MAGIC or size < 8 + 16:
                    raise ParityError(f"{path}: bad parity magic/size")
                f.seek(-16, os.SEEK_END)
                hlen = int.from_bytes(f.read(8), "little")
                if f.read(8) != MAGIC:
                    raise ParityError(f"{path}: torn parity trailer")
                if not 2 <= hlen <= size - 24:
                    raise ParityError(f"{path}: parity header length {hlen} "
                                      f"inconsistent with size {size}")
                f.seek(-16 - hlen, os.SEEK_END)
                header = json.loads(zlib.decompress(f.read(hlen)))
        except ParityError:
            raise
        except (OSError, ValueError, zlib.error) as e:
            raise ParityError(f"{path}: unreadable parity sidecar "
                              f"({e})") from None
        return cls(path, header)

    def check_stamp(self, size: int, toc_bytes: bytes) -> None:
        """Refuse to describe a container whose bytes this sidecar was not
        written for (rewritten, re-tuned, or swapped underneath)."""
        want = content_stamp(size, toc_bytes)
        if self.stamp != want:
            raise ParityError(
                f"{self.path}: stamp {self.stamp} does not match the "
                f"container's current content {want} — the container was "
                "rewritten since parity was computed")

    def stripe_of(self, branch: str, index: int) -> Optional[dict]:
        si = self._by_member.get((str(branch), int(index)))
        return self.stripes[si] if si is not None else None

    def covers(self, branch: str, index: int) -> bool:
        return (str(branch), int(index)) in self._by_member

    def _parity_blob(self, stripe: dict) -> bytes:
        from repro.io import fdcache
        blob = fdcache.pread(self.path, int(stripe["off"]),
                             int(stripe["len"]))
        if adler32_hw(blob) != int(stripe["adler"]):
            raise ParityError(
                f"{self.path}: parity blob at {stripe['off']} fails its "
                "checksum (rotted parity)")
        return blob

    def reconstruct(self, branch: str, index: int, comp_len: int,
                    read_peer: Callable[[str, int], bytes],
                    verify_peer: Callable[[str, int, bytes], bool]) -> bytes:
        """Rebuild one damaged member's on-disk payload from its stripe.

        ``read_peer(branch, index)`` returns a peer's on-disk payload
        bytes; ``verify_peer(branch, index, payload)`` must confirm the
        payload decodes to its stored raw adler32 — an unverified peer
        would XOR its own damage straight into the reconstruction.
        Raises :class:`ParityError` when the stripe cannot vouch for the
        target (no stripe, a damaged peer, rotted parity)."""
        stripe = self.stripe_of(branch, index)
        if stripe is None:
            raise ParityError(
                f"{self.path}: no stripe covers ({branch!r}, {index})")
        acc = bytearray(self._parity_blob(stripe))
        for br, idx in stripe["members"]:
            br, idx = str(br), int(idx)
            if (br, idx) == (str(branch), int(index)):
                continue
            peer = read_peer(br, idx)
            if not verify_peer(br, idx, peer):
                raise ParityError(
                    f"{self.path}: stripe peer ({br!r}, {idx}) is itself "
                    "damaged — single parity cannot heal two members")
            _xor_into(acc, peer)
        if comp_len > len(acc):
            raise ParityError(
                f"{self.path}: stripe blob shorter than target payload "
                f"({len(acc)} < {comp_len})")
        return bytes(acc[:comp_len])
