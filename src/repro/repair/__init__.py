"""repro.repair — self-healing storage (DESIGN.md §15).

Three layers over the container + remote fleet:

* :mod:`repro.repair.stripe` — XOR parity sidecars written alongside a
  container (``BasketWriter(parity=k)``) and the reconstruction math a
  ``BasketFile(heal="auto")`` uses to rebuild a rotted basket in place.
* :mod:`repro.repair.scrub` — the background scrubber: verify every
  basket checksum at a byte-rate budget, heal from parity, persist a
  resumable per-container cursor.
* :mod:`repro.repair.reconcile` — anti-entropy replica repair: diff
  per-basket checksums across replicas via CATALOG and pull good bytes
  from a healthy peer to converge a damaged one.
"""

from .stripe import (ParityError, ParitySidecar, ParityWriter, content_stamp,
                     parity_path)
from .scrub import Scrubber, scrub_container
from .reconcile import diff_catalogs, repair_replica

__all__ = [
    "ParityError", "ParitySidecar", "ParityWriter", "content_stamp",
    "parity_path", "Scrubber", "scrub_container", "diff_catalogs",
    "repair_replica",
]
