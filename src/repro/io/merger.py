"""Buffer merging — the TBufferMerger analogue.

ROOT's ``TBufferMerger`` lets N producer tasks fill in-memory ``TTree``
buffers (compressing as they go, in parallel) while a single sequential
writer drains them into one output file, so the file format's single-writer
invariant never serializes *compression*.  Here:

* ``BasketBuffer`` — an in-memory branch set: producers call
  ``write_branch`` exactly like ``BasketWriter``, but payloads land in RAM
  (optionally compressed through a shared ``CompressionEngine``).

* ``BufferMerger`` — wraps one ``BasketWriter`` and a lock; ``merge(buf)``
  appends a buffer's pre-compressed payloads to the file **without
  recompression** and records the branch TOC entries.  Producers on
  different threads interleave merges safely; the atomic tmp-then-rename
  commit of ``BasketWriter`` is preserved, so a crash mid-merge still
  leaves no valid trailer.

* ``merge_files`` — the ``hadd -ff``-style fast merge: splices existing
  BasketFiles into one output by copying compressed payloads byte-for-byte.

Used by the checkpointer for parallel shard writes (each producer thread
compresses its slice of the train state) and by any multi-writer pipeline
that wants one artifact out the other end.
"""

from __future__ import annotations

import base64
import itertools
import threading
from typing import Iterable, Optional

import numpy as np

from repro.core.basket import split_array
from repro.core.bfile import BasketFile, BasketWriter
from repro.core.codec import CompressionConfig

from .engine import CompressionEngine

__all__ = ["BasketBuffer", "BufferMerger", "merge_files"]


class BasketBuffer:
    """In-memory compressed branch set, filled by one producer."""

    def __init__(self, engine: Optional[CompressionEngine] = None,
                 tuner=None):
        self._engine = engine
        self._tuner = tuner
        self._branches: dict[str, dict] = {}   # name -> TOC-entry skeleton
        self._payloads: dict[str, list[bytes]] = {}

    def write_branch(self, name: str, arr: np.ndarray,
                     cfg: Optional[CompressionConfig] = None,
                     target_basket_bytes: int = 1 << 20) -> dict:
        arr = np.asarray(arr)
        if cfg is None and self._tuner is not None:
            cfg = self._tuner.config_for(name, arr)
        return self.write_branch_chunks(
            name, dtype=arr.dtype.str, shape=arr.shape,
            chunks=split_array(arr, target_basket_bytes), cfg=cfg)

    def write_branch_chunks(self, name: str, *, dtype, shape, chunks,
                            cfg: Optional[CompressionConfig] = None) -> dict:
        """Buffer a branch from a ``(entry_start, entry_count, buffer)``
        chunk stream (the producers>1 checkpoint staging path)."""
        if name in self._branches:
            raise ValueError(f"branch {name!r} already buffered")
        if cfg is None and self._tuner is not None:
            it = iter(chunks)
            first = next(it, None)
            if first is not None:
                cfg = self._tuner.config_for(
                    name, first[2], dtype=np.dtype(dtype))
                chunks = itertools.chain([first], it)
        cfg = cfg or CompressionConfig()
        # CompressionEngine(0) is the serial path — no pools, same stream
        packed = (self._engine or CompressionEngine(0)).pack_stream(chunks, cfg)
        payloads, baskets = [], []
        for _start, _count, payload, meta in packed:
            if self._tuner is not None:
                self._tuner.observe(name, meta)
            # pack_stream payloads are only valid until the next iteration
            # (slab transport / zero-copy identity path) — the buffer
            # retains them, so it must own the bytes
            payloads.append(payload if isinstance(payload, bytes)
                            else bytes(payload))
            baskets.append({"meta": meta.to_json()})
        entry = {
            "dtype": np.dtype(dtype).str,
            "shape": list(shape),
            "config": {"algo": cfg.algo, "level": cfg.level,
                       "precond": cfg.precond},
            "dictionary": base64.b64encode(cfg.dictionary).decode()
                          if cfg.dictionary else None,
            "baskets": baskets,
        }
        self._branches[name] = entry
        self._payloads[name] = payloads
        return entry

    def write_blob(self, name: str, raw: bytes,
                   cfg: Optional[CompressionConfig] = None) -> None:
        self.write_branch(name, np.frombuffer(raw, dtype=np.uint8), cfg)

    def branch_names(self) -> list[str]:
        return list(self._branches)

    def nbytes(self) -> int:
        return sum(len(p) for ps in self._payloads.values() for p in ps)

    def clear(self) -> None:
        self._branches.clear()
        self._payloads.clear()


class BufferMerger:
    """One output file, many producers; merges are serialized by a lock."""

    def __init__(self, path: str, workers: int = 0,
                 engine: Optional[CompressionEngine] = None,
                 tuner=None, objective=None, parity: int = 0):
        self._engine = engine
        self._owns_engine = False
        if engine is None and workers:
            self._engine = CompressionEngine(workers)
            self._owns_engine = True
        if tuner is None and objective is not None:
            from repro.tune import Tuner
            tuner = Tuner(objective, engine=self._engine)
        self._tuner = tuner
        # the writer carries the tuner so merged branches' decisions
        # persist in the output TOC (Tuner.config_for is thread-safe —
        # producers tune concurrently, per-branch decisions serialize)
        self._writer = BasketWriter(path, tuner=tuner, parity=parity)
        self._lock = threading.Lock()

    def buffer(self) -> BasketBuffer:
        """A fresh producer-side buffer wired to the shared engine."""
        return BasketBuffer(engine=self._engine, tuner=self._tuner)

    def merge(self, buf: BasketBuffer, clear: bool = True) -> None:
        """Append ``buf``'s pre-compressed baskets to the file (no
        recompression); thread-safe."""
        with self._lock:
            for name, entry in buf._branches.items():
                self._writer.write_precompressed(
                    name,
                    dtype=entry["dtype"], shape=entry["shape"],
                    config=entry["config"], dictionary=entry["dictionary"],
                    baskets=zip(buf._payloads[name],
                                (b["meta"] for b in entry["baskets"])))
        if clear:
            buf.clear()

    def write_branch(self, name: str, arr: np.ndarray,
                     cfg: Optional[CompressionConfig] = None,
                     target_basket_bytes: int = 1 << 20) -> None:
        """Convenience: buffer + merge one branch in a single call."""
        buf = self.buffer()
        buf.write_branch(name, arr, cfg, target_basket_bytes)
        self.merge(buf)

    def close(self) -> None:
        self._writer.close()
        if self._owns_engine:
            self._engine.close()

    def abort(self) -> None:
        self._writer.abort()
        if self._owns_engine:
            self._engine.close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *a):
        if exc_type is None:
            self.close()
        else:
            self.abort()


def merge_files(out_path: str, in_paths: Iterable[str],
                rename: Optional[callable] = None) -> None:
    """Fast merge: splice whole BasketFiles into one output by copying
    compressed payloads (no decompress/recompress round-trip).

    ``rename(path, branch) -> str`` maps input branch names onto output
    names (defaults to identity; duplicate output names are an error).
    """
    with BasketWriter(out_path) as w:
        for path in in_paths:
            f = BasketFile(path, verify=False)
            with open(path, "rb") as fh:   # one handle per input, not per basket
                def payloads(entry):
                    for b in entry["baskets"]:
                        fh.seek(b["offset"])
                        yield fh.read(b["meta"]["comp_len"]), b["meta"]

                for name in f.branch_names():
                    entry = f.branches[name]
                    out_name = rename(path, name) if rename else name
                    w.write_precompressed(
                        out_name,
                        dtype=entry["dtype"], shape=entry["shape"],
                        config=entry["config"],
                        dictionary=entry["dictionary"],
                        baskets=payloads(entry))
