"""Shared-memory slab pool — zero-pickle transport for process-pool codecs.

The pure-Python (GIL-holding) codecs run on a ``ProcessPoolExecutor``.  The
naive transport pickles the raw basket out and the payload back: each
direction is a serialize + pipe-write + pipe-read + deserialize of the full
buffer, chunked through a 64 KiB OS pipe.  This module replaces both
directions with a pool of pre-mapped ``multiprocessing.shared_memory``
slabs:

* the parent memcpys the raw chunk into a slab and submits only the slab
  *name* (a few bytes of pickle);
* the worker attaches the slab once (cached per process), reads the input
  in place, and — since the input is dead once the codec has run — writes
  the payload back over the same slab, returning just its length;
* the parent hands the payload slice to the file writer (``write()`` takes
  the memoryview directly) and recycles the slab.

Slabs are sized with headroom for incompressible payloads; a payload that
still doesn't fit falls back to the pickle path transparently, as does the
whole transport when ``/dev/shm`` is unavailable (``available()``).

Worker-side attachments deregister from ``resource_tracker`` — the parent
created the segments and owns their lifetime; without the deregistration a
worker exit would unlink slabs the parent is still using (bpo-39959).
"""

from __future__ import annotations

import threading
from typing import Optional

__all__ = ["SlabPool", "Slab", "available", "attach_view", "write_back"]

_HAVE: Optional[bool] = None
_HAVE_LOCK = threading.Lock()


def available() -> bool:
    """Probe (once) whether POSIX shared memory actually works here."""
    global _HAVE
    with _HAVE_LOCK:
        if _HAVE is None:
            try:
                from multiprocessing import shared_memory
                s = shared_memory.SharedMemory(create=True, size=64)
                s.buf[0] = 1
                s.close()
                s.unlink()
                _HAVE = True
            except Exception:
                _HAVE = False
        return _HAVE


# -- worker side -------------------------------------------------------------

_attached: dict = {}
_attach_lock = threading.Lock()


def _attach(name: str):
    """Attach (and cache) a slab created by the parent.

    Attaching must NOT register the segment with ``resource_tracker``: the
    parent created it and owns its lifetime, and pre-3.13
    ``SharedMemory(name=...)`` registers unconditionally (bpo-39959) — with
    the forkserver's *shared* tracker, a worker's registration/unregister
    pair would cancel the parent's and segments would be unlinked out from
    under live engines.  3.13+ has ``track=False`` for exactly this; on
    older versions registration is suppressed for the duration of the
    attach (serialized by ``_attach_lock``)."""
    from multiprocessing import shared_memory
    with _attach_lock:
        shm = _attached.get(name)
        if shm is not None:
            return shm
        try:
            shm = shared_memory.SharedMemory(name=name, track=False)
        except TypeError:   # pre-3.13: no track=; suppress registration
            from multiprocessing import resource_tracker
            orig = resource_tracker.register
            resource_tracker.register = lambda *a, **k: None
            try:
                shm = shared_memory.SharedMemory(name=name)
            finally:
                resource_tracker.register = orig
        _attached[name] = shm
        return shm


def attach_view(name: str, nbytes: int) -> memoryview:
    """Worker-side zero-copy view of the first ``nbytes`` of a slab."""
    return memoryview(_attach(name).buf)[:nbytes]


def write_back(name: str, payload) -> Optional[int]:
    """Worker-side: overwrite the slab with ``payload`` if it fits.

    Returns the payload length, or None when the slab is too small (the
    caller then returns the payload itself through the pickle path)."""
    shm = _attach(name)
    n = len(payload)
    if n > shm.size:
        return None
    shm.buf[:n] = payload
    return n


# -- transport diagnostics (used by benchmarks/fig_zerocopy.py) --------------
# module-level so they pickle by reference under their real import path —
# the engine's process workers run with a bare __main__ by design.

def roundtrip_pickle(buf: bytes) -> bytes:
    """Pickle-transport probe: the buffer crosses the pipe both ways."""
    return buf


def roundtrip_slab(name: str, n: int) -> int:
    """Slab-transport probe: touch the slab in place (one worker-side
    memcpy, standing in for the codec's payload write); only the length
    crosses back."""
    view = attach_view(name, n)
    data = bytes(view)
    view.release()
    return len(data)


# -- parent side -------------------------------------------------------------

class Slab:
    __slots__ = ("shm", "size")

    def __init__(self, shm):
        self.shm = shm
        self.size = shm.size

    @property
    def name(self) -> str:
        return self.shm.name

    def fill(self, buf) -> int:
        """memcpy a buffer-protocol object into the slab; returns nbytes."""
        mv = memoryview(buf)
        if mv.ndim != 1 or mv.format != "B":
            mv = mv.cast("B")
        self.shm.buf[:mv.nbytes] = mv
        return mv.nbytes

    def view(self, nbytes: int) -> memoryview:
        return memoryview(self.shm.buf)[:nbytes]


def _margin(nbytes: int) -> int:
    # worst-case codec expansion (incompressible input + headers)
    return nbytes + nbytes // 64 + 4096


class SlabPool:
    """Bounded free-list of shared-memory slabs.

    The engine's ``max_inflight`` already bounds how many slabs are checked
    out at once, so ``acquire`` never blocks; it reuses the smallest free
    slab that fits or maps a fresh one.  ``close()`` unlinks everything."""

    def __init__(self, slab_bytes: int = 1 << 20,
                 max_outstanding: Optional[int] = None):
        self.slab_bytes = int(slab_bytes)
        self.max_outstanding = max_outstanding
        self._outstanding = 0
        self._free: list[Slab] = []
        self._all: list[Slab] = []
        self._lock = threading.Lock()
        self._closed = False

    def acquire(self, nbytes: int, _reserved: bool = False) -> Slab:
        need = _margin(nbytes)
        with self._lock:
            if self._closed:
                if _reserved:
                    self._outstanding -= 1
                raise RuntimeError("slab pool is closed")
            if not _reserved:
                self._outstanding += 1
            best = None
            for s in self._free:
                if s.size >= need and (best is None or s.size < best.size):
                    best = s
            if best is not None:
                self._free.remove(best)
                return best
        from multiprocessing import shared_memory
        try:
            shm = shared_memory.SharedMemory(
                create=True, size=max(need, _margin(self.slab_bytes)))
        except BaseException:
            with self._lock:
                self._outstanding -= 1
            raise
        slab = Slab(shm)
        with self._lock:
            if self._closed:  # closed while mapping: destroy, don't leak
                self._outstanding -= 1
                shm.close()
                shm.unlink()
                raise RuntimeError("slab pool is closed")
            self._all.append(slab)
        return slab

    def try_acquire(self, nbytes: int) -> Optional[Slab]:
        """``acquire``, unless ``max_outstanding`` slabs are already checked
        out — then None, and the caller uses its non-shm fallback.  Bounds
        slab memory when a reader schedules a whole branch at once.  The
        check-and-reserve is one locked step, so concurrent callers can't
        stampede past the cap."""
        with self._lock:
            if self.max_outstanding is not None \
                    and self._outstanding >= self.max_outstanding:
                return None
            self._outstanding += 1
        return self.acquire(nbytes, _reserved=True)

    def release(self, slab: Slab) -> None:
        with self._lock:
            self._outstanding = max(self._outstanding - 1, 0)
            if not self._closed:
                self._free.append(slab)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            slabs, self._all, self._free = self._all, [], []
        for s in slabs:
            # unlink first: it needs no exclusive mapping, so a consumer
            # still holding a yielded view can't keep the segment on disk
            try:
                s.shm.unlink()
            except Exception:
                pass
            try:
                s.shm.close()
            except Exception:
                pass
