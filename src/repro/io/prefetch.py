"""Decompress-ahead branch reader — the TTreeCache analogue.

ROOT hides decompression latency behind the analysis loop by reading and
decompressing the baskets for *upcoming* entry ranges while the current
range is being consumed ("simultaneous read and decompression for multiple
physics events", paper Fig. 1).  ``PrefetchReader`` reproduces that:

* every basket access schedules the next ``ahead`` baskets on the engine's
  worker pool, so by the time the consumer asks for basket *i+1* it is
  usually already decompressed;
* an LRU cache of decompressed baskets (``cache_baskets`` deep) makes
  re-reads — overlapping entry ranges, restart-cursor replays, epoch
  loops over small files — free;
* ``read_all`` schedules *every* basket at once and joins in order: the
  full-throughput parallel branch read.

The reader is stateless with respect to the file (it uses the offsets and
metadata captured from the TOC at construction), so many readers can share
one ``BasketFile`` and one engine.

Staleness: the source's ``(st_dev, st_ino)`` generation is captured with
the TOC and passed to every scheduled read — a container replaced under
the reader raises ``fdcache.StaleFileError`` instead of mixing cached
baskets from the old file with fresh reads of the new one.

Remote sources: any object exposing ``branches``/``_dictionary`` plus a
``submit_baskets(branch, idxs) -> list[Future[bytes]]`` method (e.g.
``repro.remote.RemoteBasketFile``) can sit where the local ``BasketFile``
does.  Scheduling batches every uncached index of a prefetch/acquire wave
into ONE ``submit_baskets`` call, which the remote client turns into one
vectored wire request — the read-ahead that makes a high-latency link
look local.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro import obs
from repro.core.basket import BasketMeta, byte_offsets

from .engine import CompressionEngine

__all__ = ["PrefetchReader"]


class PrefetchReader:
    def __init__(self, bfile, branch: str, *, workers: int = 2,
                 ahead: int = 4, cache_baskets: int = 32,
                 engine: Optional[CompressionEngine] = None,
                 verify: Optional[bool] = None):
        entry = bfile.branches[branch]
        self.path = bfile.path
        self.branch = branch
        self.dtype = np.dtype(entry["dtype"])
        self.shape = tuple(entry["shape"])
        self.verify = getattr(bfile, "verify", True) if verify is None else verify
        self._dictionary = bfile._dictionary(entry)
        self._offsets = [b["offset"] for b in entry["baskets"]]
        self._meta_json = [dict(b["meta"]) for b in entry["baskets"]]
        self._metas = [BasketMeta.from_json(m) for m in self._meta_json]
        # remote sources schedule through the source itself (one vectored
        # request per wave); local files through the engine + fdcache
        self._source = bfile if hasattr(bfile, "submit_baskets") else None
        # the generation of the file this TOC describes: every scheduled
        # read checks it, so a tmp-then-replaced container fails loudly
        # instead of serving baskets the cached metadata does not match
        self.generation = getattr(bfile, "generation", None)
        self.ahead = max(int(ahead), 0)
        self.cache_baskets = max(int(cache_baskets), 1)
        self._engine = engine or (None if self._source is not None
                                  else CompressionEngine(workers))
        self._owns_engine = engine is None and self._engine is not None
        self._lock = threading.Lock()
        self._cache: OrderedDict[int, Future] = OrderedDict()  # idx -> Future[bytes]
        self.hits = 0
        self.misses = 0

    # -- scheduling ------------------------------------------------------

    def n_baskets(self) -> int:
        return len(self._metas)

    def _submit(self, idxs: list[int]) -> list[Future]:
        """Source-side scheduling of uncached baskets, one batch."""
        if self._source is not None:
            return self._source.submit_baskets(self.branch, idxs,
                                               verify=self.verify)
        return [self._engine.submit_unpack(
            self.path, self._offsets[i], self._meta_json[i],
            self._dictionary, self.verify, self.generation) for i in idxs]

    def _schedule_many(self, idxs) -> list[Future]:
        """Ensure every index is scheduled (or cached); LRU-touch hits and
        submit the misses as ONE batch.  Call with the lock held."""
        have: dict[int, Future] = {}
        missing: list[int] = []
        for i in idxs:
            if i in have:
                continue
            fut = self._cache.get(i)
            if fut is not None:
                self._cache.move_to_end(i)
                have[i] = fut
            else:
                missing.append(i)
                have[i] = None  # placeholder: preserves dedup
        if missing:
            for i, fut in zip(missing, self._submit(missing)):
                self._cache[i] = fut
                have[i] = fut
            while len(self._cache) > self.cache_baskets:
                _old_idx, old_fut = next(iter(self._cache.items()))
                if not old_fut.done():        # never drop work still in flight
                    break
                self._cache.popitem(last=False)
        return [have[i] for i in idxs]

    def prefetch(self, indices) -> None:
        """Schedule decompression for the given basket indices."""
        with self._lock:
            self._schedule_many([i for i in indices
                                 if 0 <= i < len(self._metas)])

    def _acquire(self, indices) -> list[Future]:
        """Futures for baskets about to be *consumed*.  Holding the future
        (not the cache slot) means LRU eviction can never force a second
        decompression of work already in flight; an index already cached
        (even if still decompressing — i.e. prefetched in time) is a hit."""
        with self._lock:
            hits = 0
            for i in indices:
                cached = i in self._cache
                hits += cached
            misses = len(indices) - hits
            self.hits += hits
            self.misses += misses
            futs = self._schedule_many(indices)
        # mirror into obs as one batched add per wave, not per basket
        if hits:
            obs.counter("prefetch.requests", event="hit").inc(hits)
        if misses:
            obs.counter("prefetch.requests", event="miss").inc(misses)
        return futs

    def _trim(self) -> None:
        """Shrink the cache back to ``cache_baskets`` (oldest completed
        first) — bulk reads schedule every basket at once, and without
        this the whole decompressed branch would stay pinned until
        close()."""
        with self._lock:
            while len(self._cache) > self.cache_baskets:
                _idx, fut = next(iter(self._cache.items()))
                if not fut.done():
                    break
                self._cache.popitem(last=False)

    def basket(self, idx: int) -> bytes:
        """Decompressed bytes of basket ``idx``; schedules ``ahead`` more."""
        fut = self._acquire([idx])[0]
        self.prefetch(range(idx + 1, min(idx + 1 + self.ahead,
                                         len(self._metas))))
        return fut.result()

    # -- reads -----------------------------------------------------------

    def _covering(self, start: int, stop: int) -> list[int]:
        return [i for i, m in enumerate(self._metas)
                if m.entry_start + m.entry_count > start
                and m.entry_start < stop]

    @staticmethod
    def _scatter(flat: np.ndarray, pos: int, chunk) -> int:
        b = np.frombuffer(chunk, dtype=np.uint8)
        flat[pos:pos + b.size] = b
        return b.size

    def read_entries(self, start: int, stop: int) -> np.ndarray:
        """Row range [start, stop); decompresses covering baskets in
        parallel and read-ahead schedules the ``ahead`` baskets after.
        The covering rows are allocated once and each basket lands in its
        slice — no ``b"".join`` rematerialization."""
        idxs = self._covering(start, stop)
        if not idxs:
            return np.zeros((0,) + self.shape[1:], dtype=self.dtype)
        futs = self._acquire(idxs)
        self.prefetch(range(idxs[-1] + 1, idxs[-1] + 1 + self.ahead))
        total = sum(self._metas[i].orig_len for i in idxs)
        row_elems = int(np.prod(self.shape[1:], dtype=np.int64)) or 1
        rows = total // (self.dtype.itemsize * row_elems)
        arr = np.empty((rows,) + self.shape[1:], dtype=self.dtype)
        flat = arr.reshape(-1).view(np.uint8)
        pos = 0
        for f in futs:
            pos += self._scatter(flat, pos, f.result())
        self._trim()
        first_entry = self._metas[idxs[0]].entry_start
        return arr[start - first_entry: stop - first_entry].copy()

    def read_all(self) -> np.ndarray:
        """Whole branch: every basket scheduled at once, scattered in order
        into one destination allocation.

        Baskets already in the cache (or mid-decompression from an earlier
        prefetch) are consumed from their futures; the rest are submitted
        as decode-**into** tasks targeting the destination slice directly —
        those bypass the cache (their result is a byte count, not reusable
        bytes), which is the right trade for a bulk scan that would blow
        the LRU anyway.  Remote sources fetch the misses as one vectored
        wave and scatter the returned bytes."""
        out = np.empty(self.shape, dtype=self.dtype)
        flat = out.reshape(-1).view(np.uint8)
        offs, pos = byte_offsets(m.orig_len for m in self._metas)
        if pos != out.nbytes:   # malformed TOC; keep the copying fallback
            futs = self._acquire(range(len(self._metas)))
            chunks = [f.result() for f in futs]
            self._trim()
            buf = b"".join(bytes(c) for c in chunks)
            return np.frombuffer(buf, dtype=self.dtype).reshape(self.shape).copy()
        # classify under the lock; submit (and, for a serial engine,
        # *execute*) outside it — a multi-GB scan must not stall other
        # threads sharing this reader.  A basket cached by a concurrent
        # thread between the two phases just decodes twice (same bytes,
        # disjoint destinations), never corrupts.
        cached_tasks, missing = [], []
        with self._lock:
            for i in range(len(self._metas)):
                fut = self._cache.get(i)
                if fut is not None:
                    self.hits += 1
                    self._cache.move_to_end(i)
                    cached_tasks.append((i, fut))
                else:
                    self.misses += 1
                    missing.append(i)
        if cached_tasks:
            obs.counter("prefetch.requests", event="hit").inc(len(cached_tasks))
        if missing:
            obs.counter("prefetch.requests", event="miss").inc(len(missing))
        if self._source is not None:
            into_futs = list(zip(missing, self._submit(missing))) if missing else []
            for i, fut in cached_tasks + into_futs:
                self._scatter(flat, offs[i], fut.result())
            self._trim()
            return out
        into_futs = [self._engine.submit_unpack_into(
            self.path, self._offsets[i], self._meta_json[i],
            self._dictionary, self.verify,
            flat[offs[i]:offs[i] + self._metas[i].orig_len], self.generation)
            for i in missing]
        for i, fut in cached_tasks:
            self._scatter(flat, offs[i], fut.result())
        for fut in into_futs:
            fut.result()
        self._trim()
        return out

    # -- lifecycle -------------------------------------------------------

    def close(self) -> None:
        with self._lock:
            self._cache.clear()
        if self._owns_engine:
            self._engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
