"""repro.io — the parallel I/O engine (DESIGN.md §5).

Basket-granular task parallelism for the compression survey's container:

* :class:`~repro.io.engine.CompressionEngine` — pipelined parallel basket
  compression with in-order streaming commit and backpressure (ROOT's
  implicit-MT flush, arXiv:1804.03326);
* :class:`~repro.io.prefetch.PrefetchReader` — decompress-ahead reads with
  an LRU decompressed-basket cache (the TTreeCache analogue);
* :class:`~repro.io.merger.BufferMerger` / ``BasketBuffer`` — multi-producer
  single-file output without recompression (the TBufferMerger analogue),
  plus :func:`~repro.io.merger.merge_files` fast file splicing;
* :mod:`~repro.io.shmem` — shared-memory slab pool: the zero-pickle
  transport behind the process-pool codecs (DESIGN.md §10);
* :mod:`~repro.io.fdcache` — one cached fd per container path with
  ``os.pread`` basket reads (no per-basket ``open(2)``).

``BasketWriter(workers=N)`` / ``BasketFile(prefetch=K)`` in
``repro.core.bfile`` delegate here, so existing call sites opt in with one
argument.
"""

from .engine import CompressionEngine, cpu_count
from .merger import BasketBuffer, BufferMerger, merge_files
from .prefetch import PrefetchReader

__all__ = ["CompressionEngine", "cpu_count", "PrefetchReader",
           "BasketBuffer", "BufferMerger", "merge_files"]
