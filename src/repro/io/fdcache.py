"""Cached positional reads — one fd per path, ``os.pread`` per basket.

The parallel unpack path used to ``open()``/``close()`` the container once
per basket, so a 64-worker decompress fan-out serialized on path resolution
and the dentry lock.  Here every (process, path) pair holds a single O_RDONLY
fd and baskets are read with ``os.pread`` — positional, thread-safe, no
seek state shared between workers.

Staleness: BasketFiles are written tmp-then-``os.replace``d, so a path can
start pointing at a *new* inode while a cached fd still references the old
one.  Each cache hit revalidates with one ``stat``: if the path's
(st_dev, st_ino) no longer matches the fd's, the fd is reopened.  That is
one cheap syscall versus the open+close pair (plus fd-table churn) it
replaces — and unlike an ``st_nlink`` probe it also holds on overlayfs,
where unlinked-but-open inodes keep reporting a link.

Reads *check out* their entry (a refcount taken under the lock), so LRU
eviction or ``invalidate()`` on another thread can only mark an in-use fd
dead — it is closed by the last reader checking it back in, never while a
``pread`` may still be using (or worse, a fresh ``open`` reusing) that fd
number.

The cache is per-process module state (process-pool workers each get their
own copy) and holds at most ``_MAX_FDS`` descriptors, evicted LRU.
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict

__all__ = ["pread", "patch", "generation", "invalidate", "clear",
           "StaleFileError", "set_fault_hook"]


class StaleFileError(OSError):
    """The path no longer points at the inode the caller captured.

    Raised by :func:`pread` when an ``expect`` generation is supplied and
    the path's current ``(st_dev, st_ino)`` differs — i.e. the container
    was atomically replaced after the caller read its TOC.  Readers catch
    this to re-open instead of mixing baskets from two file generations.
    """

_MAX_FDS = 64

_lock = threading.Lock()

# fault-injection hook (repro.fault): when set, every pread's bytes pass
# through ``hook(path, offset, buf) -> bytes`` before the length check —
# returning short bytes simulates a torn read, mutated bytes simulate
# on-disk corruption, and a sleep inside simulates a slow device.  Test
# and chaos-soak machinery only; None (the default) costs one attribute
# load on the hot path.
_fault_hook = None


def set_fault_hook(hook):
    """Install ``hook(path, offset, buf) -> bytes`` on the pread path
    (None to remove).  Returns the previous hook so tests can restore."""
    global _fault_hook
    prev, _fault_hook = _fault_hook, hook
    return prev


class _Entry:
    __slots__ = ("fd", "ident", "refs", "dead")

    def __init__(self, fd: int, ident: tuple):
        self.fd = fd
        self.ident = ident
        self.refs = 0
        self.dead = False


_entries: "OrderedDict[str, _Entry]" = OrderedDict()


def _close_quietly(fd: int) -> None:
    try:
        os.close(fd)
    except OSError:
        pass


def _retire(e: _Entry) -> None:
    """Mark dead; close now only if no reader holds it (the last reader
    closes it in ``_checkin`` otherwise).  Call with the lock held."""
    if not e.dead:
        e.dead = True
        if e.refs == 0:
            _close_quietly(e.fd)


def _checkout(path: str) -> _Entry:
    with _lock:
        e = _entries.get(path)
        if e is not None:
            try:
                st = os.stat(path)
                fresh = (st.st_dev, st.st_ino) == e.ident
            except OSError:
                fresh = False
            if fresh:
                _entries.move_to_end(path)
                e.refs += 1
                return e
            _entries.pop(path, None)
            _retire(e)
        fd = os.open(path, os.O_RDONLY)
        st = os.fstat(fd)
        e = _Entry(fd, (st.st_dev, st.st_ino))
        e.refs = 1
        _entries[path] = e
        while len(_entries) > _MAX_FDS:
            _, old = _entries.popitem(last=False)
            _retire(old)
        return e


def _checkin(e: _Entry) -> None:
    with _lock:
        e.refs -= 1
        if e.dead and e.refs == 0:
            _close_quietly(e.fd)


def pread(path: str, offset: int, n: int, expect: tuple | None = None) -> bytes:
    """Read ``n`` bytes at ``offset`` through the per-path cached fd.

    ``expect`` is a ``(st_dev, st_ino)`` generation captured when the
    caller read the file's TOC (see :func:`generation`); if the path now
    resolves to a different inode the read raises :class:`StaleFileError`
    instead of returning bytes from a file the TOC does not describe."""
    e = _checkout(path)
    try:
        if expect is not None and tuple(expect) != e.ident:
            raise StaleFileError(
                f"{path}: file was replaced (generation {e.ident} != "
                f"expected {tuple(expect)})")
        buf = os.pread(e.fd, n, offset)
    finally:
        _checkin(e)
    if _fault_hook is not None:
        buf = _fault_hook(path, offset, buf)
    if len(buf) != n:
        raise EOFError(f"{path}: short read at {offset}: {len(buf)} < {n}")
    return buf


def patch(path: str, offset: int, data: bytes,
          expect: tuple | None = None) -> None:
    """Overwrite ``len(data)`` bytes at ``offset`` **in place** — the
    repair primitive (repro.repair heals a rotted basket by writing the
    reconstructed payload back over the damage).

    In-place on purpose: a tmp-then-replace rewrite would change the
    inode and stale every open reader/cache generation, while an in-place
    patch restores the *original* bytes of the same generation — readers
    that captured the inode keep being right.  The write goes through a
    short-lived O_RDWR fd (the cached read fd stays O_RDONLY) and is
    fsynced before returning.  ``expect`` gives the same staleness guard
    as :func:`pread`."""
    fd = os.open(path, os.O_RDWR)
    try:
        st = os.fstat(fd)
        if expect is not None and tuple(expect) != (st.st_dev, st.st_ino):
            raise StaleFileError(
                f"{path}: file was replaced (generation "
                f"{(st.st_dev, st.st_ino)} != expected {tuple(expect)})")
        view = memoryview(data)
        pos = offset
        while view:
            n = os.pwrite(fd, view, pos)
            pos += n
            view = view[n:]
        os.fsync(fd)
    finally:
        _close_quietly(fd)


def generation(path: str) -> tuple[int, int]:
    """The path's current ``(st_dev, st_ino)`` identity — the generation
    key used by every basket cache (prefetch LRU, remote tiered cache) so
    a replaced file can never serve stale cached baskets.  Goes through
    the fd cache, so the identity matches what :func:`pread` will read."""
    e = _checkout(path)
    try:
        return e.ident
    finally:
        _checkin(e)


def invalidate(path: str) -> None:
    with _lock:
        e = _entries.pop(path, None)
        if e is not None:
            _retire(e)


def clear() -> None:
    with _lock:
        entries = list(_entries.values())
        _entries.clear()
        for e in entries:
            _retire(e)
