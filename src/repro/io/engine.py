"""Pipelined basket-granular compression engine.

ROOT's answer to the single-core compression wall (the paper's closing
argument, mechanised in *Increasing Parallelism in the ROOT I/O Subsystem*,
arXiv:1804.03326) is task parallelism at basket granularity: when a TTree
flushes, each basket becomes an independent compression task and the writer
commits finished payloads in order.  This module is that mechanism:

* ``CompressionEngine`` owns a bounded worker pool.  ``pack_stream`` takes
  the (entry_start, entry_count, raw_bytes) chunk stream produced by
  :func:`repro.core.basket.split_array`, compresses up to ``max_inflight``
  baskets concurrently, and yields ``(start, count, payload, meta)``
  strictly in submission order — so the caller writes at monotonically
  increasing offsets exactly like the serial path, and the output file is
  **byte-identical** to serial output (``pack_basket`` is deterministic and
  commit order equals submission order).

* Backpressure: the submitting side blocks once ``max_inflight`` baskets
  are in flight, bounding memory at ~``max_inflight * basket_bytes``
  regardless of branch size — a slow disk never lets the compressors run
  unboundedly ahead.

* GIL routing: C-backed codecs (zlib, lzma, libzstd) release the GIL while
  compressing, so a thread pool scales them across cores.  The from-scratch
  pure-Python codecs (our lz4 block format and the repro-deflate family)
  hold the GIL; for those the engine transparently uses a process pool —
  tasks carry only (bytes, config fields), so they pickle cheaply and the
  payloads come back bit-identical.  ``benchmarks/fig_parallel.py`` shows
  both regimes as the paper-style cores-vs-throughput curve.

The engine is shared: one instance can serve many branches, many writers,
and the prefetching reader (``repro.io.prefetch``) simultaneously.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import sys
import threading
import time
from collections import deque
from concurrent.futures import Executor, Future, ProcessPoolExecutor, ThreadPoolExecutor
from typing import Iterable, Iterator, Optional

from repro.core import basket as _basket
from repro.core import codec as _codec

__all__ = ["CompressionEngine", "cpu_count"]


def cpu_count() -> int:
    return os.cpu_count() or 1


# ---------------------------------------------------------------------------
# module-level task bodies (picklable, so the process backend can run them)
# ---------------------------------------------------------------------------

def _pack_task(raw: bytes, cfg_fields: tuple, start: int, count: int):
    cfg = _codec.CompressionConfig(*cfg_fields)
    payload, meta = _basket.pack_basket(raw, cfg, entry_start=start,
                                        entry_count=count)
    return start, count, payload, meta


def _unpack_task(path: str, offset: int, meta_json: dict,
                 dictionary: Optional[bytes], verify: bool) -> bytes:
    meta = _basket.BasketMeta.from_json(meta_json)
    with open(path, "rb") as f:
        f.seek(offset)
        payload = f.read(meta.comp_len)
    return _basket.unpack_basket(payload, meta, dictionary, verify=verify)


def _cfg_fields(cfg: _codec.CompressionConfig) -> tuple:
    return (cfg.algo, cfg.level, cfg.precond, cfg.dictionary)


def _warm_task(delay: float = 0.0):
    if delay:
        time.sleep(delay)
    return None


def _completed_future(fn, *args) -> Future:
    """Run ``fn`` now, wrapped in a Future (mirrors executor semantics)."""
    f: Future = Future()
    try:
        f.set_result(fn(*args))
    except Exception as e:
        f.set_exception(e)
    return f


_SENTINEL = object()

# __main__.__spec__/__file__ are process-global: the hide/spawn/restore
# window below must be exclusive across ALL engines, not just one
_SPAWN_LOCK = threading.Lock()


def _restore_attr(obj, name, saved) -> None:
    if saved is _SENTINEL:
        try:
            delattr(obj, name)
        except AttributeError:
            pass
    else:
        setattr(obj, name, saved)


class CompressionEngine:
    """Bounded worker pool with in-order streaming commit.

    ``workers=0`` degrades to fully serial execution (no pool, no threads),
    which is what makes ``BasketWriter(workers=0)`` bit-for-bit the old
    serial writer with zero overhead.
    """

    def __init__(self, workers: int = 0, max_inflight: Optional[int] = None,
                 unpack_processes: bool = False,
                 inline_bytes: int = 16384):
        self.workers = max(int(workers), 0)
        self.max_inflight = max_inflight or max(2 * self.workers, 1)
        # Decompression defaults to the thread pool even for pure-Python
        # codecs: readers are created ad hoc (one per file/branch), and a
        # process pool's worker-import cost would dwarf the decode work.
        # Long steady-state scans can opt in to process decompression.
        self.unpack_processes = unpack_processes
        # Baskets smaller than this compress inline in the caller instead
        # of being shipped to a pool.  Re-tuned for the vectorized codec
        # cores: single-core throughput rose ~3-8x, so the payload size
        # where process-pool pickling/IPC pays for itself moved up — a
        # 16 KiB basket now compresses in well under the round-trip cost.
        self.inline_bytes = max(int(inline_bytes), 0)
        self._thread_pool: Optional[ThreadPoolExecutor] = None
        self._proc_pool: Optional[ProcessPoolExecutor] = None
        self._lock = threading.Lock()
        self._closed = False

    # -- pools -----------------------------------------------------------

    def _pool_for(self, algo: str) -> Optional[Executor]:
        """Thread pool for GIL-releasing codecs, process pool otherwise."""
        if self.workers == 0:
            return None
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            if _codec.is_pure_python(algo):
                if self._proc_pool is None:
                    self._proc_pool = self._spawn_process_pool()
                return self._proc_pool
            if self._thread_pool is None:
                self._thread_pool = ThreadPoolExecutor(
                    self.workers, thread_name_prefix="repro-io")
            return self._thread_pool

    def _spawn_process_pool(self) -> ProcessPoolExecutor:
        """Pool for GIL-holding codecs, started so it can never run user
        code or deadlock:

        * *forkserver* context — workers fork from a clean server process,
          never from this (possibly jax-threaded) one, so no lock held by a
          sibling thread can deadlock a child (plain ``fork`` can);
        * every worker is spawned HERE with ``__main__``'s ``__spec__``/
          ``__file__`` temporarily hidden.  forkserver (like spawn)
          otherwise re-imports ``__main__`` per worker, which re-executes
          unguarded user scripts (hanging the pool on the re-entrant
          ``ProcessPoolExecutor``) and crashes outright for stdin scripts
          (``python - <<EOF``: ``__file__`` doesn't exist on disk).  Our
          tasks are module-level functions in this module — workers never
          need ``__main__`` at all, so a bare one is correct.
        """
        try:
            ctx = mp.get_context("forkserver")
        except ValueError:  # pragma: no cover - non-POSIX
            ctx = None
        with _SPAWN_LOCK:
            main = sys.modules.get("__main__")
            saved_spec = getattr(main, "__spec__", _SENTINEL) if main else _SENTINEL
            saved_file = getattr(main, "__file__", _SENTINEL) if main else _SENTINEL
            try:
                if main is not None:
                    main.__spec__ = None
                    main.__file__ = None
                pool = ProcessPoolExecutor(self.workers, mp_context=ctx)
                # submit() is what forks workers; preparation data (incl.
                # the hidden __main__ info) is captured synchronously per
                # spawn, so all workers must spawn inside this window
                futs = [pool.submit(_warm_task, 0.05)
                        for _ in range(self.workers)]
            finally:
                if main is not None:
                    _restore_attr(main, "__spec__", saved_spec)
                    _restore_attr(main, "__file__", saved_file)
        for f in futs:
            f.result()
        return pool

    def warmup(self, algo: str = "zlib") -> None:
        """Pre-start the pool serving ``algo`` (process pools fork lazily;
        benchmarks warm up so curves show steady-state throughput).  The
        warm tasks sleep briefly so one eager worker can't drain them all —
        every worker must spawn (and pay its module import) now."""
        pool = self._pool_for(algo)
        if pool is not None:
            delay = 0.25 if isinstance(pool, ProcessPoolExecutor) else 0.0
            for f in [pool.submit(_warm_task, delay)
                      for _ in range(self.workers)]:
                f.result()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            pools = [p for p in (self._thread_pool, self._proc_pool) if p]
            self._thread_pool = self._proc_pool = None
        for p in pools:
            p.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()

    # -- ordered map (the pipeline primitive) ----------------------------

    def _map_ordered(self, pool: Optional[Executor], submit_one,
                     items: Iterable) -> Iterator:
        """Yield results in submission order, ≤ max_inflight in flight.

        The deque head is the oldest future; blocking on it while the tail
        keeps compressing is what pipelines compression with the caller's
        sequential disk writes."""
        if pool is None:
            for it in items:
                yield submit_one(None, it)
            return
        pending: deque[Future] = deque()
        it = iter(items)
        exhausted = False
        try:
            while pending or not exhausted:
                while not exhausted and len(pending) < self.max_inflight:
                    try:
                        item = next(it)
                    except StopIteration:
                        exhausted = True
                        break
                    pending.append(submit_one(pool, item))
                if pending:
                    yield pending.popleft().result()
        finally:
            for f in pending:
                f.cancel()

    # -- compression side ------------------------------------------------

    def pack_stream(self, chunks: Iterable[tuple[int, int, bytes]],
                    cfg: _codec.CompressionConfig) -> Iterator[tuple]:
        """(start, count, raw) stream -> (start, count, payload, meta)
        stream, in order, compressed ``workers``-wide."""
        pool = self._pool_for(cfg.algo if cfg.enabled else "none")
        fields = _cfg_fields(cfg)
        inline = self.inline_bytes

        def submit_one(p, chunk):
            start, count, raw = chunk
            if p is None:
                return _pack_task(raw, fields, start, count)
            if len(raw) < inline:
                # small basket: the pool round-trip (pickle + IPC + wakeup)
                # costs more than compressing right here
                return _completed_future(_pack_task, raw, fields, start, count)
            return p.submit(_pack_task, raw, fields, start, count)

        return self._map_ordered(pool, submit_one, chunks)

    # -- decompression side (used by the prefetching reader) -------------

    def submit_unpack(self, path: str, offset: int, meta_json: dict,
                      dictionary: Optional[bytes], verify: bool) -> Future:
        """Schedule one basket's read+decompress; returns a Future[bytes]."""
        algo = meta_json.get("algo", "none") if self.unpack_processes else "none"
        pool = self._pool_for(algo)
        if pool is None:
            return _completed_future(_unpack_task, path, offset, meta_json,
                                     dictionary, verify)
        return pool.submit(_unpack_task, path, offset, meta_json,
                           dictionary, verify)
