"""Pipelined basket-granular compression engine.

ROOT's answer to the single-core compression wall (the paper's closing
argument, mechanised in *Increasing Parallelism in the ROOT I/O Subsystem*,
arXiv:1804.03326) is task parallelism at basket granularity: when a TTree
flushes, each basket becomes an independent compression task and the writer
commits finished payloads in order.  This module is that mechanism:

* ``CompressionEngine`` owns a bounded worker pool.  ``pack_stream`` takes
  the (entry_start, entry_count, buffer) chunk stream produced by
  :func:`repro.core.basket.split_array`, compresses up to ``max_inflight``
  baskets concurrently, and yields ``(start, count, payload, meta)``
  strictly in submission order — so the caller writes at monotonically
  increasing offsets exactly like the serial path, and the output file is
  **byte-identical** to serial output (``pack_basket`` is deterministic and
  commit order equals submission order).

* Backpressure: the submitting side blocks once ``max_inflight`` baskets
  are in flight, bounding memory at ~``max_inflight * basket_bytes``
  regardless of branch size — a slow disk never lets the compressors run
  unboundedly ahead.

* GIL routing: C-backed codecs (zlib, lzma, libzstd) release the GIL while
  compressing, so a thread pool scales them across cores.  The from-scratch
  pure-Python codecs (our lz4 block format and the repro-deflate family)
  hold the GIL; for those the engine transparently uses a process pool.

* Zero-copy transport: process-pool tasks move their buffers through a
  ``multiprocessing.shared_memory`` slab pool (``repro.io.shmem``) instead
  of pickled-bytes pipe round-trips — the parent memcpys the raw chunk
  into a pre-mapped slab, the worker compresses in place and writes the
  payload back over the same slab, and only slab names and lengths cross
  the pipe.  Falls back to the pickle transport when shared memory is
  unavailable (``shm=False`` forces the fallback).  Output bytes are
  identical either way.

Payload lifetime: ``pack_stream`` may yield payloads that are memoryviews
(into a slab, or into the caller's own source array on the serial identity
path).  They are valid until the generator is advanced or closed; consumers
that retain payloads must ``bytes()`` them (``BasketWriter`` writes them to
disk immediately; ``BasketBuffer`` copies).

The engine is shared: one instance can serve many branches, many writers,
and the prefetching reader (``repro.io.prefetch``) simultaneously.
"""

from __future__ import annotations

import contextlib
import logging
import multiprocessing as mp
import os
import sys
import threading
import time
from collections import deque
from concurrent.futures import (CancelledError, Executor, Future,
                                ProcessPoolExecutor, ThreadPoolExecutor)
from typing import Iterable, Iterator, Optional

import numpy as np

from repro import obs
from repro.core import basket as _basket
from repro.core import codec as _codec

from . import fdcache as _fdcache
from . import shmem as _shmem

__all__ = ["CompressionEngine", "cpu_count"]

_LOG = logging.getLogger("repro.io")


def cpu_count() -> int:
    return os.cpu_count() or 1


# ---------------------------------------------------------------------------
# module-level task bodies (picklable, so the process backend can run them)
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def _task_span(name: str, tp, **args):
    """Span for an engine task body, recorded only when a caller's
    traceparent rode in with the task — per-basket spans on untraced bulk
    workloads would flood the ring for nothing.  With ``tp`` set, the
    span joins the caller's trace even across the process-pool boundary
    (the worker's ring folds back on :meth:`CompressionEngine.collect_obs`)."""
    if not tp:
        yield
        return
    with obs.context.activated(tp):
        with obs.trace.span(name, cat="engine", **args):
            yield


def _obs_pack(raw, cfg, start: int, count: int, tp=None):
    """pack_basket with stage telemetry.  Runs in whichever worker executes
    the task: thread workers hit the parent registry directly; process
    workers hit their own, folded back by :meth:`CompressionEngine.collect_obs`."""
    t0 = time.perf_counter()
    with _task_span("engine.pack", tp, algo=cfg.algo), \
            obs.profile.mem_phase("engine.pack"):
        payload, meta = _basket.pack_basket(raw, cfg, entry_start=start,
                                            entry_count=count)
    obs.histogram("engine.pack_s", algo=cfg.algo).observe(
        time.perf_counter() - t0)
    obs.counter("engine.pack.bytes_in", algo=cfg.algo).inc(meta.orig_len)
    obs.counter("engine.pack.bytes_out", algo=cfg.algo).inc(meta.comp_len)
    return payload, meta


def _pack_task(raw, cfg_fields: tuple, start: int, count: int, tp=None):
    cfg = _codec.CompressionConfig(*cfg_fields)
    payload, meta = _obs_pack(raw, cfg, start, count, tp)
    return start, count, payload, meta


def _pack_task_shm(slab_name: str, nbytes: int, cfg_fields: tuple,
                   start: int, count: int, tp=None):
    """Worker body for the slab transport: input read in place from the
    slab, payload written back over it (the input is dead by then).  The
    return value carries only the payload *length* — or the payload bytes
    themselves if they outgrew the slab (incompressible + header margin
    exceeded), which the parent handles transparently."""
    raw = _shmem.attach_view(slab_name, nbytes)
    cfg = _codec.CompressionConfig(*cfg_fields)
    payload, meta = _obs_pack(raw, cfg, start, count, tp)
    if payload is raw:          # identity config: content already in place
        return start, count, nbytes, meta
    n = _shmem.write_back(slab_name, payload)
    if n is None:
        return start, count, bytes(payload), meta
    return start, count, n, meta


def _measure_trial(sample, cfg: "_codec.CompressionConfig", reps: int):
    """Timed compress + decompress-into of one payload (best-of-reps).

    Each measurement lands in the obs registry — per-algo rate histograms
    plus a trial counter — so calibration evidence is inspectable after
    the fact (obstat / STATS) instead of collapsing into one returned
    number.  The return value is still the best-of-reps cost-model point
    the tuner selects on."""
    t_c = float("inf")
    payload = meta = None
    for _ in range(reps):
        t0 = time.perf_counter()
        payload, meta = _basket.pack_basket(sample, cfg)
        t_c = min(t_c, time.perf_counter() - t0)
    out = np.empty(meta.orig_len, np.uint8)
    t_d = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _basket.unpack_basket_into(payload, meta, out, cfg.dictionary,
                                   verify=False)
        t_d = min(t_d, time.perf_counter() - t0)
    obs.counter("tune.trials", algo=cfg.algo).inc()
    obs.histogram("tune.trial_s", algo=cfg.algo).observe(t_c + t_d)
    mb = meta.orig_len / 1e6
    if t_c > 0:
        obs.histogram("tune.trial.comp_mbps", algo=cfg.algo).observe(mb / t_c)
    if t_d > 0:
        obs.histogram("tune.trial.decomp_mbps", algo=cfg.algo).observe(mb / t_d)
    return meta.orig_len, meta.comp_len, t_c, t_d


def _trial_task(sample, cfg_fields: tuple, reps: int = 1,
                budget_s: Optional[float] = None):
    """One autotuner trial: compress the sampled payload, then decompress
    it back through the zero-copy into-path, timing both (best-of-reps).
    Returns ``(orig_len, comp_len, comp_s, decomp_s)`` — the raw cost-model
    point ``repro.tune`` wraps into a TrialResult.

    ``budget_s`` bounds the per-candidate cost: an eighth of the sample is
    measured first, and the full sample runs only if the extrapolated cost
    fits the budget — so a slow candidate (the pure-Python cores can run
    at single-digit MB/s) is ranked from its probe instead of stalling the
    trial matrix.  The probe keeps the sample's stratification: it takes
    the leading eighth of each of 8 equal segments (= a slice of every
    sampler window), not a head-only prefix — head-only probing is the
    mistuning mode the stratified sampler exists to avoid.
    """
    cfg = _codec.CompressionConfig(*cfg_fields)
    reps = max(int(reps), 1)
    n = _buf_len(sample)
    if budget_s is not None and n >= 4096:
        a = np.frombuffer(sample, np.uint8) \
            if not isinstance(sample, np.ndarray) else sample.reshape(-1)
        seg = n // 8
        sub = max((seg // 8) & ~7, 8)    # element-aligned for every precond
        probe = np.concatenate([a[(i * seg) & ~7:((i * seg) & ~7) + sub]
                                for i in range(8)])
        cut = probe.size
        res = _measure_trial(probe, cfg, 1)
        est = (res[2] + res[3]) * (n / max(cut, 1)) * reps
        if est > budget_s:
            return res
    return _measure_trial(sample, cfg, reps)


def _unpack_task(path: str, offset: int, meta_json: dict,
                 dictionary: Optional[bytes], verify: bool,
                 ident: Optional[tuple] = None, tp=None) -> bytes:
    meta = _basket.BasketMeta.from_json(meta_json)
    with _task_span("engine.unpack", tp, algo=meta.algo), \
            obs.profile.mem_phase("engine.unpack"):
        payload = _fdcache.pread(path, offset, meta.comp_len, expect=ident)
        t0 = time.perf_counter()
        raw = _basket.unpack_basket(payload, meta, dictionary, verify=verify)
    obs.histogram("engine.unpack_s", algo=meta.algo).observe(
        time.perf_counter() - t0)
    obs.counter("engine.unpack.bytes_out", algo=meta.algo).inc(meta.orig_len)
    return raw


def _unpack_task_into(path: str, offset: int, meta_json: dict,
                      dictionary: Optional[bytes], verify: bool, out,
                      ident: Optional[tuple] = None, tp=None) -> int:
    """Read + decompress one basket directly into ``out`` (same-process
    destination slice — the thread-pool / serial scatter path)."""
    meta = _basket.BasketMeta.from_json(meta_json)
    with _task_span("engine.unpack", tp, algo=meta.algo):
        payload = _fdcache.pread(path, offset, meta.comp_len, expect=ident)
        t0 = time.perf_counter()
        n = _basket.unpack_basket_into(payload, meta, out, dictionary,
                                       verify=verify)
    obs.histogram("engine.unpack_s", algo=meta.algo).observe(
        time.perf_counter() - t0)
    obs.counter("engine.unpack.bytes_out", algo=meta.algo).inc(meta.orig_len)
    return n


def _unpack_task_shm(path: str, offset: int, meta_json: dict,
                     dictionary: Optional[bytes], verify: bool,
                     slab_name: str, ident: Optional[tuple] = None, tp=None):
    """Worker body: decode into the slab; only the length crosses back."""
    raw = _unpack_task(path, offset, meta_json, dictionary, verify, ident, tp)
    n = _shmem.write_back(slab_name, raw)
    return raw if n is None else n


def _cfg_fields(cfg: _codec.CompressionConfig) -> tuple:
    return (cfg.algo, cfg.level, cfg.precond, cfg.dictionary)


_buf_len = _basket._nbytes      # byte length of any buffer-protocol object


def _warm_task(delay: float = 0.0):
    if delay:
        time.sleep(delay)
    return None


def _obs_snapshot_task(delay: float = 0.0):
    """Worker body for telemetry folding: each process worker returns (and
    zeroes) its own registry's delta snapshot plus its drained trace ring
    and profile folds, so worker spans/samples are not lost at the pool
    boundary.  The sleep is the warmup trick — N sleeping tasks for N
    workers means one eager worker can't answer them all, so every worker
    gets drained."""
    if delay:
        time.sleep(delay)
    return {"metrics": obs.snapshot(reset=True),
            "trace": obs.trace.drain(),
            "profile": obs.profile.drain()}


def _prof_ctl_task(action: str, hz: float, mem, delay: float = 0.0):
    """Worker body for profiler control: start/stop the sampling profiler
    *inside* a process-pool worker, so a pool workload's flamegraph
    includes worker stacks (folded back by ``_obs_snapshot_task``).  Same
    sleeping-warmup trick — every worker must be reached."""
    if delay:
        time.sleep(delay)
    if action == "start":
        return obs.profile.start(hz=hz, mem=mem)
    obs.profile.stop()
    return True


def _completed_future(fn, *args) -> Future:
    """Run ``fn`` now, wrapped in a Future (mirrors executor semantics)."""
    f: Future = Future()
    try:
        f.set_result(fn(*args))
    except Exception as e:
        f.set_exception(e)
    return f


_SENTINEL = object()

# __main__.__spec__/__file__ are process-global: the hide/spawn/restore
# window below must be exclusive across ALL engines, not just one
_SPAWN_LOCK = threading.Lock()


def _restore_attr(obj, name, saved) -> None:
    if saved is _SENTINEL:
        try:
            delattr(obj, name)
        except AttributeError:
            pass
    else:
        setattr(obj, name, saved)


class CompressionEngine:
    """Bounded worker pool with in-order streaming commit.

    ``workers=0`` degrades to fully serial execution (no pool, no threads),
    which is what makes ``BasketWriter(workers=0)`` bit-for-bit the old
    serial writer with zero overhead.

    ``shm`` controls the process-pool transport: ``"auto"`` (default) uses
    the shared-memory slab pool when the platform supports it, ``False``
    forces the pickled-bytes fallback, ``True`` insists (still falling back
    with a warning if shared memory is unavailable).
    """

    def __init__(self, workers: int = 0, max_inflight: Optional[int] = None,
                 unpack_processes: bool = False,
                 inline_bytes: int = 16384,
                 shm="auto"):
        self.workers = max(int(workers), 0)
        self.max_inflight = max_inflight or max(2 * self.workers, 1)
        # Decompression defaults to the thread pool even for pure-Python
        # codecs: readers are created ad hoc (one per file/branch), and a
        # process pool's worker-import cost would dwarf the decode work.
        # Long steady-state scans can opt in to process decompression.
        self.unpack_processes = unpack_processes
        # Baskets smaller than this compress inline in the caller instead
        # of being shipped to a pool.  Re-tuned for the vectorized codec
        # cores: single-core throughput rose ~3-8x, so the payload size
        # where process-pool pickling/IPC pays for itself moved up — a
        # 16 KiB basket now compresses in well under the round-trip cost.
        self.inline_bytes = max(int(inline_bytes), 0)
        self.shm = shm
        self._thread_pool: Optional[ThreadPoolExecutor] = None
        self._proc_pool: Optional[ProcessPoolExecutor] = None
        self._slab_pool: Optional[_shmem.SlabPool] = None
        self._lock = threading.Lock()
        self._closed = False

    # -- pools -----------------------------------------------------------

    def _pool_for(self, algo: str) -> Optional[Executor]:
        """Thread pool for GIL-releasing codecs, process pool otherwise."""
        if self.workers == 0:
            return None
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            if _codec.is_pure_python(algo):
                if self._proc_pool is None:
                    self._proc_pool = self._spawn_process_pool()
                return self._proc_pool
            if self._thread_pool is None:
                self._thread_pool = ThreadPoolExecutor(
                    self.workers, thread_name_prefix="repro-io")
            return self._thread_pool

    def _slabs(self) -> Optional[_shmem.SlabPool]:
        """The slab pool serving the process transport (None = pickle)."""
        if self.shm is False:
            return None
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            if self._slab_pool is None:
                if not _shmem.available():
                    if self.shm is True:
                        _LOG.warning("shared memory unavailable; "
                                     "falling back to pickle transport")
                    self.shm = False
                    return None
                self._slab_pool = _shmem.SlabPool(
                    max_outstanding=4 * self.workers + 8)
            return self._slab_pool

    def _spawn_process_pool(self) -> ProcessPoolExecutor:
        """Pool for GIL-holding codecs, started so it can never run user
        code or deadlock:

        * *forkserver* context — workers fork from a clean server process,
          never from this (possibly jax-threaded) one, so no lock held by a
          sibling thread can deadlock a child (plain ``fork`` can);
        * every worker is spawned HERE with ``__main__``'s ``__spec__``/
          ``__file__`` temporarily hidden.  forkserver (like spawn)
          otherwise re-imports ``__main__`` per worker, which re-executes
          unguarded user scripts (hanging the pool on the re-entrant
          ``ProcessPoolExecutor``) and crashes outright for stdin scripts
          (``python - <<EOF``: ``__file__`` doesn't exist on disk).  Our
          tasks are module-level functions in this module — workers never
          need ``__main__`` at all, so a bare one is correct.
        """
        try:
            ctx = mp.get_context("forkserver")
        except ValueError:  # pragma: no cover - non-POSIX
            ctx = None
        with _SPAWN_LOCK:
            main = sys.modules.get("__main__")
            saved_spec = getattr(main, "__spec__", _SENTINEL) if main else _SENTINEL
            saved_file = getattr(main, "__file__", _SENTINEL) if main else _SENTINEL
            try:
                if main is not None:
                    main.__spec__ = None
                    main.__file__ = None
                pool = ProcessPoolExecutor(self.workers, mp_context=ctx)
                # submit() is what forks workers; preparation data (incl.
                # the hidden __main__ info) is captured synchronously per
                # spawn, so all workers must spawn inside this window
                futs = [pool.submit(_warm_task, 0.05)
                        for _ in range(self.workers)]
            finally:
                if main is not None:
                    _restore_attr(main, "__spec__", saved_spec)
                    _restore_attr(main, "__file__", saved_file)
        for f in futs:
            f.result()
        return pool

    def warmup(self, algo: str = "zlib") -> None:
        """Pre-start the pool serving ``algo`` (process pools fork lazily;
        benchmarks warm up so curves show steady-state throughput).  The
        warm tasks sleep briefly so one eager worker can't drain them all —
        every worker must spawn (and pay its module import) now."""
        pool = self._pool_for(algo)
        if pool is not None:
            delay = 0.25 if isinstance(pool, ProcessPoolExecutor) else 0.0
            for f in [pool.submit(_warm_task, delay)
                      for _ in range(self.workers)]:
                f.result()

    def collect_obs(self, delay: float = 0.05) -> None:
        """Fold process-pool workers' metric deltas *and trace rings* into
        this process's registry/ring.  Thread workers already share them;
        only the forkserver children have private copies.  Safe to call
        repeatedly — metric snapshots are reset-deltas and rings drain, so
        nothing double-counts and no span is folded twice."""
        if not obs.enabled():
            return
        with self._lock:
            pool = self._proc_pool
        if pool is None:
            return
        try:
            futs = [pool.submit(_obs_snapshot_task, delay)
                    for _ in range(self.workers)]
            for f in futs:
                got = f.result()
                if isinstance(got, dict) and "metrics" in got:
                    obs.merge(got["metrics"])
                    obs.trace.ingest(got.get("trace") or [])
                    obs.profile.ingest(got.get("profile"))
                else:       # a worker running the pre-v2 task body
                    obs.merge(got)
        except Exception:   # broken pool at teardown: telemetry is advisory
            pass

    def profile_workers(self, action: str = "start",
                        hz: float = 0.0, mem=False,
                        delay: float = 0.05) -> None:
        """Start or stop the sampling profiler inside every process-pool
        worker (thread workers already share the parent's profiler).  The
        workers' samples fold back on :meth:`collect_obs` / ``close()``.

        ``"start"`` spawns the process pool if it doesn't exist yet —
        the pool is otherwise lazy (first pure-python pack), and the
        natural call order is "arm the profiler, then run the workload",
        which would silently profile nothing against a not-yet-spawned
        pool.  ``"stop"`` against no pool is a no-op, as is everything
        when obs is disabled or ``workers == 0``."""
        if not obs.enabled() or self.workers == 0:
            return
        with self._lock:
            if self._closed:
                return
            if self._proc_pool is None:
                if action != "start":
                    return
                self._proc_pool = self._spawn_process_pool()
            pool = self._proc_pool
        hz = hz or obs.profile.DEFAULT_HZ
        try:
            futs = [pool.submit(_prof_ctl_task, action, hz, mem, delay)
                    for _ in range(self.workers)]
            for f in futs:
                f.result()
        except Exception:   # broken pool at teardown: profiling is advisory
            pass

    def close(self) -> None:
        self.collect_obs()
        with self._lock:
            self._closed = True
            pools = [p for p in (self._thread_pool, self._proc_pool) if p]
            self._thread_pool = self._proc_pool = None
            slab_pool, self._slab_pool = self._slab_pool, None
        for p in pools:
            p.shutdown(wait=True)
        if slab_pool is not None:   # after shutdown: no worker still maps them
            slab_pool.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()

    # -- ordered map (the pipeline primitive) ----------------------------

    @staticmethod
    def _drain(fut: Future) -> None:
        """Cancel a pending future; if it is already running, wait it out
        and surface (log) its exception — a failing worker must not die
        silently just because the consumer closed the stream early."""
        if fut.cancel():
            return
        try:
            exc = fut.exception()
        except CancelledError:  # pragma: no cover - raced cancellation
            return
        if exc is not None:
            _LOG.warning("repro.io worker failed during pipeline teardown: %r",
                         exc)

    def _map_ordered(self, pool: Optional[Executor], submit_one,
                     items: Iterable) -> Iterator:
        """Yield results in submission order, ≤ max_inflight in flight.

        The deque head is the oldest future; blocking on it while the tail
        keeps compressing is what pipelines compression with the caller's
        sequential disk writes."""
        if pool is None:
            for it in items:
                yield submit_one(None, it)
            return
        pending: deque[Future] = deque()
        depth = obs.gauge("engine.inflight")
        it = iter(items)
        exhausted = False
        try:
            while pending or not exhausted:
                while not exhausted and len(pending) < self.max_inflight:
                    try:
                        item = next(it)
                    except StopIteration:
                        exhausted = True
                        break
                    pending.append(submit_one(pool, item))
                depth.set(len(pending))
                if pending:
                    yield pending.popleft().result()
        finally:
            depth.set(0)
            for f in pending:
                self._drain(f)

    # -- generic compute (shared-service hook) ---------------------------

    def submit(self, fn, *args) -> Future:
        """Run ``fn(*args)`` on the engine's thread pool (inline when
        ``workers=0``) — the shared-compute hook for services built on one
        engine, e.g. the remote basket server's wire transcoding, where
        the C archive codecs release the GIL while decoding."""
        pool = self._pool_for("none")      # the thread pool
        if pool is None:
            return _completed_future(fn, *args)
        return pool.submit(fn, *args)

    # -- compression side ------------------------------------------------

    def pack_stream(self, chunks: Iterable[tuple[int, int, bytes]],
                    cfg: _codec.CompressionConfig) -> Iterator[tuple]:
        """(start, count, buffer) stream -> (start, count, payload, meta)
        stream, in order, compressed ``workers``-wide.  Input buffers may
        be any buffer-protocol object; yielded payloads are bytes-like and
        valid until the next iteration (copy if retained)."""
        pool = self._pool_for(cfg.algo if cfg.enabled else "none")
        fields = _cfg_fields(cfg)
        tp = obs.context.current_traceparent()
        if isinstance(pool, ProcessPoolExecutor):
            slabs = self._slabs()
            if slabs is not None:
                return self._pack_stream_shm(pool, slabs, chunks, fields, tp)
        inline = self.inline_bytes

        def submit_one(p, chunk):
            start, count, raw = chunk
            if p is None:
                return _pack_task(raw, fields, start, count, tp)
            if _buf_len(raw) < inline:
                # small basket: the pool round-trip (pickle + IPC + wakeup)
                # costs more than compressing right here
                return _completed_future(_pack_task, raw, fields, start,
                                         count, tp)
            if isinstance(p, ProcessPoolExecutor) and \
                    not isinstance(raw, (bytes, bytearray)):
                raw = bytes(raw)    # pickle transport needs a real object
            return p.submit(_pack_task, raw, fields, start, count, tp)

        return self._map_ordered(pool, submit_one, chunks)

    def _pack_stream_shm(self, pool: ProcessPoolExecutor,
                         slabs: _shmem.SlabPool, chunks: Iterable,
                         fields: tuple, tp=None) -> Iterator[tuple]:
        """pack_stream over the slab transport: same ordered-commit loop,
        but each in-flight basket owns a slab carrying raw input out and
        the payload back.  Yielded payloads may view the slab — the slab is
        recycled when the generator is advanced."""
        pending: deque = deque()    # (future, slab | None)
        depth = obs.gauge("engine.inflight")
        it = iter(chunks)
        exhausted = False
        inline = self.inline_bytes
        try:
            while pending or not exhausted:
                while not exhausted and len(pending) < self.max_inflight:
                    try:
                        start, count, raw = next(it)
                    except StopIteration:
                        exhausted = True
                        break
                    n = _buf_len(raw)
                    if n < inline:
                        pending.append((_completed_future(
                            _pack_task, raw, fields, start, count, tp), None))
                        continue
                    slab = slabs.acquire(n)
                    try:
                        slab.fill(raw)
                        fut = pool.submit(_pack_task_shm, slab.name, n,
                                          fields, start, count, tp)
                    except BaseException:
                        slabs.release(slab)
                        raise
                    pending.append((fut, slab))
                depth.set(len(pending))
                if pending:
                    fut, slab = pending.popleft()
                    try:
                        start, count, payload, meta = fut.result()
                    except BaseException:
                        if slab is not None:
                            slabs.release(slab)
                        raise
                    if slab is None:
                        yield start, count, payload, meta
                        continue
                    try:
                        if isinstance(payload, int):
                            view = slab.view(payload)
                            try:
                                yield start, count, view, meta
                            finally:
                                view.release()
                        else:   # payload outgrew the slab: came back pickled
                            yield start, count, payload, meta
                    finally:
                        slabs.release(slab)
        finally:
            depth.set(0)
            for fut, slab in pending:
                self._drain(fut)
                if slab is not None:
                    slabs.release(slab)

    # -- decompression side (used by the prefetching reader) -------------

    def submit_unpack(self, path: str, offset: int, meta_json: dict,
                      dictionary: Optional[bytes], verify: bool,
                      ident: Optional[tuple] = None) -> Future:
        """Schedule one basket's read+decompress; returns a Future[bytes].
        ``ident`` is the container's captured (st_dev, st_ino) generation —
        the read fails with ``StaleFileError`` if the path was replaced."""
        algo = meta_json.get("algo", "none") if self.unpack_processes else "none"
        pool = self._pool_for(algo)
        tp = obs.context.current_traceparent()
        if pool is None:
            return _completed_future(_unpack_task, path, offset, meta_json,
                                     dictionary, verify, ident, tp)
        if pool is self._proc_pool:
            slabs = self._slabs()
            if slabs is not None:
                return self._submit_unpack_shm(pool, slabs, path, offset,
                                               meta_json, dictionary, verify,
                                               ident, tp)
        return pool.submit(_unpack_task, path, offset, meta_json,
                           dictionary, verify, ident, tp)

    @staticmethod
    def _submit_unpack_shm(pool, slabs, path, offset, meta_json,
                           dictionary, verify, ident=None, tp=None) -> Future:
        """Process unpack over the slab transport: the worker decodes into
        a slab; the parent's completion callback lifts the bytes out (one
        memcpy instead of a pickled pipe round-trip) and recycles it.
        Falls back to the pickle transport when the pool's outstanding-slab
        cap is hit (a reader scheduling a whole branch at once must not map
        the whole branch in slabs)."""
        slab = slabs.try_acquire(int(meta_json["orig_len"]))
        if slab is None:
            return pool.submit(_unpack_task, path, offset, meta_json,
                               dictionary, verify, ident, tp)
        try:
            inner = pool.submit(_unpack_task_shm, path, offset, meta_json,
                                dictionary, verify, slab.name, ident, tp)
        except BaseException:
            slabs.release(slab)
            raise
        outer: Future = Future()

        def _done(f: Future) -> None:
            try:
                res = f.result()
                data = bytes(slab.view(res)) if isinstance(res, int) else res
            except BaseException as e:
                slabs.release(slab)
                outer.set_exception(e)
                return
            slabs.release(slab)
            outer.set_result(data)

        inner.add_done_callback(_done)
        return outer

    # -- autotuner trials (used by repro.tune) ---------------------------

    def submit_trial(self, sample, cfg_fields: tuple, reps: int = 1,
                     budget_s: Optional[float] = None) -> Future:
        """Schedule one tuner trial (compress + decompress the sampled
        payload under ``cfg_fields``, timed); returns a Future of
        ``(orig_len, comp_len, comp_s, decomp_s)``.  Routed like any
        compression task: thread pool for GIL-releasing codecs, process
        pool for the pure-Python cores — so a trial matrix measures
        ``workers``-wide.  Timings are taken inside the worker; under a
        loaded pool concurrent trials contend for cores, which perturbs
        absolute MB/s but preserves the ranking the tuner selects on."""
        pool = self._pool_for(cfg_fields[0])
        if pool is None:
            return _completed_future(_trial_task, sample, cfg_fields, reps,
                                     budget_s)
        if isinstance(pool, ProcessPoolExecutor) and \
                not isinstance(sample, (bytes, bytearray)):
            sample = bytes(sample)      # pickle transport needs a real object
        return pool.submit(_trial_task, sample, cfg_fields, reps, budget_s)

    def submit_unpack_into(self, path: str, offset: int, meta_json: dict,
                           dictionary: Optional[bytes], verify: bool,
                           out, ident: Optional[tuple] = None) -> Future:
        """Schedule one basket's read+decompress **into** ``out`` (a
        writable 1-D uint8 view of the destination array slice); returns a
        Future[int] of bytes written.  Thread/serial workers decode in
        place; process workers decode remotely and the completion callback
        memcpys into ``out``."""
        algo = meta_json.get("algo", "none") if self.unpack_processes else "none"
        pool = self._pool_for(algo)
        tp = obs.context.current_traceparent()
        if pool is None:
            return _completed_future(_unpack_task_into, path, offset,
                                     meta_json, dictionary, verify, out,
                                     ident, tp)
        if pool is self._proc_pool:
            slabs = self._slabs()
            slab = slabs.try_acquire(int(meta_json["orig_len"])) \
                if slabs is not None else None
            try:
                if slab is not None:
                    # decode lands in the slab; scatter it straight into
                    # the destination slice — one memcpy, no intermediate
                    inner = pool.submit(_unpack_task_shm, path, offset,
                                        meta_json, dictionary, verify,
                                        slab.name, ident, tp)
                else:
                    inner = pool.submit(_unpack_task, path, offset,
                                        meta_json, dictionary, verify,
                                        ident, tp)
            except BaseException:
                if slab is not None:
                    slabs.release(slab)
                raise
            outer: Future = Future()

            def _done(f: Future) -> None:
                try:
                    res = f.result()
                    if isinstance(res, int):
                        view = slab.view(res)
                        out[:res] = np.frombuffer(view, dtype=np.uint8)
                        view.release()
                        n = res
                    else:
                        src = np.frombuffer(res, dtype=np.uint8)
                        out[:src.size] = src
                        n = src.size
                except BaseException as e:
                    if slab is not None:
                        slabs.release(slab)
                    outer.set_exception(e)
                    return
                if slab is not None:
                    slabs.release(slab)
                outer.set_result(n)

            inner.add_done_callback(_done)
            return outer
        return pool.submit(_unpack_task_into, path, offset, meta_json,
                           dictionary, verify, out, ident, tp)
