"""paligemma-3b [vlm] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma backbone.  The SigLIP frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (B, 256, d_model);
image tokens get bidirectional (prefix-LM) attention.  [arXiv:2407.07726]"""

from repro.models import ModelConfig, LayerPattern

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab=257216,
    n_img_tokens=256,           # 224px / 14 patch -> 16 x 16
    embed_scale=True,
    ffn_act="gelu",
    tie_embeddings=True,
    pattern=(LayerPattern("attn", "dense"),),
)
