"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating, logit softcap.  [arXiv:2408.00118]"""

from repro.models import ModelConfig, LayerPattern

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=14336,
    vocab=256_000,
    attn_softcap=50.0,
    final_softcap=30.0,
    local_window=4096,
    post_norm=True,
    embed_scale=True,
    ffn_act="gelu",
    tie_embeddings=True,
    # alternating sliding-window ("local") and full ("global") attention
    pattern=(LayerPattern("local", "dense"), LayerPattern("attn", "dense")),
)
