"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 —
Finch: data-dependent decay.  [arXiv:2404.05892]"""

from repro.models import ModelConfig, LayerPattern

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                 # 2048 / rwkv_head_dim
    n_kv_heads=32,
    d_head=64,
    d_ff=7168,
    vocab=65536,
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
    tie_embeddings=False,
    pattern=(LayerPattern("rwkv", "rwkv_cm"),),
)
