"""Config registry: ``--arch <id>`` lookup, input shapes, reduced smokes.

Every assigned architecture is one module exposing ``CONFIG``;
``get_config(name)`` resolves it, ``reduced(cfg)`` shrinks it to a
CPU-smoke scale preserving every structural flag (pattern, MoE, softcaps,
prefix, enc-dec), and ``SHAPES``/``shapes_for`` define the assigned
(arch x input-shape) grid.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models import ModelConfig

__all__ = ["ARCHS", "get_config", "list_archs", "reduced",
           "SHAPES", "shapes_for", "ShapeSpec"]

ARCHS = {
    "qwen3-8b": "qwen3_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "gemma2-9b": "gemma2_9b",
    "stablelm-12b": "stablelm_12b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "paligemma-3b": "paligemma_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.CONFIG


def list_archs():
    return sorted(ARCHS)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> list[ShapeSpec]:
    """The assigned shape set for an arch.  ``long_500k`` needs a
    sub-quadratic decode path, so pure full-attention archs skip it
    (DESIGN.md §6); ssm/hybrid archs run all four."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out


def reduced(cfg: ModelConfig) -> ModelConfig:
    """CPU-smoke variant: tiny dims, same structure (pattern incl. MoE /
    local-global / mamba-attn interleave, softcaps, prefix, enc-dec)."""
    # keep the GQA group structure but cap the ratio at 4
    n_kv = min(cfg.n_kv_heads, 2)
    n_heads = n_kv * min(cfg.n_heads // cfg.n_kv_heads, 4)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=len(cfg.pattern) * min(cfg.n_groups, 2),
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=16,
        d_ff=128,
        d_ff_expert=96 if cfg.n_experts else None,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        rwkv_head_dim=16,
        rwkv_decay_lora=8,
        ssm_state=8,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_img_tokens=8 if cfg.n_img_tokens else 0,
        local_window=min(cfg.local_window, 8) if cfg.local_window else 0,
    )
