"""The paper's own test configuration (§2): an artificially-generated
ROOT-tree-like event file with 2,000 events, used by the figure benchmarks
and by the compression test-suite.

Structure mirrors a CMS-NanoAOD-style tree (the paper's Fig. 6 sample):
float kinematics columns, small-int multiplicity columns, and var-size
(C-array) branches whose serialization yields the (payload, offset-array)
pairs the paper's §2.2 preconditioner discussion is about.
"""

from __future__ import annotations

import dataclasses

__all__ = ["PaperIOConfig", "PAPER_IO"]


@dataclasses.dataclass(frozen=True)
class PaperIOConfig:
    n_events: int = 2000            # the paper's test-tree size
    basket_bytes: int = 32 * 1024   # ROOT default basket size
    seed: int = 20190511            # the paper's "accessed" date, for fun
    # survey axes (paper Figures 2-3): every codec at levels 1, 6, 9 (+0)
    levels: tuple = (1, 6, 9)
    codecs: tuple = ("zlib", "lz4", "zstd", "lzma",
                     "repro-deflate", "repro-deflate-ref", "repro-zstd")
    preconds: tuple = ("none", "shuffle4", "bitshuffle4", "delta4+shuffle4")


PAPER_IO = PaperIOConfig()
