"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave (one attention layer
per 8-layer block, at index 4), MoE every other layer, no positional
embeddings (mamba carries position).  [arXiv:2403.19887]"""

from repro.models import ModelConfig, LayerPattern

_M, _A = "mamba", "attn"
_D, _E = "dense", "moe"

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=65536,
    rope_theta=0.0,             # jamba: no explicit positional encoding
    n_experts=16,
    experts_per_token=2,
    d_ff_expert=14336,
    capacity_factor=1.25,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=False,
    # 8-layer jamba block: attention at index 4, MoE on odd layers
    pattern=(
        LayerPattern(_M, _D), LayerPattern(_M, _E),
        LayerPattern(_M, _D), LayerPattern(_M, _E),
        LayerPattern(_A, _D), LayerPattern(_M, _E),
        LayerPattern(_M, _D), LayerPattern(_M, _E),
    ),
)
