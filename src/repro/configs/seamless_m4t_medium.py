"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16, i.e. MHA)
d_ff=4096 vocab=256206 — enc-dec, multimodal.  The audio frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, T, d_model).
[arXiv:2308.11596]"""

from repro.models import ModelConfig, LayerPattern

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                # decoder layers
    n_enc_layers=12,            # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=256206,
    cross_attn=True,
    ffn_act="gelu",
    tie_embeddings=True,
    pattern=(LayerPattern("attn", "dense"),),
)
