"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, shared expert, MoE interleaved
every other layer (the a17b active-param budget).  [hf:meta-llama/Llama-4]"""

from repro.models import ModelConfig, LayerPattern

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    experts_per_token=1,
    d_ff_expert=8192,
    shared_expert=True,
    capacity_factor=1.25,
    rope_theta=500_000.0,
    tie_embeddings=False,
    # llama4-maverick interleaves dense and MoE FFN layers
    pattern=(LayerPattern("attn", "dense"), LayerPattern("attn", "moe")),
)
