"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1, shared expert, MoE every layer.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""

from repro.models import ModelConfig, LayerPattern

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    experts_per_token=1,
    d_ff_expert=8192,
    shared_expert=True,
    capacity_factor=1.25,
    rope_theta=500_000.0,
    tie_embeddings=False,
    pattern=(LayerPattern("attn", "moe"),),
)
