"""Cost model: measured trials, objectives, and Pareto selection.

The paper's survey measures every (algorithm, level, preconditioner) on
real branch data and reads the answer off a three-axis trade surface:
compression ratio, compression speed, decompression speed.  This module is
that surface as code:

* :class:`TrialResult` — one measured point (a candidate config run on a
  sampled payload).
* :class:`Objective` — a declared operating point: log-linear weights over
  (ratio, write MB/s, read MB/s).  ``min_bytes`` / ``max_write_tput`` /
  ``max_read_tput`` are the pure axes (with a whisper of weight on the
  other axes so exact ties break toward better all-round configs);
  ``production`` / ``analysis`` / ``checkpoint`` are the paper's §3 use
  cases as weighted blends.
* :func:`pareto_front` / :func:`select` — dominated candidates can never
  win any objective, so selection filters to the Pareto front first and
  then takes the objective's argmax with a fully deterministic tie-break.

Scores are log-linear (``w·log(metric)``) so weights express *relative*
improvements — "10% better ratio" trades against "10% faster decode" at
the weight ratio, independent of absolute magnitudes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence

from repro.core.codec import CompressionConfig

__all__ = ["TrialResult", "Objective", "OBJECTIVES", "resolve_objective",
           "pareto_front", "select"]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class TrialResult:
    """One measured (config, cost) point on the survey surface."""

    algo: str
    level: int
    precond: str
    orig_len: int        # sample bytes in
    comp_len: int        # compressed bytes out
    comp_s: float        # best-of-reps compress wall seconds
    decomp_s: float      # best-of-reps decompress wall seconds

    @property
    def ratio(self) -> float:
        return self.orig_len / max(self.comp_len, 1)

    @property
    def comp_mbps(self) -> float:
        return self.orig_len / max(self.comp_s, _EPS) / 1e6

    @property
    def decomp_mbps(self) -> float:
        return self.orig_len / max(self.decomp_s, _EPS) / 1e6

    def config(self, dictionary: Optional[bytes] = None) -> CompressionConfig:
        return CompressionConfig(algo=self.algo, level=self.level,
                                 precond=self.precond, dictionary=dictionary)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "TrialResult":
        return TrialResult(**{f.name: d[f.name]
                              for f in dataclasses.fields(TrialResult)})


@dataclasses.dataclass(frozen=True)
class Objective:
    """Log-linear operating point over (ratio, write tput, read tput)."""

    name: str
    w_ratio: float = 0.0
    w_write: float = 0.0
    w_read: float = 0.0

    def score(self, t: TrialResult) -> float:
        return (self.w_ratio * math.log(max(t.ratio, _EPS))
                + self.w_write * math.log(max(t.comp_mbps, _EPS))
                + self.w_read * math.log(max(t.decomp_mbps, _EPS)))


OBJECTIVES: dict[str, Objective] = {
    # pure axes (tiny secondary weights = deterministic sane tie-breaks)
    "min_bytes": Objective("min_bytes", 1.0, 0.01, 0.01),
    "max_write_tput": Objective("max_write_tput", 0.01, 1.0, 0.0),
    "max_read_tput": Objective("max_read_tput", 0.01, 0.0, 1.0),
    # the paper's §3 operating points as blends
    "production": Objective("production", 1.0, 0.05, 0.25),   # ratio-bound, CPU-rich
    "analysis": Objective("analysis", 0.3, 0.05, 1.0),        # decode-speed-bound
    "checkpoint": Objective("checkpoint", 0.6, 0.5, 0.1),     # write-often read-rarely
}


def resolve_objective(obj) -> Objective:
    """Accept an :class:`Objective`, a registered name, or a weight dict
    ``{"ratio": w, "write": w, "read": w}``."""
    if isinstance(obj, Objective):
        return obj
    if isinstance(obj, str):
        try:
            return OBJECTIVES[obj]
        except KeyError:
            raise ValueError(
                f"unknown objective {obj!r}; valid objectives: "
                f"{', '.join(sorted(OBJECTIVES))}") from None
    if isinstance(obj, dict):
        extra = set(obj) - {"name", "ratio", "write", "read"}
        if extra:
            raise ValueError(f"unknown objective weight keys {sorted(extra)}; "
                             "use 'ratio', 'write', 'read'")
        return Objective(name=obj.get("name", "custom"),
                         w_ratio=float(obj.get("ratio", 0.0)),
                         w_write=float(obj.get("write", 0.0)),
                         w_read=float(obj.get("read", 0.0)))
    raise TypeError(f"objective must be str, dict, or Objective, "
                    f"got {type(obj).__name__}")


def _dominates(a: TrialResult, b: TrialResult) -> bool:
    """a dominates b: no worse on every axis, strictly better on one."""
    ge = (a.ratio >= b.ratio and a.comp_mbps >= b.comp_mbps
          and a.decomp_mbps >= b.decomp_mbps)
    gt = (a.ratio > b.ratio or a.comp_mbps > b.comp_mbps
          or a.decomp_mbps > b.decomp_mbps)
    return ge and gt


def pareto_front(trials: Iterable[TrialResult]) -> list[TrialResult]:
    """Non-dominated subset of ``trials`` (input order preserved)."""
    ts = list(trials)
    return [t for t in ts
            if not any(_dominates(o, t) for o in ts if o is not t)]


def select(trials: Sequence[TrialResult], objective) -> TrialResult:
    """The Pareto-optimal trial maximizing ``objective``.

    Deterministic: exact score ties break by (ratio, write tput, read
    tput, then config identity), so re-running selection on the same cost
    table always returns the same config.
    """
    obj = resolve_objective(objective)
    front = pareto_front(trials)
    if not front:
        raise ValueError("no trials to select from")
    return max(front, key=lambda t: (obj.score(t), t.ratio, t.comp_mbps,
                                     t.decomp_mbps,
                                     (t.algo, t.level, t.precond)))
