"""Deterministic stratified payload sampling for trial compressions.

The tuner never compresses a whole branch to decide its codec: it measures
trial configs on a small *sample* that has to be (a) cheap, (b)
deterministic — same branch bytes, same sample, same decision — and (c)
representative of the whole branch, not just its head.  A head-only sample
is exactly the failure mode the paper's offset-array discussion warns
about: data whose first basket looks monotone/low-entropy while the tail
does not (appended columns, mixed-phase event files) gets mistuned.

``stratified_sample`` therefore takes ``windows`` equal-width windows at
evenly spaced offsets across the full buffer — head, body and tail all
contribute — and concatenates them.  Window boundaries are aligned down to
``itemsize`` so preconditioners (shuffle/delta/bitshuffle) see whole
elements; window *joins* introduce one artificial discontinuity each,
which costs delta-style preconditioners a few bytes per window and is
identical for every candidate, so rankings are unaffected.

``byte_entropy`` is the drift detector's cheap distribution fingerprint:
order-0 Shannon entropy in bits/byte from a 256-bin histogram.
"""

from __future__ import annotations

import numpy as np

__all__ = ["stratified_sample", "sample_offsets", "byte_entropy",
           "DEFAULT_SAMPLE_BYTES", "DEFAULT_WINDOWS"]

DEFAULT_SAMPLE_BYTES = 1 << 16   # 64 KiB of trial payload per branch
DEFAULT_WINDOWS = 8


def _as_u8(buf) -> np.ndarray:
    a = buf if isinstance(buf, np.ndarray) else np.frombuffer(buf, np.uint8)
    if a.dtype != np.uint8:
        a = a.view(np.uint8)
    return a.reshape(-1)


def sample_offsets(n: int, itemsize: int = 1,
                   target_bytes: int = DEFAULT_SAMPLE_BYTES,
                   windows: int = DEFAULT_WINDOWS) -> tuple[list[int], int]:
    """(window start offsets, window byte width) for an ``n``-byte buffer.

    Deterministic in (n, itemsize, target_bytes, windows).  Starts are
    evenly spaced over [0, n - width] and aligned down to ``itemsize``;
    the width is ``target_bytes // windows`` aligned likewise.  When the
    buffer fits in ``target_bytes`` a single [0, n) window covers it.
    """
    itemsize = max(int(itemsize), 1)
    if n <= target_bytes:
        return [0], n
    k = max(int(windows), 1)
    w = max((target_bytes // k) // itemsize * itemsize, itemsize)
    k = min(k, max(n // w, 1))
    if k <= 1:
        return [0], min(w, n)
    span = n - w
    starts = [(span * i // (k - 1)) // itemsize * itemsize for i in range(k)]
    # evenly spaced + aligned can collide only when windows overlap; keep
    # first occurrence so the sample never double-counts a region
    seen, out = set(), []
    for s in starts:
        if s not in seen:
            seen.add(s)
            out.append(s)
    return out, w


def stratified_sample(buf, itemsize: int = 1,
                      target_bytes: int = DEFAULT_SAMPLE_BYTES,
                      windows: int = DEFAULT_WINDOWS) -> np.ndarray:
    """Concatenated stratified windows of ``buf`` as a uint8 array.

    Zero-copy when the whole buffer fits in ``target_bytes`` (the returned
    array views ``buf``); otherwise one small allocation of
    ``<= target_bytes`` bytes.
    """
    a = _as_u8(buf)
    starts, w = sample_offsets(a.size, itemsize, target_bytes, windows)
    if len(starts) == 1 and w == a.size:
        return a
    return np.concatenate([a[s:s + w] for s in starts])


def byte_entropy(buf) -> float:
    """Order-0 Shannon entropy of ``buf`` in bits per byte (0.0 .. 8.0)."""
    a = _as_u8(buf)
    if a.size == 0:
        return 0.0
    counts = np.bincount(a, minlength=256)
    p = counts[counts > 0] / a.size
    return float(-(p * np.log2(p)).sum())
