"""The measurement-driven autotuner: the paper's survey as a feedback loop.

``core.policy`` hard-codes the survey's *conclusions* (static profiles +
dtype heuristics).  This module re-runs the survey's *method* online, on
the user's actual branch data:

    sampler -> trial matrix -> cost model -> decision cache -> drift loop

Per branch, the :class:`Tuner` draws a deterministic stratified sample
(:mod:`repro.tune.sampler`), runs trial compressions for a candidate
matrix built from the codec/preconditioner registries (optionally in
parallel through a shared :class:`repro.io.engine.CompressionEngine`),
fits the measured (ratio, compress MB/s, decompress MB/s) cost table, and
selects the Pareto-optimal config under the declared objective
(:mod:`repro.tune.model`).  Decisions are cached per branch; writers
persist them in the BasketFile TOC so appends and re-opens reuse them
without re-measurement (:func:`load_decisions` / :meth:`Tuner.from_file`).

Cheap drift guard: each decision remembers the byte-entropy of the sample
it was measured on, and every written basket's observed ratio feeds a
per-branch EWMA.  A reuse request re-fingerprints the fresh data; if the
entropy or the observed ratio has shifted past the thresholds, the cached
decision is discarded and the branch re-tunes.

``policy.choose`` remains the zero-measurement fallback: branches too
small to sample meaningfully (``min_tune_bytes``), non-numeric blobs, and
any trial-matrix failure all fall back to the static heuristic.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro import obs
from repro.core.codec import CompressionConfig
from repro.core.policy import PROFILES, choose, precond_for_array

from .model import (Objective, TrialResult, resolve_objective, select)
from .sampler import (DEFAULT_SAMPLE_BYTES, DEFAULT_WINDOWS, byte_entropy,
                      stratified_sample)

__all__ = ["Decision", "Tuner", "default_candidates", "load_decisions"]


@dataclasses.dataclass
class Decision:
    """One cached per-branch choice plus the evidence it rests on."""

    trial: TrialResult
    objective: str
    sample_entropy: float
    n_candidates: int = 0
    source: str = "measured"      # "measured" | "shared" | "persisted"

    def config(self, dictionary: Optional[bytes] = None) -> CompressionConfig:
        return self.trial.config(dictionary)

    def to_json(self) -> dict:
        d = self.trial.to_json()
        d.update(objective=self.objective,
                 sample_entropy=round(self.sample_entropy, 4),
                 n_candidates=self.n_candidates)
        return d

    @staticmethod
    def from_json(d: dict) -> "Decision":
        return Decision(trial=TrialResult.from_json(d),
                        objective=d.get("objective", "checkpoint"),
                        sample_entropy=float(d.get("sample_entropy", -1.0)),
                        n_candidates=int(d.get("n_candidates", 0)),
                        source="persisted")


class _Drift:
    """Per-branch EWMA of observed basket compression ratios."""

    __slots__ = ("ewma", "n")

    def __init__(self):
        self.ewma = 0.0
        self.n = 0

    def update(self, ratio: float, alpha: float = 0.3) -> None:
        self.ewma = ratio if self.n == 0 else \
            (1.0 - alpha) * self.ewma + alpha * ratio
        self.n += 1


def default_candidates(arr: np.ndarray, objective: Objective
                       ) -> list[tuple[str, int, str]]:
    """(algo, level, precond) trial matrix from the registries.

    Algo/level pairs come from the static :data:`PROFILES` table (so the
    tuned choice can never lose to a static profile it refused to try on
    the axes it is allowed to win), pruned by objective — a candidate
    class that cannot win the declared objective is not worth measuring:

    * ``lzma`` only when the objective is ratio-bound (``w_ratio >= 0.8``):
      its trials are expensive and it can't win a throughput axis;
    * pure-Python codecs (the profile table only ever contributes our LZ4
      block format here) dropped when writes carry real weight
      (``w_write >= 0.5``) — they compress at single-digit MB/s — and when
      the objective is ratio-bound — an LZ-only format with no entropy
      stage can't win ``min_bytes`` against the deflate/lzma family;
    * pure-Python high-compression levels (``>= 4``) dropped everywhere:
      they share level 1's decoder (same block format, ~same decode
      speed), so on the one axis they could still win — read throughput —
      they measure nothing level 1 doesn't, at 3-10x the trial cost;
    * high levels (``>= 4``) dropped when the objective is purely
      write-bound (``w_write >= 0.8``): more search never compresses
      faster.

    Preconditioners: the dtype heuristic, the plain byte shuffle, and
    none.
    """
    from repro.core.codec import is_pure_python

    heur = precond_for_array(arr)
    preconds = {heur, "none"}
    dt = arr.dtype
    if dt.kind in "iu":
        preconds.add(f"shuffle{min(dt.itemsize, 8)}")
    elif dt.kind == "f" or dt.name == "bfloat16" or \
            (dt.kind == "V" and dt.itemsize == 2):
        preconds.add(f"shuffle{max(dt.itemsize, 2)}")
    pairs, seen = [], set()
    for prof, p in PROFILES.items():
        algo, level = p["algo"], p["level"]
        if algo == "none" or (algo, level) in seen:
            continue
        if algo == "lzma" and objective.w_ratio < 0.8:
            continue
        if is_pure_python(algo) and (objective.w_write >= 0.5
                                     or objective.w_ratio >= 0.8
                                     or level >= 4):
            continue
        if level >= 4 and objective.w_write >= 0.8:
            continue
        seen.add((algo, level))
        pairs.append((algo, level))
    if objective.w_ratio >= 0.8:
        # pure ratio axis: within one algo only its strongest level can
        # win, so lower levels are dead trials
        top = {}
        for a, lv in pairs:
            top[a] = max(top.get(a, -1), lv)
        pairs = [(a, lv) for a, lv in pairs if lv == top[a]]
    elif objective.w_read >= 0.8:
        # pure decode axis: decode speed is ~level-independent within an
        # algo, so one level each measures the axis; the lowest is the
        # cheapest to trial
        lo = {}
        for a, lv in pairs:
            lo[a] = min(lo.get(a, 99), lv)
        pairs = [(a, lv) for a, lv in pairs if lv == lo[a]]
    return [(a, lv, pc) for a, lv in pairs for pc in sorted(preconds)]


class Tuner:
    """Per-branch adaptive (algo, level, precond) selection.

    ``objective`` — a name from :data:`repro.tune.model.OBJECTIVES`
    (``min_bytes`` / ``max_write_tput`` / ``max_read_tput`` or the paper's
    ``production`` / ``analysis`` / ``checkpoint`` blends), a weight dict,
    or an :class:`Objective`.

    ``engine`` — optional shared :class:`repro.io.engine.CompressionEngine`;
    when it has workers, trial compressions run concurrently through its
    pools (:meth:`CompressionEngine.submit_trial`).

    Thread-safe: one tuner may serve many producer threads (the
    ``producers>1`` checkpoint path); tuning a given branch is serialized.
    """

    def __init__(self, objective="checkpoint", *,
                 candidates: Optional[Sequence[tuple]] = None,
                 sample_bytes: int = DEFAULT_SAMPLE_BYTES,
                 sample_windows: int = DEFAULT_WINDOWS,
                 min_tune_bytes: int = 1 << 16,
                 trial_reps: int = 1,
                 trial_budget_s: Optional[float] = None,
                 engine=None,
                 fallback_profile: Optional[str] = None,
                 drift_ratio: float = 0.35,
                 drift_entropy: float = 0.75,
                 drift_min_baskets: int = 4,
                 share_signatures: bool = True):
        self.objective = resolve_objective(objective)
        self.candidates = list(candidates) if candidates is not None else None
        self.sample_bytes = int(sample_bytes)
        self.sample_windows = int(sample_windows)
        self.min_tune_bytes = int(min_tune_bytes)
        self.trial_reps = max(int(trial_reps), 1)
        # per-candidate wall budget: a slow candidate is ranked from a
        # probe (an eighth of the sample) instead of running in full —
        # ratio-bound objectives get a larger budget because their win
        # condition (compressed bytes) benefits from full-sample ratios
        if trial_budget_s is None:
            trial_budget_s = 0.06 if self.objective.w_ratio >= 0.8 else 0.04
        self.trial_budget_s = float(trial_budget_s)
        self.engine = engine
        # too-small-to-measure branches use the static profile nearest the
        # declared objective
        axis_fallback = {"min_bytes": "archive", "max_write_tput": "wire",
                         "max_read_tput": "analysis"}
        self.fallback_profile = fallback_profile or axis_fallback.get(
            self.objective.name,
            self.objective.name if self.objective.name in PROFILES
            else "checkpoint")
        self.drift_ratio = float(drift_ratio)
        self.drift_entropy = float(drift_entropy)
        self.drift_min_baskets = int(drift_min_baskets)
        # content-signature sharing: branches with the same (dtype,
        # heuristic precond, quantized sample entropy, objective) run the
        # trial matrix once — a corpus of N same-statistics weight planes
        # pays for one measurement, not N.  Every branch still gets its
        # own persisted decision and its own drift state (a branch whose
        # data later diverges re-tunes individually).
        self.share_signatures = bool(share_signatures)
        self._sig_cache: dict[tuple, Decision] = {}
        self.decisions: dict[str, Decision] = {}
        self.stats = {"tuned": 0, "reused": 0, "shared": 0, "fallback": 0,
                      "retuned": 0, "trials": 0, "trial_s": 0.0}
        self._drift: dict[str, _Drift] = {}
        self._lock = threading.RLock()
        self._branch_locks: dict[str, threading.Lock] = {}

    # -- persistence -----------------------------------------------------

    def decisions_json(self, names=None) -> dict[str, dict]:
        """JSON-able {branch: decision} map (the BasketFile TOC payload)."""
        with self._lock:
            keep = set(names) if names is not None else None
            return {n: d.to_json() for n, d in self.decisions.items()
                    if keep is None or n in keep}

    def load(self, mapping: dict[str, dict]) -> None:
        """Seed the cache with persisted decisions (no re-measurement).
        Malformed entries (foreign format revision, partial corruption)
        are skipped — those branches simply re-tune."""
        with self._lock:
            for name, d in mapping.items():
                try:
                    self.decisions[name] = Decision.from_json(d)
                except (KeyError, TypeError, ValueError):
                    continue

    @classmethod
    def from_file(cls, path: str, objective=None, **kw) -> "Tuner":
        """A tuner pre-seeded with the decisions persisted in ``path``'s
        TOC — the append/re-open path: matching branches reuse their
        persisted config with zero trial compressions."""
        decisions = load_decisions(path)
        if objective is None:
            objs = {d.get("objective") for d in decisions.values()}
            objective = objs.pop() if len(objs) == 1 else "checkpoint"
        t = cls(objective, **kw)
        t.load(decisions)
        return t

    # -- the decision loop ------------------------------------------------

    def config_for(self, name: str, data, dtype=None) -> CompressionConfig:
        """The per-branch decision: cached -> reused (after the drift
        check), new + big enough -> measured, otherwise the static
        ``policy.choose`` fallback.

        ``data`` is the branch array, or any buffer (+ ``dtype``) — e.g.
        the first staged chunk on the streaming checkpoint path.
        """
        arr = self._as_array(data, dtype)
        with self._lock:
            dec = self.decisions.get(name)
            if dec is not None:
                if dec.objective == self.objective.name \
                        and not self._stale(name, dec, arr):
                    self.stats["reused"] += 1
                    obs.counter("tune.decisions", outcome="reused").inc()
                    return dec.config()
                self.decisions.pop(name, None)
                self._drift.pop(name, None)
                retune = True
            else:
                retune = False
            if arr.nbytes < self.min_tune_bytes:
                self.stats["fallback"] += 1
                obs.counter("tune.decisions", outcome="fallback").inc()
                return choose(name, arr, self.fallback_profile)
        t0 = time.perf_counter()
        sample = self._sample(arr)
        h = byte_entropy(sample)
        sig = None
        if self.share_signatures:
            sig = (arr.dtype.str, precond_for_array(arr),
                   round(h * 4) / 4, self.objective.name)
        # trial compressions run OUTSIDE the tuner-wide lock: concurrent
        # producers tune different branches in parallel and observe()
        # never stalls behind a trial matrix.  The tuning lock is keyed by
        # signature when sharing is on — same-statistics branches
        # serialize so the first wave pays ONE matrix, not one each —
        # and by branch name otherwise.
        with self._lock:
            blk = self._branch_locks.setdefault(sig or name,
                                                threading.Lock())
        with blk:
            with self._lock:
                dec = self.decisions.get(name)
                if dec is not None and dec.objective == self.objective.name:
                    # another thread tuned this branch while we waited
                    self.stats["reused"] += 1
                    obs.counter("tune.decisions", outcome="reused").inc()
                    return dec.config()
                # a drift-triggered re-tune must NOT be satisfied from the
                # signature cache: the fingerprint (order-0 entropy) can't
                # see the order/correlation change the ratio EWMA caught,
                # so the cached entry may be exactly the stale decision —
                # re-measure, then overwrite it
                if sig is not None and not retune:
                    hit = self._sig_cache.get(sig)
                    if hit is not None:
                        dec = Decision(trial=hit.trial,
                                       objective=hit.objective,
                                       sample_entropy=h, n_candidates=0,
                                       source="shared")
                        self.decisions[name] = dec
                        self._drift.pop(name, None)
                        self.stats["shared"] += 1
                        self.stats["trial_s"] += time.perf_counter() - t0
                        obs.counter("tune.decisions", outcome="shared").inc()
                        return dec.config()
            dec = self._tune(name, arr, sample, h, sig, t0)
            with self._lock:
                if dec is None:     # every trial failed: static fallback
                    self.stats["fallback"] += 1
                    obs.counter("tune.decisions", outcome="fallback").inc()
                    return choose(name, arr, self.fallback_profile)
                kind = "retuned" if retune else "tuned"
                self.stats[kind] += 1
                obs.counter("tune.decisions", outcome=kind).inc()
                return dec.config()

    def observe(self, name: str, meta) -> None:
        """Feed one written basket's metadata to the drift detector."""
        orig = getattr(meta, "orig_len", None)
        comp = getattr(meta, "comp_len", None)
        if orig is None:            # plain dict (TOC-shaped) metas work too
            orig, comp = meta.get("orig_len", 0), meta.get("comp_len", 0)
        if not orig:
            return
        with self._lock:
            self._drift.setdefault(name, _Drift()).update(
                orig / max(comp, 1))

    # -- internals --------------------------------------------------------

    @staticmethod
    def _as_array(data, dtype) -> np.ndarray:
        if isinstance(data, np.ndarray):
            arr = data
        elif hasattr(data, "dtype") and hasattr(data, "shape"):
            arr = np.asarray(data)      # jax / array-likes
        else:
            arr = np.frombuffer(data, dtype=np.dtype(dtype or np.uint8))
        if arr.dtype.name == "bfloat16":
            arr = arr.view(np.uint16)
        return arr

    def _sample(self, arr: np.ndarray) -> np.ndarray:
        flat = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
        # relative cap: never sample more than ~3% of the branch, so the
        # trial matrix stays a bounded fraction of the branch's own write
        # cost (the <=5% tuning-overhead budget); floor at 16 KiB so small
        # branches still measure something meaningful
        eff = min(self.sample_bytes, max(flat.size // 32, 1 << 14))
        return stratified_sample(flat, max(arr.dtype.itemsize, 1),
                                 eff, self.sample_windows)

    def _stale(self, name: str, dec: Decision, arr: np.ndarray) -> bool:
        d = self._drift.get(name)
        if d is not None and d.n >= self.drift_min_baskets:
            ref = max(dec.trial.ratio, 1e-9)
            if abs(d.ewma - dec.trial.ratio) > self.drift_ratio * ref:
                return True
        if dec.sample_entropy >= 0.0 and arr.nbytes >= self.min_tune_bytes:
            h = byte_entropy(self._sample(arr))
            if abs(h - dec.sample_entropy) > self.drift_entropy:
                return True
        return False

    def _tune(self, name: str, arr: np.ndarray, sample: np.ndarray,
              entropy: float, sig, t0: float) -> Optional[Decision]:
        from repro.io.engine import _trial_task
        cands = self.candidates if self.candidates is not None \
            else default_candidates(arr, self.objective)
        with obs.profile.mem_phase("tune.matrix"):
            trials = self._run_trials(sample, cands)
        # fairness pass: a budget-cut candidate was measured on a probe,
        # and ratio (and fixed-overhead-diluted MB/s) at probe size is not
        # comparable to full-sample numbers — so before the final pick,
        # re-measure any probe-sized finalist on the full sample (bounded:
        # top 3 by score, budget off)
        full_n = len(sample)
        for t in sorted(trials, key=self.objective.score, reverse=True)[:3]:
            if t.orig_len >= full_n:
                continue
            try:
                r = _trial_task(sample, (t.algo, t.level, t.precond, None),
                                self.trial_reps)
            except Exception:
                continue
            trials[trials.index(t)] = TrialResult(t.algo, t.level,
                                                  t.precond, *r)
        obs.histogram("tune.matrix_s").observe(time.perf_counter() - t0)
        with self._lock:
            self.stats["trials"] += len(cands)
            self.stats["trial_s"] += time.perf_counter() - t0
            if not trials:
                return None
            best = select(trials, self.objective)
            dec = Decision(trial=best, objective=self.objective.name,
                           sample_entropy=entropy,
                           n_candidates=len(cands))
            self.decisions[name] = dec
            if sig is not None:
                self._sig_cache[sig] = dec      # refreshes a stale entry
            self._drift.pop(name, None)
            return dec

    def _run_trials(self, sample, cands) -> list[TrialResult]:
        from repro.core.codec import is_pure_python
        from repro.io.engine import _trial_task
        trials: list[TrialResult] = []

        def run_inline(c):
            try:
                trials.append(TrialResult(*c, *_trial_task(
                    sample, (*c, None), self.trial_reps,
                    self.trial_budget_s)))
            except Exception:
                pass                # unusable candidate (bad precond, ...)

        if self.engine is not None and getattr(self.engine, "workers", 0):
            futs = []
            for c in cands:
                # pure-Python candidates would make the engine spawn its
                # process pool (~1 s of forkserver warmup) for a
                # probe-sized task; trial them inline instead
                if is_pure_python(c[0]):
                    run_inline(c)
                else:
                    futs.append((c, self.engine.submit_trial(
                        sample, (*c, None), self.trial_reps,
                        self.trial_budget_s)))
            for c, f in futs:
                try:
                    trials.append(TrialResult(*c, *f.result()))
                except Exception:
                    continue
            return trials
        for c in cands:
            run_inline(c)
        return trials


def load_decisions(path: str) -> dict[str, dict]:
    """The tuning decisions persisted in a BasketFile's TOC (may be {})."""
    from repro.core.bfile import BasketFile
    f = BasketFile(path, verify=False)
    try:
        return dict(f.tuning)
    finally:
        f.close()
