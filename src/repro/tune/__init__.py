"""repro.tune — measurement-driven compression autotuning (DESIGN.md §11).

The paper's survey as an online subsystem: sample real branch payloads,
run trial compressions through the codec/preconditioner registries, fit a
per-branch (ratio, write MB/s, read MB/s) cost model, and pick the
Pareto-optimal config under a declared objective.  Decisions cache per
branch, persist in the BasketFile TOC, and are guarded by a cheap
ratio/entropy drift detector.

Entry points: ``Tuner`` (the subsystem), ``OBJECTIVES`` (the operating
points), and the ``tuner=``/``objective=`` arguments on ``BasketWriter``,
``save_pytree``/``CheckpointManager``, and ``write_token_shards``.
``repro.core.policy.choose`` remains the zero-measurement fallback.
"""

from .model import (OBJECTIVES, Objective, TrialResult, pareto_front,
                    resolve_objective, select)
from .sampler import byte_entropy, sample_offsets, stratified_sample
from .tuner import Decision, Tuner, default_candidates, load_decisions

__all__ = [
    "OBJECTIVES", "Objective", "TrialResult", "pareto_front",
    "resolve_objective", "select",
    "byte_entropy", "sample_offsets", "stratified_sample",
    "Decision", "Tuner", "default_candidates", "load_decisions",
]
