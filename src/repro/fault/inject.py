"""Seeded fault plans — reproducible descriptions of what to break.

A :class:`FaultPlan` is a list of :class:`FaultRule`\\ s plus a seed.
Whether a rule fires for a given event is a pure function of
``(seed, rule index, connection id, direction, frame number)`` — a
blake2b hash mapped to [0, 1) and compared against the rule's
probability.  No RNG state, no ``random`` module: the same plan applied
to the same traffic fires the same faults, every run, in every process.
(Python's builtin ``hash()`` is deliberately *not* used — it is salted
per process, which is exactly the non-determinism this module exists to
remove.)

Rule kinds (what the proxy / pread hook does when a rule fires):

========  ============================================================
drop      swallow the frame (receiver waits → client times out)
delay     sleep ``delay_s`` before forwarding (stall; hedging bait)
reset     hard RST on the client-side socket (connection reset)
garble    flip one deterministic payload byte (corrupt frame/basket)
short     forward a prefix of the frame, then close (torn stream)
========  ============================================================

Triggers compose (all present must match): ``verb`` (catalog / readv /
ping / stats), ``direction`` (``"c2s"`` / ``"s2c"``), ``every`` (fire on
every Nth matching frame), ``after_byte`` (only once this many bytes
passed the connection), ``p`` (probability), ``max_fires`` (stop after K
firings, plan-wide per rule).
"""

from __future__ import annotations

import hashlib
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

__all__ = ["FaultRule", "FaultPlan", "parse_rule", "pread_fault_hook",
           "rot_container"]

KINDS = ("drop", "delay", "reset", "garble", "short")


@dataclass(frozen=True)
class FaultRule:
    """One kind of damage plus the conditions under which it happens."""
    kind: str
    p: float = 1.0                      # fire probability per match
    direction: Optional[str] = None     # "c2s" | "s2c" | None (both)
    verb: Optional[str] = None          # "readv", "catalog", ... | None
    every: Optional[int] = None         # fire on every Nth matching frame
    after_byte: Optional[int] = None    # only after N bytes on the conn
    delay_s: float = 0.05               # stall length for kind="delay"
    max_fires: Optional[int] = None     # total firing budget

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(want one of {KINDS})")
        if self.direction not in (None, "c2s", "s2c"):
            raise ValueError(f"direction must be c2s/s2c, not "
                             f"{self.direction!r}")


def _unit(seed: int, rule_idx: int, conn_id: int, direction: str,
          frame_no: int) -> float:
    """Deterministic uniform [0, 1) for one (rule, frame) event."""
    key = f"{seed}|{rule_idx}|{conn_id}|{direction}|{frame_no}".encode()
    h = hashlib.blake2b(key, digest_size=8).digest()
    return int.from_bytes(h, "little") / 2.0 ** 64


class FaultPlan:
    """A seeded set of rules; :meth:`decide` answers "which rules fire for
    this event".  Thread-safe (the proxy evaluates from pump threads)."""

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._fired = [0] * len(self.rules)

    def decide(self, *, conn_id: int = 0, direction: str = "c2s",
               verb: Optional[str] = None, frame_no: int = 0,
               offset: int = 0) -> list[FaultRule]:
        """The rules that fire for one frame event (usually 0 or 1)."""
        out = []
        for i, r in enumerate(self.rules):
            if r.direction is not None and r.direction != direction:
                continue
            if r.verb is not None and r.verb != verb:
                continue
            if r.after_byte is not None and offset < r.after_byte:
                continue
            if r.every is not None:
                if frame_no <= 0 or frame_no % r.every != 0:
                    continue
            if r.p < 1.0 and _unit(self.seed, i, conn_id, direction,
                                   frame_no) >= r.p:
                continue
            with self._lock:
                if r.max_fires is not None and self._fired[i] >= r.max_fires:
                    continue
                self._fired[i] += 1
            out.append(r)
        return out

    def counts(self) -> dict[str, int]:
        """Total firings per kind — soak gates assert every planned fault
        actually happened (a chaos run that injected nothing proves
        nothing)."""
        with self._lock:
            fired = list(self._fired)
        out: dict[str, int] = {}
        for r, n in zip(self.rules, fired):
            out[r.kind] = out.get(r.kind, 0) + n
        return out

    def reset(self) -> None:
        with self._lock:
            self._fired = [0] * len(self.rules)


def parse_rule(spec: str) -> FaultRule:
    """Parse a CLI rule string: ``kind[:k=v,k=v,...]``.

    Keys: ``p`` (probability), ``dir`` (c2s/s2c), ``verb``, ``every``,
    ``after`` (bytes), ``ms`` (delay in milliseconds), ``max`` (firing
    budget).  Examples::

        garble:p=0.02,dir=s2c
        delay:verb=readv,ms=100,p=0.5
        reset:every=50
        short:after=4096,max=1
    """
    kind, _, rest = spec.partition(":")
    kw: dict = {}
    if rest:
        for item in rest.split(","):
            k, _, v = item.partition("=")
            k = k.strip()
            if not _:
                raise ValueError(f"malformed rule item {item!r} in {spec!r}")
            if k == "p":
                kw["p"] = float(v)
            elif k == "dir":
                kw["direction"] = v
            elif k == "verb":
                kw["verb"] = v
            elif k == "every":
                kw["every"] = int(v)
            elif k == "after":
                kw["after_byte"] = int(v)
            elif k == "ms":
                kw["delay_s"] = float(v) / 1000.0
            elif k == "max":
                kw["max_fires"] = int(v)
            else:
                raise ValueError(f"unknown rule key {k!r} in {spec!r}")
    return FaultRule(kind=kind.strip(), **kw)


def garble_byte(buf: bytes, seed: int, tag: int = 0,
                lo: int = 0) -> bytes:
    """Flip one deterministically-chosen byte of ``buf`` at index ≥ ``lo``
    (the proxy keeps frame headers intact — corrupting a length field
    turns "corrupt payload" into "receiver hangs forever", a different
    and less useful fault)."""
    if len(buf) <= lo:
        return buf
    span = len(buf) - lo
    i = lo + int(_unit(seed, 71, tag, "g", span) * span)
    i = min(i, len(buf) - 1)
    out = bytearray(buf)
    out[i] ^= 0x5A
    return bytes(out)


def rot_container(path: str, *, seed: int = 0, every: int = 3,
                  phase: int = 0,
                  max_baskets: Optional[int] = None) -> list[tuple[str, int]]:
    """Deterministically rot a container *on disk* — bit-rot you can
    reproduce.  Walks the TOC in container *write order* (ascending file
    offset) and garbles one payload byte (:func:`garble_byte`, via
    ``os.pwrite``) of every ``every``-th basket, starting at position
    ``phase``; returns the damaged ``(branch, index)`` list.

    With a parity sidecar of stripe width ``k`` the stripes are k-wide
    runs of *consecutive* baskets in write order — the same walk order —
    so ``every >= k + 1`` guarantees at most one damaged member per
    stripe: every hit healable from single parity.  Different ``seed``/
    ``phase`` per replica rots *different* baskets, the setup anti-entropy
    repair converges.  ``max_baskets`` bounds the total damage."""
    from repro.core.bfile import BasketFile
    if every < 1:
        raise ValueError(f"every must be >= 1, got {every}")
    with BasketFile(path, verify=False) as bf:
        order = sorted(
            ((int(b["offset"]), name, i, int(b["meta"]["comp_len"]))
             for name in bf.branch_names()
             for i, b in enumerate(bf.branches[name]["baskets"])))
        plan = [(name, i, off, ln)
                for n, (off, name, i, ln) in enumerate(order)
                if n % every == phase % every]
    if max_baskets is not None:
        plan = plan[:max_baskets]
    damaged = []
    fd = os.open(path, os.O_RDWR)
    try:
        for name, i, off, ln in plan:
            buf = os.pread(fd, ln, off)
            bad = garble_byte(buf, seed, tag=off)
            if bad == buf:          # zero-length payload: nothing to flip
                continue
            j = next(k for k in range(len(buf)) if buf[k] != bad[k])
            os.pwrite(fd, bad[j:j + 1], off + j)
            damaged.append((name, i))
        os.fsync(fd)
    finally:
        os.close(fd)
    return damaged


def pread_fault_hook(*, match: Optional[str] = None, kind: str = "garble",
                     every: int = 1, seed: int = 0,
                     max_fires: Optional[int] = None,
                     delay_s: float = 0.05):
    """Build a hook for :func:`repro.io.fdcache.set_fault_hook` — local
    storage faults underneath a live reader or server.

    ``match`` substring-filters the path (None = every pread); ``kind``
    is ``garble`` (flip a byte), ``short`` (drop the last byte → reader
    sees a torn read), or ``delay`` (sleep ``delay_s`` — a slow device);
    ``every`` fires on every Nth matching call; ``max_fires`` bounds the
    total.  Returns the hook; install/remove with ``set_fault_hook``.
    The hook exposes ``hook.fired`` for test assertions."""
    if kind not in ("garble", "short", "delay"):
        raise ValueError(f"pread fault kind {kind!r} not supported")
    state = {"calls": 0, "fired": 0}
    lock = threading.Lock()

    def hook(path: str, offset: int, buf: bytes) -> bytes:
        with lock:
            if match is not None and match not in path:
                return buf
            state["calls"] += 1
            if state["calls"] % max(every, 1) != 0:
                return buf
            if max_fires is not None and state["fired"] >= max_fires:
                return buf
            state["fired"] += 1
            hook.fired = state["fired"]
        if kind == "delay":
            time.sleep(delay_s)
            return buf
        if kind == "short":
            return buf[:-1] if buf else buf
        return garble_byte(buf, seed, tag=offset)

    hook.fired = 0
    return hook
