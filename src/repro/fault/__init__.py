"""repro.fault — deterministic fault injection (DESIGN.md §14).

The failure-hardening in ``repro.remote`` and ``repro.core.bfile`` is only
as real as the failures it has been run against.  This package supplies
those failures *reproducibly*:

* :class:`FaultPlan` / :class:`FaultRule` — a seeded description of what
  to break, when: drop/delay/reset/garble/short-read, triggered per verb,
  per direction, per frame count, or per byte offset.  Decisions are pure
  functions of ``(seed, rule, connection, frame)`` — the same plan
  replays the same faults, so a chaos-soak failure is a test case, not a
  weather report.
* :class:`ChaosProxy` — an in-process TCP proxy speaking raw RBSP framing
  that applies a plan between a real client and a real server.
* :func:`pread_fault_hook` — the local-storage analogue: a hook for
  ``repro.io.fdcache.set_fault_hook`` that garbles, truncates, or delays
  basket preads underneath a live server or local reader.
* :func:`rot_container` — persistent bit-rot: deterministically garble
  every Nth basket of a container *on disk* (TOC walk + ``pwrite``), the
  damage the self-healing tier (DESIGN.md §15) exists to repair.  With
  parity width ``k``, ``every >= k + 1`` keeps every stripe healable.

``tools/chaos.py`` is the CLI: stand a chaos proxy in front of any
running basket server and point clients at it.
"""

from .inject import (FaultPlan, FaultRule, parse_rule, pread_fault_hook,
                     rot_container)
from .proxy import ChaosProxy

__all__ = ["FaultPlan", "FaultRule", "parse_rule", "pread_fault_hook",
           "rot_container", "ChaosProxy"]
