"""ChaosProxy — an in-process RBSP-aware TCP proxy that applies a FaultPlan.

Sits between a real client and a real basket server::

    plan = FaultPlan([FaultRule("garble", p=0.02, direction="s2c")], seed=7)
    with ChaosProxy(srv.host, srv.port, plan) as px:
        f = RemoteBasketFile(host=px.host, port=px.port, path="data.bskt")

Each accepted client connection opens one upstream connection and two pump
threads (client→server, server→client).  Pumps parse *raw RBSP framing*
(header → body/payload lengths → exact byte counts) so faults land on
frame boundaries: a ``garble`` flips a byte strictly after the 21-byte
header (corrupting a length field would hang the receiver instead of
failing its checksum — a different, less useful fault), a ``drop``
swallows exactly one frame, a ``short`` tears mid-frame and closes, a
``reset`` sends a hard RST.  Verb and per-connection frame counts feed
the plan's triggers, so "delay every 3rd s2c readv response" means
exactly that.

Deterministic: connection ids are assigned in accept order and frame
numbers per direction, so with a single client the same plan replays the
same faults (see :mod:`repro.fault.inject`).
"""

from __future__ import annotations

import socket
import struct
import threading
from typing import Optional

from repro.remote import protocol as P

from .inject import FaultPlan, garble_byte

__all__ = ["ChaosProxy"]

_HEADER = struct.Struct("<4sBIQI")
# frame type -> plan verb (responses map to their request's verb so one
# rule spec covers both directions)
_VERB = {P.REQ_CATALOG: "catalog", P.RESP_CATALOG: "catalog",
         P.REQ_READV: "readv", P.RESP_READV: "readv",
         P.REQ_PING: "ping", P.RESP_PING: "ping",
         P.REQ_STATS: "stats", P.RESP_STATS: "stats",
         P.RESP_BUSY: "busy", P.RESP_ERROR: "error"}


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks, got = [], 0
    while got < n:
        b = sock.recv(min(n - got, 1 << 20))
        if not b:
            raise EOFError
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


class _Conn:
    """One proxied connection pair plus its pump threads."""

    def __init__(self, proxy: "ChaosProxy", client: socket.socket,
                 conn_id: int):
        self.proxy = proxy
        self.client = client
        self.conn_id = conn_id
        self.upstream = socket.create_connection(
            (proxy.upstream_host, proxy.upstream_port), timeout=30)
        for s in (self.client, self.upstream):
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._closed = threading.Event()
        self._threads = [
            threading.Thread(target=self._pump, daemon=True,
                             args=(self.client, self.upstream, "c2s"),
                             name=f"chaos-c2s-{conn_id}"),
            threading.Thread(target=self._pump, daemon=True,
                             args=(self.upstream, self.client, "s2c"),
                             name=f"chaos-s2c-{conn_id}"),
        ]
        for t in self._threads:
            t.start()

    def _pump(self, src: socket.socket, dst: socket.socket,
              direction: str) -> None:
        plan = self.proxy.plan
        frame_no = 0
        offset = 0
        try:
            while not self._closed.is_set():
                head = _recv_exact(src, _HEADER.size)
                magic, ftype, body_len, payload_len, _sum = \
                    _HEADER.unpack(head)
                if magic != P.MAGIC:
                    # not RBSP (or we lost sync): fall back to dumb
                    # byte-pumping for the rest of the stream
                    dst.sendall(head)
                    self._raw_pump(src, dst)
                    return
                rest = _recv_exact(src, body_len + payload_len)
                frame = head + rest
                frame_no += 1
                offset += len(frame)
                fired = plan.decide(conn_id=self.conn_id,
                                    direction=direction,
                                    verb=_VERB.get(ftype),
                                    frame_no=frame_no, offset=offset)
                if not self._apply(fired, frame, dst, frame_no):
                    return
        except (EOFError, OSError):
            pass
        finally:
            self.close()

    def _apply(self, fired, frame: bytes, dst: socket.socket,
               frame_no: int) -> bool:
        """Apply fired rules to one frame; False = stream is dead."""
        for r in fired:
            if r.kind == "delay":
                self._closed.wait(r.delay_s)
            elif r.kind == "drop":
                return True            # swallow the frame, keep pumping
            elif r.kind == "reset":
                self._reset()
                return False
            elif r.kind == "garble":
                frame = garble_byte(frame, self.proxy.plan.seed,
                                    tag=frame_no, lo=_HEADER.size)
            elif r.kind == "short":
                try:
                    dst.sendall(frame[:max(len(frame) // 2, 1)])
                except OSError:
                    pass
                self.close()
                return False
        try:
            dst.sendall(frame)
        except OSError:
            return False
        return True

    def _raw_pump(self, src: socket.socket, dst: socket.socket) -> None:
        while not self._closed.is_set():
            b = src.recv(1 << 16)
            if not b:
                return
            dst.sendall(b)

    def _reset(self) -> None:
        """Hard RST toward the client: SO_LINGER(1, 0) + close."""
        try:
            self.client.setsockopt(
                socket.SOL_SOCKET, socket.SO_LINGER,
                struct.pack("ii", 1, 0))
        except OSError:
            pass
        self.close()

    def close(self) -> None:
        if self._closed.is_set():
            return
        self._closed.set()
        for s in (self.client, self.upstream):
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass
        self.proxy._forget(self)


class ChaosProxy:
    """Listen on ``host:port`` (0 = ephemeral), forward to the upstream
    basket server, applying ``plan`` to every RBSP frame in both
    directions.  Context-manageable; :meth:`close` tears down the
    listener and every live proxied connection."""

    def __init__(self, upstream_host: str, upstream_port: int,
                 plan: Optional[FaultPlan] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self.upstream_host = upstream_host
        self.upstream_port = int(upstream_port)
        self.plan = plan if plan is not None else FaultPlan([])
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, int(port)))
        self._lsock.listen(64)
        self._conns: set[_Conn] = set()
        self._lock = threading.Lock()
        self._next_id = 0
        self._closing = False
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="chaos-accept")
        self._accept_thread.start()

    @property
    def host(self) -> str:
        return self._lsock.getsockname()[0]

    @property
    def port(self) -> int:
        return self._lsock.getsockname()[1]

    def _accept_loop(self) -> None:
        while True:
            try:
                client, _addr = self._lsock.accept()
            except OSError:
                return                  # listener closed
            with self._lock:
                if self._closing:
                    client.close()
                    return
                cid = self._next_id
                self._next_id += 1
            try:
                conn = _Conn(self, client, cid)
            except OSError:
                client.close()          # upstream refused
                continue
            with self._lock:
                self._conns.add(conn)

    def _forget(self, conn: _Conn) -> None:
        with self._lock:
            self._conns.discard(conn)

    def close(self) -> None:
        with self._lock:
            if self._closing:
                return
            self._closing = True
            conns = list(self._conns)
        try:
            self._lsock.close()
        except OSError:
            pass
        for c in conns:
            c.close()
        self._accept_thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
