"""ModelConfig — one dataclass describing every assigned architecture.

The layer stack is expressed as a repeating ``pattern`` of ``(mixer, ffn)``
pairs (see model.py): the pattern is unrolled inside one "group" and groups
are scanned, so heterogeneous stacks (gemma2 local/global, jamba 1:7
mamba:attn with alternating MoE) compile to one compact scanned HLO.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "LayerPattern"]


@dataclasses.dataclass(frozen=True)
class LayerPattern:
    mixer: str = "attn"       # attn | local | mamba | rwkv
    ffn: str = "dense"        # dense | moe | none (rwkv channel-mix is its own)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | ssm | hybrid | encdec | vlm

    # --- core dims
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = 32000

    # --- attention variants
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    rope_theta: float = 10000.0
    local_window: int = 0          # sliding-window size for "local" mixers
    norm_eps: float = 1e-6
    post_norm: bool = False        # gemma2: post-ffn/attn extra norms
    embed_scale: bool = False      # gemma: x *= sqrt(d_model)

    # --- layer pattern (repeated n_layers // len(pattern) times)
    pattern: tuple = (LayerPattern(),)

    # --- FFN / MoE
    ffn_act: str = "silu"
    n_experts: int = 0
    experts_per_token: int = 1
    d_ff_expert: Optional[int] = None
    capacity_factor: float = 1.25
    shared_expert: bool = False
    router_aux_weight: float = 0.01
    router_z_weight: float = 0.001

    # --- SSM (mamba) dims
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # --- RWKV dims
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    # --- enc-dec
    n_enc_layers: int = 0          # >0 => encoder-decoder
    cross_attn: bool = False

    # --- VLM
    n_img_tokens: int = 0          # >0 => image-prefix prefix-LM

    # --- global
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    q_chunk: int = 0               # flash-style query chunking for long prefill

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: n_layers={self.n_layers} not divisible by pattern {len(self.pattern)}"
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(p.mixer in ("mamba", "rwkv") for p in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token decode shape? True when no mixer
        needs an O(seq) KV cache *scan over full history per step* — i.e.
        recurrent-state mixers.  Hybrids qualify (attn layers keep a KV cache
        but decode cost is O(S) memory, O(S) attention per step on 1/8 of
        layers; the spec assigns long_500k to ssm/hybrid)."""
        return any(p.mixer in ("mamba", "rwkv") for p in self.pattern)
