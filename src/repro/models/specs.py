"""ParamSpec: one param definition -> init / abstract / sharding.

Every model parameter is declared once as a ``ParamSpec(shape, axes)`` where
``axes`` names each dimension with a *logical* axis ("embed", "heads",
"ff", "vocab", "experts", ...).  From that single declaration we derive:

* ``init_params``      — real arrays (smoke tests, examples)
* ``abstract_params``  — ShapeDtypeStructs, no allocation (dry-run)
* ``map_logical``      — PartitionSpec per param via the divisibility-aware
                         rule engine in ``repro.parallel.sharding``
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "init_params", "abstract_params", "map_logical", "tree_paths"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                 # logical axis name (or None) per dim
    init: str = "normal"        # normal | zeros | ones | scaled
    scale: float = 1.0          # stddev multiplier (normal) / fan-in override
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_paths(tree, prefix=""):
    """Flatten a nested-dict spec tree to {dotted.path: leaf}."""
    out = {}
    if _is_spec(tree) or not isinstance(tree, dict):
        out[prefix.rstrip(".")] = tree
        return out
    for k, v in tree.items():
        out.update(tree_paths(v, f"{prefix}{k}."))
    return out


def init_params(spec_tree, key, param_dtype=None):
    """Materialize real arrays from a spec tree (used by smoke tests)."""
    flat = tree_paths(spec_tree)
    keys = jax.random.split(key, max(len(flat), 1))
    out_flat = {}
    for (path, spec), k in zip(sorted(flat.items()), keys):
        dtype = param_dtype or spec.dtype
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.full(spec.shape, spec.scale, dtype)  # "ones" = constant ``scale``
        else:
            fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
            std = spec.scale / np.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)
        out_flat[path] = arr
    return _unflatten(out_flat)


def abstract_params(spec_tree, param_dtype=None):
    """ShapeDtypeStruct tree — the dry-run stand-in (no allocation)."""
    flat = tree_paths(spec_tree)
    out = {p: jax.ShapeDtypeStruct(s.shape, param_dtype or s.dtype)
           for p, s in flat.items()}
    return _unflatten(out)


def map_logical(spec_tree, fn: Callable[[ParamSpec], Any]):
    """Apply ``fn(spec)`` per leaf, preserving structure (sharding derivation)."""
    flat = tree_paths(spec_tree)
    return _unflatten({p: fn(s) for p, s in flat.items()})


def _unflatten(flat: dict):
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree
