"""Core transformer building blocks: norms, RoPE, GQA attention, dense FFN.

All functions are pure: ``(params, inputs, cfg) -> outputs``.  Each block
has a ``*_specs`` twin returning the ParamSpec tree so init/abstract/
sharding derive from one definition (see specs.py).

Attention covers every assigned-arch variant behind flags:
  * GQA with arbitrary kv_heads (incl. MQA kv=1 — paligemma)
  * qk-norm (qwen3), QKV bias (qwen2.5), attn-logit softcap (gemma2)
  * sliding-window "local" layers (gemma2 alternating pattern)
  * bidirectional / prefix-LM masks (seamless encoder, paligemma image
    prefix), cross-attention (seamless decoder)
  * KV-cache decode with dynamic position update
  * query-chunked (flash-style) scoring for long prefill so the S x S
    score tensor never materializes beyond (q_chunk x S)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .specs import ParamSpec
from repro.parallel.actctx import constrain

__all__ = [
    "rms_norm", "rope", "attn_specs", "attention", "ffn_specs", "ffn",
    "norm_specs",
]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def norm_specs(d_model: int) -> dict:
    return {"scale": ParamSpec((d_model,), ("embed",), init="ones")}


PERF_FLAGS = {
    # §Perf iteration A: avoid materializing an fp32 copy of the residual
    # stream in rms_norm.  XLA turns the bf16->f32 convert that a
    # conventional rms does first into an f32 SHADOW COPY of the whole
    # scan-saved residual stack (measured: +7.5 GiB live + 2x convert
    # traffic per group at 400B scale; see EXPERIMENTS.md §Perf).  The
    # einsum-variance form keeps products bf16 with fp32 accumulation
    # (exactly the MXU contract) and applies the inverse in bf16.
    "rms_einsum": False,
    # §Perf iteration B: store softmax probabilities in bf16 (row stats
    # stay fp32) so the (q_chunk, T) tensors — the largest attention
    # traffic — halve.
    "softmax_bf16_probs": False,
}


def rms_norm(p: dict, x: jnp.ndarray, eps: float = 1e-6,
             zero_centered: bool = False) -> jnp.ndarray:
    """RMSNorm; fp32 statistics either via a full fp32 copy (baseline,
    paper-faithful numerics) or via einsum accumulation (§Perf A)."""
    dt = x.dtype
    scale = p["scale"].astype(jnp.float32)
    if zero_centered:
        scale = 1.0 + scale
    if PERF_FLAGS["rms_einsum"] and dt != jnp.float32:
        var = jnp.einsum("...d,...d->...", x, x,
                         preferred_element_type=jnp.float32) / x.shape[-1]
        inv = jax.lax.rsqrt(var + eps)[..., None]
        return x * (inv * scale).astype(dt)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    return (xn * scale).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (B, S, H, D) (D even), positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attn_specs(cfg, cross: bool = False) -> dict:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    sp = {
        "wq": ParamSpec((d, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, Dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        sp["bq"] = ParamSpec((H, Dh), ("heads", "head_dim"), init="zeros")
        sp["bk"] = ParamSpec((KV, Dh), ("kv_heads", "head_dim"), init="zeros")
        sp["bv"] = ParamSpec((KV, Dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        sp["q_norm"] = ParamSpec((Dh,), (None,), init="ones")
        sp["k_norm"] = ParamSpec((Dh,), (None,), init="ones")
    return sp


def _mask_bias(mode: str, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
               window: int = 0, prefix_len: int = 0,
               k_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Additive mask (B?, S_q, S_k) in fp32: 0 = attend, -inf = blocked."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if mode == "bidir":
        ok = jnp.ones_like(q + k, dtype=bool)
    elif mode == "causal":
        ok = k <= q
    elif mode == "sliding":
        ok = (k <= q) & (k > q - window)
    elif mode == "prefix":
        # bidirectional within the first prefix_len positions, causal after
        ok = (k <= q) | (k < prefix_len)
    else:  # pragma: no cover
        raise ValueError(mode)
    if k_valid is not None:
        ok = ok & k_valid[..., None, :]
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _scores_softmax_values(q, k, v, bias, softcap: float, scale: float):
    """q: (B,S,KV,G,D), k/v: (B,T,KV,D), bias: (B,1|S?,T) broadcastable.
    Returns (B,S,KV,G,D) fp32."""
    if PERF_FLAGS["softmax_bf16_probs"] and q.dtype != jnp.float32:
        # bf16 operands, fp32 accumulation (the MXU contract) — no fp32
        # copies of q/k hit HBM
        s = jnp.einsum("bskgd,btkd->bkgst",
                       (q.astype(jnp.float32) * scale).astype(q.dtype), k,
                       preferred_element_type=jnp.float32)
    else:
        s = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = s + bias[:, None, None, :, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, -1e30)  # rows that are fully masked
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    if PERF_FLAGS["softmax_bf16_probs"] and v.dtype != jnp.float32:
        # §Perf B: probabilities carry ~8 significant bits anyway after
        # exp; storing them bf16 halves the dominant (S_q, T) traffic.
        return jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))


def attention(p: dict, x: jnp.ndarray, cfg, *,
              mode: str = "causal",
              positions: Optional[jnp.ndarray] = None,
              cache: Optional[dict] = None,
              cache_pos: Optional[jnp.ndarray] = None,
              update_cache: bool = True,
              build_cache: int = 0,
              cache_dtype=jnp.bfloat16,
              kv_input: Optional[jnp.ndarray] = None,
              window: int = 0,
              prefix_len: int = 0,
              q_chunk: int = 0) -> tuple[jnp.ndarray, Optional[dict]]:
    """GQA attention.  Returns (out (B,S,d), cache-or-None).

    * training: cache None, build_cache 0 -> full self-attention over x.
    * prefill: build_cache = max_len -> also returns {"k","v"} padded to
      max_len with this sequence's (roped) kv written at positions 0..S-1.
    * decode: cache {"k","v"} (B, T, KV, D); x is (B, 1, d); cache_pos a
      scalar int32 — new kv written at that slot, attention over the cache.
    * cross-attention: kv_input (B, T, d) (encoder output, training) or
      cache given with update_cache=False (decode over static encoder kv —
      no rope, every slot valid).
    """
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = H // KV
    cdt = x.dtype
    scale = Dh ** -0.5
    is_cross = (kv_input is not None) or (cache is not None and not update_cache)

    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt)),
                  ("dp", None, "tp", None))
    if not (cache is not None and not update_cache):
        kv_src = kv_input if kv_input is not None else x
        k = constrain(jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(cdt)),
                      ("dp", None, "tp", None))
        v = constrain(jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(cdt)),
                      ("dp", None, "tp", None))
    else:
        k = v = None                      # static cross cache: kv precomputed
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
        if k is not None:
            k = k + p["bk"].astype(cdt)
            v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        q = rms_norm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        if k is not None:
            k = rms_norm({"scale": p["k_norm"]}, k, cfg.norm_eps)

    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if not is_cross and cfg.rope_theta > 0:           # no rope on cross-attn
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and update_cache:
        # decode: write this step's kv into the cache at cache_pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
    elif cache is not None:
        ck, cv = cache["k"], cache["v"]               # static (cross) cache
        new_cache = cache

    if cache is not None:
        T = ck.shape[1]
        k_pos = jnp.arange(T, dtype=jnp.int32)[None]                  # (1, T)
        if update_cache:
            k_valid = (k_pos <= cache_pos)
            if mode == "sliding" and window:
                k_valid = k_valid & (k_pos > cache_pos - window)
        else:
            k_valid = jnp.ones_like(k_pos, dtype=bool)
        bias = jnp.where(k_valid, 0.0, -jnp.inf).astype(jnp.float32)[:, None, :]
        bias = jnp.broadcast_to(bias, (B, S, T))
        q5 = q.reshape(B, S, KV, G, Dh)
        out = _scores_softmax_values(q5, ck.astype(cdt), cv.astype(cdt),
                                     bias, cfg.attn_softcap, scale)
    else:
        q5 = q.reshape(B, S, KV, G, Dh)
        k_pos_full = positions if kv_input is None else jnp.broadcast_to(
            jnp.arange(k.shape[1], dtype=jnp.int32)[None], (B, k.shape[1]))
        if q_chunk and S > q_chunk and S % q_chunk == 0:
            # flash-style: per-chunk bias so no (S, S) mask materializes
            nq = S // q_chunk
            q_blocks = q5.reshape(B, nq, q_chunk, KV, G, Dh).transpose(1, 0, 2, 3, 4, 5)
            p_blocks = positions.reshape(B, nq, q_chunk).transpose(1, 0, 2)

            @jax.checkpoint   # recompute probs in bwd: never save (c,T) scores
            def step(_, qb):
                qq, pp = qb
                bb = _mask_bias(mode, pp, k_pos_full, window=window,
                                prefix_len=prefix_len)                # (B,c,T)
                o = _scores_softmax_values(qq, k, v, bb, cfg.attn_softcap, scale)
                return 0, o

            _, outs = jax.lax.scan(step, 0, (q_blocks, p_blocks))
            out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, G, Dh)
        else:
            bias_full = _mask_bias(mode, positions, k_pos_full, window=window,
                                   prefix_len=prefix_len)             # (B,S,T)
            out = _scores_softmax_values(q5, k, v, bias_full, cfg.attn_softcap, scale)
        if build_cache:
            zk = jnp.zeros((B, build_cache, KV, Dh), cache_dtype)
            new_cache = {
                "k": jax.lax.dynamic_update_slice(zk, k.astype(cache_dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(zk, v.astype(cache_dtype), (0, 0, 0, 0)),
            }

    out = out.astype(cdt).reshape(B, S, H, Dh)
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    return proj, new_cache


# ---------------------------------------------------------------------------
# Dense FFN (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def ffn_specs(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "w_down": ParamSpec((d_ff, d_model), ("ff", "embed")),
    }


def ffn(p: dict, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    cdt = x.dtype
    g = constrain(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cdt)),
                  ("dp", None, "tp"))
    u = constrain(jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cdt)),
                  ("dp", None, "tp"))
    if act == "gelu":
        g = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(cdt)
    else:
        g = jax.nn.silu(g.astype(jnp.float32)).astype(cdt)
    return jnp.einsum("bsf,fd->bsd", g * u, p["w_down"].astype(cdt))
