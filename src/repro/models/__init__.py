"""repro.models — unified LM stack covering the 10 assigned architectures.

Pure-functional modules (params are pytrees of arrays) with a ParamSpec
layer that yields, from one definition: real initialized params (smoke
tests), ShapeDtypeStructs (dry-run), and NamedShardings (pjit).
"""

from .specs import ParamSpec, init_params, abstract_params, map_logical
from .config import ModelConfig, LayerPattern
from .model import Model

__all__ = ["ParamSpec", "init_params", "abstract_params", "map_logical",
           "Model", "ModelConfig", "LayerPattern"]
