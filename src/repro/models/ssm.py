"""Mamba (selective SSM) mixer — jamba's attention-free layer.

TPU adaptation notes (DESIGN.md §3): the CUDA reference fuses the selective
scan so the (d_inner, d_state) hidden state never leaves SRAM.  On TPU we
express the same recurrence as a *chunked associative scan*: an outer
``lax.scan`` over sequence chunks carries the (B, d_inner, d_state) state in
registers/VMEM-resident arrays, and the inner ``lax.associative_scan`` gives
log-depth parallelism within a chunk.  The chunk size bounds the transient
(chunk, B, d_inner, d_state) decay/input tensors — the TPU analogue of the
kernel's SRAM blocking — and the outer scan is the remat boundary.

Recurrence (Mamba-1, per channel c and state n):
    h_t = exp(dt_t[c] * A[c, n]) * h_{t-1} + dt_t[c] * B_t[n] * x_t[c]
    y_t[c] = sum_n C_t[n] * h_t[c, n] + D[c] * x_t[c]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .specs import ParamSpec
from repro.parallel.actctx import constrain

__all__ = ["mamba_specs", "mamba", "mamba_step", "init_mamba_state"]

PERF_FLAGS = {"mamba_bf16_y": False}   # §Perf C (see layers.PERF_FLAGS)


def mamba_specs(cfg) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "inner2")),
        "conv_w": ParamSpec((cfg.ssm_conv, di), (None, "inner"), scale=0.5),
        "conv_b": ParamSpec((di,), ("inner",), init="zeros"),
        "x_proj": ParamSpec((di, dt_rank + 2 * n), ("inner", None)),
        "dt_proj": ParamSpec((dt_rank, di), (None, "inner")),
        "dt_bias": ParamSpec((di,), ("inner",), init="ones", scale=0.01),
        "a_log": ParamSpec((di, n), ("inner", None), init="ones"),
        "d_skip": ParamSpec((di,), ("inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("inner", "embed")),
    }


def _ssm_params(p, x, cfg):
    """x: (B, S, di) -> dt (B,S,di), a=exp(dt*A) (B,S,di,n), bx (B,S,di,n), c (B,S,n)."""
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = p["x_proj"].shape[1] - 2 * n
    xp = jnp.einsum("bsc,cr->bsr", x, p["x_proj"].astype(x.dtype))
    dt_in, b_in, c_in = jnp.split(xp, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_in, p["dt_proj"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                              # (B,S,di)
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))                     # (di,n)
    a = jnp.exp(dt[..., None] * a_mat[None, None])                       # (B,S,di,n)
    bx = (dt * x.astype(jnp.float32))[..., None] * b_in.astype(jnp.float32)[:, :, None, :]
    return a, bx, c_in.astype(jnp.float32)


def _chunk_scan(a, bx, h0):
    """One chunk of the recurrence via associative scan.

    a, bx: (L, B, di, n) fp32; h0: (B, di, n).  Returns (h_all (L,B,di,n),
    h_last)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, bx), axis=0)
    h_all = a_s * h0[None] + b_s
    return h_all, h_all[-1]


def _conv1d(p, x, cfg):
    """Depthwise causal conv via shifted adds.  x: (B, S, di).

    fp32 accumulation so the full pass matches ``mamba_step``'s einsum
    (which accumulates in fp32) — bf16 accumulation here caused ~1e-2
    per-layer train/decode drift."""
    w = p["conv_w"].astype(jnp.float32)                                  # (K, di)
    K = w.shape[0]
    xf = x.astype(jnp.float32)
    out = xf * w[K - 1]
    for k in range(1, K):
        shifted = jnp.pad(xf, ((0, 0), (k, 0), (0, 0)))[:, :-k]
        out = out + shifted * w[K - 1 - k]
    return (out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)


def mamba(p: dict, x: jnp.ndarray, cfg, chunk: int = 64,
          return_state: bool = False):
    """Full-sequence mamba mixer.  x: (B, S, d) -> (B, S, d)
    (+ decode-ready state when ``return_state``)."""
    B, S, _ = x.shape
    cdt = x.dtype
    xz = constrain(jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cdt)),
                   ("dp", None, "tp"))
    xin_pre, z = jnp.split(xz, 2, axis=-1)                               # (B,S,di)
    xin = jax.nn.silu(_conv1d(p, xin_pre, cfg).astype(jnp.float32)).astype(cdt)
    xin = constrain(xin, ("dp", None, "tp"))

    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fallback: single chunk (smoke-test sizes)
    nc = S // chunk
    # per-chunk SSM-param computation: the (L, B, di, n) decay/input tensors
    # exist only inside one scan step (the TPU analogue of the CUDA kernel's
    # SRAM blocking); the checkpointed step keeps backward residuals to the
    # (B, di, n) carries.
    x_c = xin.reshape(B, nc, chunk, cfg.d_inner).transpose(1, 0, 2, 3)   # (nc,B,L,di)

    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)

    @jax.checkpoint
    def outer(h, xc):
        a, bx, c = _ssm_params(p, xc, cfg)                               # (B,L,di,n)
        h_all, h_last = _chunk_scan(a.transpose(1, 0, 2, 3),
                                    bx.transpose(1, 0, 2, 3), h)         # (L,B,di,n)
        yc = jnp.einsum("lbcn,bln->blc", h_all, c)                       # (B,L,di)
        if PERF_FLAGS["mamba_bf16_y"]:
            yc = yc.astype(cdt)        # §Perf C: halve the stacked y traffic
        return h_last, yc

    h_fin, y_chunks = jax.lax.scan(outer, h0, x_c)                       # (nc,B,L,di)
    y = y_chunks.transpose(1, 0, 2, 3).reshape(B, S, cfg.d_inner).astype(jnp.float32)
    y = y + xin.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(cdt) * jax.nn.silu(z.astype(jnp.float32)).astype(cdt)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(cdt))
    if not return_state:
        return out
    ktail = cfg.ssm_conv - 1
    conv_state = jnp.pad(xin_pre, ((0, 0), (max(ktail - S, 0), 0), (0, 0)))[:, -ktail:]
    return out, {"conv": conv_state, "ssm": h_fin}


def init_mamba_state(cfg, batch: int, dtype=jnp.float32, abstract: bool = False):
    """Decode-time carried state: causal-conv tail + SSM hidden."""
    shapes = {
        "conv": ((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": ((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def mamba_step(p: dict, x: jnp.ndarray, state: dict, cfg):
    """One decode step.  x: (B, 1, d); state from init_mamba_state."""
    B = x.shape[0]
    cdt = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cdt))
    xin, z = jnp.split(xz, 2, axis=-1)                                   # (B,1,di)

    # conv over (tail ++ current)
    window = jnp.concatenate([state["conv"].astype(cdt), xin], axis=1)   # (B,K,di)
    w = p["conv_w"].astype(cdt)
    conv = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(cdt)
    xin1 = jax.nn.silu(conv.astype(jnp.float32)).astype(cdt)[:, None]    # (B,1,di)
    new_conv = window[:, 1:]

    a, bx, c = _ssm_params(p, xin1, cfg)                                 # (B,1,di,n)
    h = a[:, 0] * state["ssm"] + bx[:, 0]                                # (B,di,n)
    y = jnp.einsum("bcn,bn->bc", h, c[:, 0]) \
        + xin1[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(cdt) * jax.nn.silu(z[:, 0].astype(jnp.float32)).astype(cdt)
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"].astype(cdt))[:, None]
    return out, {"conv": new_conv.astype(state["conv"].dtype), "ssm": h}
