"""Mixture-of-Experts FFN (llama4-scout/maverick top-1, jamba top-2).

GSPMD-friendly *per-row* capacity dispatch: every batch row routes its own
S tokens independently, so with batch sharded over ("pod","data") the
dispatch gather/scatter is device-local — the only MoE collectives are the
ones the chosen weight sharding induces (TP reduce on d_ff; FSDP all-gather
when expert weights are ZeRO-sharded).  See DESIGN.md §7 for why this
formulation was chosen over global-sort EP-a2a (which remains a
hillclimb variant in repro.parallel.ep_a2a).

Dispatch mechanics per row:
  1. router top-k (softmax gates renormalized over the top-k)
  2. position-in-expert = exclusive cumsum of expert one-hot over S
  3. source-token index buffer (E, C) built by scatter; over-capacity
     assignments drop (Switch semantics, capacity_factor knob)
  4. expert_in = gather  ->  (E, C, d) ;  batched expert einsums
  5. combine: gather back per (token, k) slot, gate-weight, sum over k

Aux outputs: Switch load-balance loss and router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .specs import ParamSpec
from repro.parallel.actctx import constrain

__all__ = ["moe_specs", "moe_ffn"]


def moe_specs(cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff_expert or cfg.d_ff, cfg.n_experts
    sp = {
        "router": ParamSpec((d, E), ("embed", "experts_r"), scale=0.1),
        "w_gate": ParamSpec((E, d, f), ("experts", "embed", "ff")),
        "w_up": ParamSpec((E, d, f), ("experts", "embed", "ff")),
        "w_down": ParamSpec((E, f, d), ("experts", "ff", "embed")),
    }
    if cfg.shared_expert:
        sp["shared"] = {
            "w_gate": ParamSpec((d, f), ("embed", "ff")),
            "w_up": ParamSpec((d, f), ("embed", "ff")),
            "w_down": ParamSpec((f, d), ("ff", "embed")),
        }
    return sp


# ---------------------------------------------------------------------------
# scatter-free dispatch/combine gathers.
#
# jax.grad of a gather is a scatter-add, which GSPMD cannot batch-shard (it
# replicates the whole tensor across the mesh — measured 32 GiB/device at
# jamba scale).  But the dispatch and combine gathers are *mutually inverse*
# permutations (up to capacity drops), so each one's backward is the other's
# forward shape: custom_vjp lets us express both directions as pure batched
# gathers, which GSPMD shards perfectly.
# ---------------------------------------------------------------------------


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dispatch_gather(K, x, src, slot_valid, slot, valid):
    """x: (B,S,d) token stream; src: (B,EC) flat assignment index (t*K+k)
    or sentinel; returns (B,EC,d)."""
    tok = jnp.minimum(src // K, x.shape[1] - 1)
    out = jnp.take_along_axis(x, tok[..., None], axis=1)
    return jnp.where(slot_valid[..., None], out, jnp.zeros((), x.dtype))


def _dispatch_fwd(K, x, src, slot_valid, slot, valid):
    return (_dispatch_gather(K, x, src, slot_valid, slot, valid),
            (jnp.zeros((), x.dtype), slot, valid))


def _dispatch_bwd(K, res, g):
    # dx[b,t] = sum_k valid[b,t,k] * g[b, slot[b,t,k]]  — a gather by slot
    (xmark, slot, valid) = res
    xdtype = xmark.dtype
    B, SK = slot.shape
    safe = jnp.minimum(slot, g.shape[1] - 1)
    gk = jnp.take_along_axis(g, safe[..., None], axis=1)          # (B,SK,d)
    gk = jnp.where(valid[..., None], gk, jnp.zeros((), g.dtype))
    dx = gk.reshape(B, SK // K, K, g.shape[-1]).sum(axis=2).astype(xdtype)
    return dx, None, None, None, None


_dispatch_gather.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine_gather(y, slot, valid, src, slot_valid):
    """y: (B,EC,d) expert outputs; slot: (B,SK); returns (B,SK,d)."""
    safe = jnp.minimum(slot, y.shape[1] - 1)
    out = jnp.take_along_axis(y, safe[..., None], axis=1)
    return jnp.where(valid[..., None], out, jnp.zeros((), y.dtype))


def _combine_fwd(y, slot, valid, src, slot_valid):
    return (_combine_gather(y, slot, valid, src, slot_valid),
            (jnp.zeros((), y.dtype), src, slot_valid))


def _combine_bwd(res, g):
    # dy[b,j] = slot_valid[b,j] * g[b, src[b,j]]  — a gather by src
    (ymark, src, slot_valid) = res
    ydtype = ymark.dtype
    safe = jnp.minimum(src, g.shape[1] - 1)
    dy = jnp.take_along_axis(g, safe[..., None], axis=1)
    dy = jnp.where(slot_valid[..., None], dy, jnp.zeros((), g.dtype))
    return dy.astype(ydtype), None, None, None, None


_combine_gather.defvjp(_combine_fwd, _combine_bwd)


def _dense_ffn(p, x, act):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    g = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) if act == "silu" \
        else jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", g * u, p["w_down"].astype(x.dtype))


def moe_ffn(p: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (out (B, S, d), {"lb_loss", "z_loss"})."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    cdt = x.dtype
    C = int(min(max(1, round(S * K / E * cfg.capacity_factor)), S * K))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))               # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, K)                            # (B,S,K)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # --- aux losses (Switch): load balance + z-loss
    me = probs.mean(axis=(0, 1))                                       # (E,)
    onehot = jax.nn.one_hot(idx_k, E, dtype=jnp.float32)               # (B,S,K,E)
    ce = onehot.mean(axis=(0, 1, 2))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- position of each (s, k) assignment within its expert, per row.
    # flatten (S, K) in token-major order; exclusive cumsum of one-hot.
    # (cumsum/gather/top_k only — NO scatter: GSPMD cannot batch-shard
    # coordinate scatters and would replicate the whole dispatch, verified
    # catastrophic at 400B scale; see DESIGN.md §7.)
    oh_flat = onehot.reshape(B, S * K, E)                              # (B,SK,E)
    pos_incl = jnp.cumsum(oh_flat, axis=1)
    pos = (pos_incl - oh_flat)                                         # exclusive
    pos_k = jnp.einsum("bte,bte->bt", pos, oh_flat).astype(jnp.int32)  # (B,SK)
    e_flat = idx_k.reshape(B, S * K)
    valid = pos_k < C
    slot = jnp.where(valid, e_flat * C + pos_k, E * C)                 # (B,SK)

    # --- expert-major source indices via top_k (first-come-first-serve):
    # score[b,e,t] = t if assignment t chose e else SK; the C smallest
    # scores per (b,e) are that expert's capacity slots in arrival order.
    tpos = jnp.arange(S * K, dtype=jnp.int32)
    score = jnp.where(oh_flat.transpose(0, 2, 1) > 0,                  # (B,E,SK)
                      tpos[None, None, :], S * K)
    neg_vals, src = jax.lax.top_k(-score, C)                           # (B,E,C)
    src = src.reshape(B, E * C)
    slot_valid = (neg_vals.reshape(B, E * C) > -(S * K))

    # --- gather tokens -> (B, E, C, d)  (scatter-free custom-vjp gather)
    xg = _dispatch_gather(K, x, src, slot_valid, slot, valid)          # (B,EC,d)
    expert_in = constrain(xg.reshape(B, E, C, d), ("dp", None, None, None))

    # --- expert FFN: batched einsums over E; f sharded = TP, E ZeRO/FSDP
    # (activations pinned to DP so the partitioner gathers *weights*)
    g = jnp.einsum("becd,edf->becf", expert_in, p["w_gate"].astype(cdt))
    u = jnp.einsum("becd,edf->becf", expert_in, p["w_up"].astype(cdt))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(cdt) if cfg.ffn_act == "silu" \
        else jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(cdt)
    expert_out = jnp.einsum("becf,efd->becd", act * u, p["w_down"].astype(cdt))
    out_flat = constrain(expert_out.reshape(B, E * C, d), ("dp", None, None))

    # --- combine: per (token, k) read its slot back, gate-weight, sum over k
    back = _combine_gather(out_flat, slot, valid, src, slot_valid)    # (B,SK,d)
    back = back.reshape(B, S, K, d) * gate_k[..., None].astype(cdt)
    out = back.sum(axis=2)

    if cfg.shared_expert:
        out = out + _dense_ffn(p["shared"], x, cfg.ffn_act)
    return out, {"lb_loss": lb_loss, "z_loss": z_loss}
