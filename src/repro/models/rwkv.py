"""RWKV-6 ("Finch") mixer — data-dependent decay linear attention.

Recurrence per head (state S is (d_k, d_v)):
    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
with w_t = exp(-exp(w0 + tanh(x̂_t W_a) W_b)) — the *data-dependent* decay
that distinguishes RWKV-6 from RWKV-4/5 (paper: arXiv:2404.05892).

TPU mapping: chunked linear attention.  Within a chunk of L tokens the
pairwise decay products are exp(cum[t] - cum[i]) so the intra-chunk part is
two decay-weighted matmuls (MXU-friendly (L, D) x (D, L)); the inter-chunk
part carries the (H, D, D) state through a ``lax.scan``.  fp32 throughout
the decay algebra; L is kept small (32) so exp(±cum) stays bounded.

Token shift (the x̂ above) is the RWKV "shift by one" mix:
    x̂_t = x_t + mu * (x_{t-1} - x_t)      (x_{-1} = 0, or decode carry)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .specs import ParamSpec
from repro.parallel.actctx import constrain

_LW_FLOOR = -25.0 / 32.0   # per-step log-decay floor (see rwkv_time_mix)

# §Perf: int8-compressed TP reduction on the row-parallel projections
# (the paper's wire codec profile applied to collectives; inference paths)
PERF_FLAGS = {"compressed_tp": False}

__all__ = [
    "rwkv_time_specs", "rwkv_channel_specs",
    "rwkv_time_mix", "rwkv_time_step",
    "rwkv_channel_mix", "rwkv_channel_step",
    "init_rwkv_state",
]


def rwkv_time_specs(cfg) -> dict:
    d = cfg.d_model
    lora = cfg.rwkv_decay_lora
    return {
        "mu": ParamSpec((5, d), (None, "embed"), init="zeros"),   # r,k,v,w,g shifts
        "w_r": ParamSpec((d, d), ("embed", "heads_d")),
        "w_k": ParamSpec((d, d), ("embed", "heads_d")),
        "w_v": ParamSpec((d, d), ("embed", "heads_d")),
        "w_g": ParamSpec((d, d), ("embed", "heads_d")),
        "w_o": ParamSpec((d, d), ("heads_d", "embed")),
        "decay_base": ParamSpec((d,), ("embed",), init="ones", scale=-6.0),
        "decay_a": ParamSpec((d, lora), ("embed", None), scale=0.1),
        "decay_b": ParamSpec((lora, d), (None, "embed"), scale=0.1),
        "bonus_u": ParamSpec((d,), ("embed",), init="zeros"),
        "ln_scale": ParamSpec((d,), ("embed",), init="ones"),     # per-head groupnorm
    }


def rwkv_channel_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu": ParamSpec((2, d), (None, "embed"), init="zeros"),   # k, r shifts
        "w_k": ParamSpec((d, f), ("embed", "ff")),
        "w_v": ParamSpec((f, d), ("ff", "embed")),
        "w_r": ParamSpec((d, d), ("embed", "embed_o")),
    }


def _shift(x: jnp.ndarray, carry: jnp.ndarray | None = None) -> jnp.ndarray:
    """x_{t-1}; first position takes ``carry`` (decode) or zeros (train)."""
    if carry is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([carry[:, None], x[:, :-1]], axis=1)


def _decay(p, xw: jnp.ndarray) -> jnp.ndarray:
    """log-decay lw_t = -exp(w0 + tanh(xw A) B)  (negative, fp32)."""
    lora = jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32), p["decay_a"].astype(jnp.float32))
    lw = p["decay_base"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(lora), p["decay_b"].astype(jnp.float32))
    return -jnp.exp(lw)


def _heads(x, H, D):
    return x.reshape(*x.shape[:-1], H, D)


def _group_norm(x, scale, eps):
    """Per-head layernorm on (..., H, D)."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(-1, keepdims=True)
    xn = (xf - mean) * jax.lax.rsqrt(var + eps)
    return xn * scale.astype(jnp.float32).reshape(*([1] * (x.ndim - 2)), *x.shape[-2:])


def rwkv_time_mix(p: dict, x: jnp.ndarray, cfg, chunk: int = 32,
                  shift_carry=None, state0=None):
    """x: (B, S, d) -> (out (B, S, d), (last_x, last_state))."""
    B, S, d = x.shape
    D = cfg.rwkv_head_dim
    H = d // D
    cdt = x.dtype

    xprev = _shift(x, shift_carry)
    mu = p["mu"].astype(cdt)                                             # (5, d)
    xr, xk, xv, xw, xg = (x + mu[i] * (xprev - x) for i in range(5))

    def proj(xi, w):
        return _heads(constrain(jnp.einsum("bsd,de->bse", xi, w.astype(cdt)),
                                ("dp", None, "tp")), H, D)

    r = proj(xr, p["w_r"]).astype(jnp.float32)
    k = proj(xk, p["w_k"]).astype(jnp.float32)
    v = proj(xv, p["w_v"]).astype(jnp.float32)
    g = constrain(jnp.einsum("bsd,de->bse", xg, p["w_g"].astype(cdt)),
                  ("dp", None, "tp"))
    lw = _heads(constrain(_decay(p, xw), ("dp", None, "tp")), H, D)      # fp32 <0
    u = _heads(p["bonus_u"].astype(jnp.float32), H, D)                   # (H,D)

    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk
    # -> (nc, B, L, H, D)
    def c5(t):
        return t.reshape(B, nc, chunk, H, D).transpose(1, 0, 2, 3, 4)
    r_c, k_c, v_c, lw_c = c5(r), c5(k), c5(v), c5(lw)

    if state0 is None:
        state0 = jnp.zeros((B, H, D, D), jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)         # strictly lower

    def chunk_fn(S_in, rkvw):
        rc, kc, vc, lwc = rkvw                                           # (B,L,H,D)
        # stability: the factored exp(-cum) must stay in fp32 range, so floor
        # the *per-step* log-decay.  The floor is a fixed constant (not a
        # function of chunk length) so train (chunk=32) and decode (chunk=1)
        # compute the *same* recurrence; telescoping stays exact for the
        # floored decay, and decays faster than e^-0.78/step are ~0 within a
        # chunk anyway (secondary chunking would lift this; GLA §4).
        lwc = jnp.maximum(lwc, _LW_FLOOR)
        cum = jnp.cumsum(lwc, axis=1)                                    # inclusive
        cum_ex = cum - lwc                                               # exclusive
        # intra-chunk: A[t,i] = sum_d r_t e^{cum_ex[t]} * k_i e^{-cum[i]}, i<t
        r_dec = rc * jnp.exp(cum_ex)
        k_dec = kc * jnp.exp(-cum)
        scores = jnp.einsum("blhd,bmhd->bhlm", r_dec, k_dec) * causal[None, None]
        diag = jnp.einsum("blhd,blhd->bhl", rc, u[None, None] * kc)
        y = jnp.einsum("bhlm,bmhd->blhd", scores, vc) + diag.transpose(0, 2, 1)[..., None] * vc
        # inter-chunk: state contribution
        y = y + jnp.einsum("blhk,bhkv->blhv", r_dec, S_in)
        # state update to end of chunk
        decay_all = jnp.exp(cum[:, -1])                                  # (B,H,D)
        k_tail = kc * jnp.exp(cum[:, -1][:, None] - cum)                 # decay to chunk end
        S_out = decay_all[..., None] * S_in + jnp.einsum("blhk,blhv->bhkv", k_tail, vc)
        return S_out, y

    S_fin, y_c = jax.lax.scan(chunk_fn, state0, (r_c, k_c, v_c, lw_c))
    y = y_c.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    y = _group_norm(y, _heads(p["ln_scale"], H, D), cfg.norm_eps)
    y = (y.reshape(B, S, d).astype(cdt)
         * jax.nn.silu(g.astype(jnp.float32)).astype(cdt))
    if PERF_FLAGS["compressed_tp"]:
        from repro.parallel.compressed import rowparallel_einsum_compressed
        out = rowparallel_einsum_compressed(y, p["w_o"])
    else:
        out = jnp.einsum("bse,ed->bsd", y, p["w_o"].astype(cdt))
    return out, (x[:, -1], S_fin)


def rwkv_time_step(p: dict, x: jnp.ndarray, cfg, shift_carry, state):
    """One decode step: x (B, 1, d)."""
    out, (last_x, S_fin) = rwkv_time_mix(p, x, cfg, chunk=1,
                                         shift_carry=shift_carry, state0=state)
    return out, (last_x, S_fin)


def rwkv_channel_mix(p: dict, x: jnp.ndarray, cfg, shift_carry=None):
    """Squared-ReLU channel mix.  Returns (out, last_x)."""
    cdt = x.dtype
    xprev = _shift(x, shift_carry)
    mu = p["mu"].astype(cdt)
    xk = x + mu[0] * (xprev - x)
    xr = x + mu[1] * (xprev - x)
    k = constrain(jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(cdt)),
                  ("dp", None, "tp"))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(cdt)
    if PERF_FLAGS["compressed_tp"]:
        from repro.parallel.compressed import rowparallel_einsum_compressed
        kv = rowparallel_einsum_compressed(k, p["w_v"])
    else:
        kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(cdt))
    rgate = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(cdt)).astype(jnp.float32)).astype(cdt)
    return rgate * kv, x[:, -1]


def rwkv_channel_step(p, x, cfg, shift_carry):
    return rwkv_channel_mix(p, x, cfg, shift_carry=shift_carry)


def init_rwkv_state(cfg, batch: int, dtype=jnp.bfloat16, abstract: bool = False):
    d = cfg.d_model
    D = cfg.rwkv_head_dim
    H = d // D
    shapes = {
        "tm_shift": ((batch, d), dtype),
        "tm_state": ((batch, H, D, D), jnp.float32),
        "cm_shift": ((batch, d), dtype),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in shapes.items()}
    return {k: jnp.zeros(s, dt) for k, (s, dt) in shapes.items()}
