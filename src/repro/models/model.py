"""The unified Model: every assigned architecture is an instance of this
one stack, driven by ModelConfig.pattern.

Layer stacking: the config's ``pattern`` (a tuple of LayerPattern entries)
is unrolled *inside* a group function; groups are scanned with
``jax.lax.scan`` over group-stacked params (leading dim = n_groups), and the
group function is the remat boundary.  This keeps the HLO one-group-sized
for any depth and makes heterogeneous stacks (gemma2 local/global pairs,
jamba's 8-layer mamba/attn/MoE blocks) compile compactly.

Entry points:
  forward(params, batch)                -> (hidden (B,S,d), aux)
  loss(params, batch)                   -> (scalar, metrics)     [train_step]
  prefill(params, batch, max_len)       -> (last logits, cache)  [serve]
  decode_step(params, cache, token, pos)-> (logits, new cache)   [serve]
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import moe as M
from . import rwkv as R
from . import ssm as S
from .specs import ParamSpec, init_params, abstract_params, tree_paths
from repro.parallel.actctx import constrain

__all__ = ["Model"]


def _stack_specs(tree, n: int):
    """Prefix every ParamSpec leaf with a (n,) 'layers' group dim."""
    if isinstance(tree, ParamSpec):
        return ParamSpec((n,) + tuple(tree.shape), ("layers",) + tuple(tree.axes),
                         init=tree.init, scale=tree.scale, dtype=tree.dtype)
    return {k: _stack_specs(v, n) for k, v in tree.items()}


def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # specs / init
    # ------------------------------------------------------------------

    def _layer_specs(self, pe) -> dict:
        cfg = self.cfg
        sp: dict = {"ln1": L.norm_specs(cfg.d_model)}
        if pe.mixer in ("attn", "local"):
            sp["attn"] = L.attn_specs(cfg)
            if cfg.post_norm:
                sp["post_ln1"] = L.norm_specs(cfg.d_model)
        elif pe.mixer == "mamba":
            sp["mamba"] = S.mamba_specs(cfg)
        elif pe.mixer == "rwkv":
            sp["tm"] = R.rwkv_time_specs(cfg)
        else:  # pragma: no cover
            raise ValueError(pe.mixer)
        if cfg.cross_attn:
            sp["ln_x"] = L.norm_specs(cfg.d_model)
            sp["xattn"] = L.attn_specs(cfg, cross=True)
        if pe.ffn != "none":
            sp["ln2"] = L.norm_specs(cfg.d_model)
            if pe.ffn == "dense":
                sp["ffn"] = L.ffn_specs(cfg.d_model, cfg.d_ff)
            elif pe.ffn == "moe":
                sp["moe"] = M.moe_specs(cfg)
            elif pe.ffn == "rwkv_cm":
                sp["cm"] = R.rwkv_channel_specs(cfg)
            else:  # pragma: no cover
                raise ValueError(pe.ffn)
            if cfg.post_norm and pe.ffn in ("dense", "moe"):
                sp["post_ln2"] = L.norm_specs(cfg.d_model)
        return sp

    def param_specs(self) -> dict:
        cfg = self.cfg
        group = {f"l{j}": self._layer_specs(pe) for j, pe in enumerate(cfg.pattern)}
        sp = {
            "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=1.0),
            "layers": _stack_specs(group, cfg.n_groups),
            "final_norm": L.norm_specs(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            sp["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        if cfg.is_encdec:
            enc_cfg_layer = self._enc_layer_specs()
            sp["encoder"] = {
                "layers": _stack_specs({"l0": enc_cfg_layer}, cfg.n_enc_layers),
                "final_norm": L.norm_specs(cfg.d_model),
            }
        return sp

    def _enc_layer_specs(self) -> dict:
        cfg = self.cfg
        return {
            "ln1": L.norm_specs(cfg.d_model),
            "attn": L.attn_specs(cfg),
            "ln2": L.norm_specs(cfg.d_model),
            "ffn": L.ffn_specs(cfg.d_model, cfg.d_ff),
        }

    def init(self, key, dtype=jnp.float32):
        return init_params(self.param_specs(), key, param_dtype=dtype)

    def abstract(self, dtype=jnp.float32):
        return abstract_params(self.param_specs(), param_dtype=dtype)

    # ------------------------------------------------------------------
    # embedding / head
    # ------------------------------------------------------------------

    def embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return constrain(x, ("dp", None, None))

    def unembed(self, params, h):
        cfg = self.cfg
        w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
        # bf16 operands, fp32 accumulate — no fp32 copy of the (d, V) matrix
        logits = jnp.einsum("...d,dv->...v", h, w.astype(h.dtype),
                            preferred_element_type=jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits

    # ------------------------------------------------------------------
    # one group of layers (train / prefill / decode variants share this)
    # ------------------------------------------------------------------

    def _apply_group(self, gp, x, *, positions, prefix_len, enc_out,
                     cache_g=None, cache_pos=None, build_cache=0):
        """Unrolled pattern application.  Returns (x, aux, new_cache_g)."""
        cfg = self.cfg
        aux = _zero_aux()
        new_cache = {}
        decoding = cache_g is not None and cache_pos is not None
        x = constrain(x, ("dp", None, None))    # pin residual: batch over DP
        for j, pe in enumerate(cfg.pattern):
            sub = gp[f"l{j}"]
            key = f"l{j}"
            lcache = (cache_g or {}).get(key, {})
            nc: dict = {}
            # ---- mixer
            h = L.rms_norm(sub["ln1"], x, cfg.norm_eps)
            if pe.mixer in ("attn", "local"):
                mode = "sliding" if pe.mixer == "local" else (
                    "prefix" if (cfg.n_img_tokens and not decoding) else "causal")
                attn_out, kv = L.attention(
                    sub["attn"], h, cfg, mode=mode, positions=positions,
                    cache=lcache.get("self"), cache_pos=cache_pos,
                    build_cache=build_cache,
                    window=cfg.local_window, prefix_len=prefix_len,
                    q_chunk=getattr(cfg, "q_chunk", 0) or 0)
                if kv is not None:
                    nc["self"] = kv
                if cfg.post_norm:
                    attn_out = L.rms_norm(sub["post_ln1"], attn_out, cfg.norm_eps)
                x = x + attn_out
            elif pe.mixer == "mamba":
                if decoding:
                    mx, mstate = S.mamba_step(sub["mamba"], h, lcache["ssm_state"], cfg)
                    nc["ssm_state"] = mstate
                elif build_cache:
                    mx, mstate = S.mamba(sub["mamba"], h, cfg, return_state=True)
                    nc["ssm_state"] = mstate
                else:
                    mx = S.mamba(sub["mamba"], h, cfg)
                x = x + mx
            elif pe.mixer == "rwkv":
                carry = lcache.get("tm_shift") if (decoding or build_cache) else None
                state0 = lcache.get("tm_state") if decoding else None
                tmx, (last_x, s_fin) = R.rwkv_time_mix(
                    sub["tm"], h, cfg, shift_carry=carry if decoding else None,
                    state0=state0)
                if decoding or build_cache:
                    nc["tm_shift"] = last_x
                    nc["tm_state"] = s_fin
                x = x + tmx
            # ---- cross attention (enc-dec decoder)
            if cfg.cross_attn:
                hx = L.rms_norm(sub["ln_x"], x, cfg.norm_eps)
                if decoding:
                    xout, _ = L.attention(sub["xattn"], hx, cfg, mode="bidir",
                                          cache=lcache["cross"], update_cache=False)
                    nc["cross"] = lcache["cross"]
                else:
                    xout, xkv = L.attention(sub["xattn"], hx, cfg, mode="bidir",
                                            kv_input=enc_out,
                                            build_cache=0)
                    if build_cache:
                        # cross kv cache: recompute enc projections once
                        cdt = hx.dtype
                        xk = jnp.einsum("btd,dhk->bthk", enc_out,
                                        sub["xattn"]["wk"].astype(cdt))
                        xv = jnp.einsum("btd,dhk->bthk", enc_out,
                                        sub["xattn"]["wv"].astype(cdt))
                        nc["cross"] = {"k": xk.astype(jnp.bfloat16),
                                       "v": xv.astype(jnp.bfloat16)}
                x = x + xout
            # ---- ffn
            if pe.ffn != "none":
                h2 = L.rms_norm(sub["ln2"], x, cfg.norm_eps)
                if pe.ffn == "dense":
                    f = L.ffn(sub["ffn"], h2, cfg.ffn_act)
                elif pe.ffn == "moe":
                    f, moe_aux = M.moe_ffn(sub["moe"], h2, cfg)
                    aux = {k: aux[k] + moe_aux[k] for k in aux}
                else:  # rwkv channel mix
                    carry = lcache.get("cm_shift") if decoding else None
                    f, cm_last = R.rwkv_channel_mix(sub["cm"], h2, cfg,
                                                    shift_carry=carry)
                    if decoding or build_cache:
                        nc["cm_shift"] = cm_last
                if cfg.post_norm and pe.ffn in ("dense", "moe"):
                    f = L.rms_norm(sub["post_ln2"], f, cfg.norm_eps)
                x = x + f
            x = constrain(x, ("dp", None, None))
            new_cache[key] = nc
        return x, aux, new_cache

    # ------------------------------------------------------------------
    # encoder (enc-dec archs)
    # ------------------------------------------------------------------

    def encode(self, params, frames):
        """frames: (B, T, d) precomputed modality embeddings (stub frontend)."""
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.dtype))
        enc = params["encoder"]

        def group_fn(gp, x):
            sub = gp["l0"]
            h = L.rms_norm(sub["ln1"], x, cfg.norm_eps)
            a, _ = L.attention(sub["attn"], h, cfg, mode="bidir")
            x = x + a
            h2 = L.rms_norm(sub["ln2"], x, cfg.norm_eps)
            return x + L.ffn(sub["ffn"], h2, cfg.ffn_act)

        group_fn = self._maybe_remat(group_fn)

        def body(carry, gp):
            return group_fn(gp, carry), None

        x, _ = jax.lax.scan(body, x, enc["layers"])
        return L.rms_norm(enc["final_norm"], x, cfg.norm_eps)

    def _maybe_remat(self, fn):
        r = self.cfg.remat
        if r == "none":
            return fn
        if r == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return jax.checkpoint(fn)

    # ------------------------------------------------------------------
    # forward (training)
    # ------------------------------------------------------------------

    def _inputs_to_x(self, params, batch):
        """tokens (+patches/frames) -> (x, positions, prefix_len, enc_out)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self.embed(params, tokens)
        prefix_len = 0
        enc_out = None
        if cfg.n_img_tokens and "patches" in batch:
            patches = batch["patches"].astype(x.dtype)       # (B, P, d) stub
            x = jnp.concatenate([patches, x], axis=1)
            prefix_len = patches.shape[1]
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
        B, S2 = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S2, dtype=jnp.int32)[None], (B, S2))
        return x, positions, prefix_len, enc_out

    def forward(self, params, batch):
        cfg = self.cfg
        x, positions, prefix_len, enc_out = self._inputs_to_x(params, batch)

        def group_fn(gp, x):
            x, aux, _ = self._apply_group(gp, x, positions=positions,
                                          prefix_len=prefix_len, enc_out=enc_out)
            return x, aux

        group_fn = self._maybe_remat(group_fn)

        def body(carry, gp):
            x, aux = group_fn(gp, carry)
            return x, aux

        x, auxs = jax.lax.scan(body, x, params["layers"])
        aux = jax.tree.map(lambda a: a.sum(0), auxs)
        x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        return constrain(x, ("dp", None, None)), aux

    # ------------------------------------------------------------------
    # loss (chunked cross-entropy — no (B,S,V) fp32 materialization)
    # ------------------------------------------------------------------

    def loss(self, params, batch, s_chunk: int = 512):
        cfg = self.cfg
        h, aux = self.forward(params, batch)
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        if cfg.n_img_tokens and "patches" in batch:
            h = h[:, batch["patches"].shape[1]:]             # loss on text only
        B, Sl, d = h.shape
        if mask is None:
            mask = jnp.ones((B, Sl), jnp.float32)
        w = (params["lm_head"] if not cfg.tie_embeddings
             else params["embed"].T)                          # (d, V)

        c = min(s_chunk, Sl)
        if Sl % c:
            c = Sl
        nc = Sl // c
        h_c = h.reshape(B, nc, c, d).transpose(1, 0, 2, 3)
        t_c = targets.reshape(B, nc, c).transpose(1, 0, 2)
        m_c = mask.reshape(B, nc, c).transpose(1, 0, 2)

        def chunk_fn(carry, htm):
            hc, tc, mc = htm
            logits = jnp.einsum("bsd,dv->bsv", hc, w.astype(hc.dtype),
                                preferred_element_type=jnp.float32)
            if cfg.final_softcap:
                logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
            nll = (lse - tgt) * mc
            correct = (jnp.argmax(logits, -1) == tc) * mc
            return carry, (nll.sum(), mc.sum(), correct.sum())

        chunk_fn = jax.checkpoint(chunk_fn) if cfg.remat != "none" else chunk_fn
        _, (nll, cnt, corr) = jax.lax.scan(chunk_fn, 0, (h_c, t_c, m_c))
        total = jnp.maximum(cnt.sum(), 1.0)
        xent = nll.sum() / total
        loss = xent + cfg.router_aux_weight * aux["lb_loss"] \
                    + cfg.router_z_weight * aux["z_loss"]
        metrics = {"loss": loss, "xent": xent, "accuracy": corr.sum() / total,
                   "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"],
                   "tokens": total}
        return loss, metrics

    # ------------------------------------------------------------------
    # serving: cache init / prefill / decode
    # ------------------------------------------------------------------

    def init_cache(self, batch_size: int, max_len: int, enc_len: int = 0,
                   abstract: bool = False, cache_dtype=jnp.bfloat16):
        cfg = self.cfg

        def mk(shape, dtype):
            return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                    else jnp.zeros(shape, dtype))

        def one_group():
            g = {}
            for j, pe in enumerate(cfg.pattern):
                e: dict = {}
                if pe.mixer in ("attn", "local"):
                    kv_shape = (batch_size, max_len, cfg.n_kv_heads, cfg.d_head)
                    e["self"] = {"k": mk(kv_shape, cache_dtype),
                                 "v": mk(kv_shape, cache_dtype)}
                elif pe.mixer == "mamba":
                    e["ssm_state"] = S.init_mamba_state(cfg, batch_size,
                                                        abstract=abstract)
                elif pe.mixer == "rwkv":
                    st = R.init_rwkv_state(cfg, batch_size, abstract=abstract)
                    e["tm_shift"], e["tm_state"] = st["tm_shift"], st["tm_state"]
                if cfg.cross_attn:
                    xs = (batch_size, enc_len, cfg.n_kv_heads, cfg.d_head)
                    e["cross"] = {"k": mk(xs, cache_dtype), "v": mk(xs, cache_dtype)}
                if pe.ffn == "rwkv_cm":
                    e["cm_shift"] = mk((batch_size, cfg.d_model), cache_dtype)
                g[f"l{j}"] = e
            return g

        g = one_group()
        # stack group cache n_groups times (leading scan dim)
        def stack(leaf):
            if abstract:
                return jax.ShapeDtypeStruct((cfg.n_groups,) + tuple(leaf.shape),
                                            leaf.dtype)
            return jnp.broadcast_to(leaf[None], (cfg.n_groups,) + leaf.shape).copy()

        return jax.tree.map(stack, g)

    def prefill(self, params, batch, max_len: int):
        """Run the prompt, build the cache.  Returns (last-pos logits, cache)."""
        cfg = self.cfg
        x, positions, prefix_len, enc_out = self._inputs_to_x(params, batch)

        def body(carry, gp):
            x = carry
            x, _, nc = self._apply_group(gp, x, positions=positions,
                                         prefix_len=prefix_len, enc_out=enc_out,
                                         build_cache=max_len)
            return x, nc

        x, cache = jax.lax.scan(body, x, params["layers"])
        x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self.unembed(params, x[:, -1])
        return logits, cache

    def decode_step(self, params, cache, token, pos):
        """token: (B, 1) int32; pos: scalar int32 (next position index).
        Returns (logits (B, V), new cache)."""
        cfg = self.cfg
        x = self.embed(params, token)
        B = x.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

        def body(carry, gp_cache):
            x = carry
            gp, cg = gp_cache
            x, _, nc = self._apply_group(gp, x, positions=positions,
                                         prefix_len=0, enc_out=None,
                                         cache_g=cg, cache_pos=pos)
            return x, nc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self.unembed(params, x[:, -1])
        return logits, new_cache
