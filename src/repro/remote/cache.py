"""Tiered client-side basket cache: decoded-bytes LRU + wire-payload spill.

The TTreeCache lesson applied to a networked reader: the expensive things,
in order, are (1) the round-trip, (2) the decode, (3) local disk.  So the
cache has two byte-budgeted tiers:

* **memory** — decoded (raw) basket bytes, LRU.  A hit costs a dict
  lookup; re-reads (epoch loops, overlapping entry ranges) are free.
* **disk** — *wire* payloads (still compressed, with their metadata), LRU
  with files spilled under a cache directory.  A hit costs a local read +
  decode but no round-trip; the tier is what makes a cold re-open of a
  recently-read remote file cheap.

Keys are ``(path, generation, branch, index)`` where ``path`` includes
the serving endpoint (``host:port/rel-path`` — two servers exporting
same-named files must never share entries) and ``generation`` is the
server-reported ``(st_dev, st_ino)`` of the container — the same key
``repro.io.fdcache`` revalidates local reads with — so a file replaced on
the server can never serve stale cached baskets: its new catalog carries
a new generation and misses cleanly.

Thread-safe; one cache may back many ``RemoteBasketFile``s.  Disk spill
can be fed asynchronously (:meth:`put_wire_async`): the hot read path
enqueues and a background writer does the file I/O, dropping entries
rather than stalling when the disk can't keep up — the cache is
advisory, the socket pipeline is not.
"""

from __future__ import annotations

import hashlib
import os
import queue
import shutil
import tempfile
import threading
from collections import OrderedDict
from typing import Optional

from repro import obs

__all__ = ["TieredCache", "basket_key"]


def basket_key(path: str, generation, branch: str, index: int) -> tuple:
    """The canonical cache key for one basket of one file generation."""
    gen = tuple(generation) if generation is not None else None
    return (str(path), gen, str(branch), int(index))


class TieredCache:
    """Byte-budgeted two-tier basket cache (see module docstring).

    ``mem_bytes=0`` disables the decoded tier, ``disk_bytes=0`` the spill
    tier.  ``disk_dir=None`` creates (and owns) a temporary directory,
    removed on :meth:`close`."""

    def __init__(self, mem_bytes: int = 64 << 20, disk_bytes: int = 0,
                 disk_dir: Optional[str] = None):
        self.mem_bytes = max(int(mem_bytes), 0)
        self.disk_bytes = max(int(disk_bytes), 0)
        self._lock = threading.Lock()
        self._mem: OrderedDict[tuple, bytes] = OrderedDict()
        self._mem_used = 0
        self._disk: OrderedDict[tuple, tuple[str, int, dict]] = OrderedDict()
        self._disk_used = 0
        self._owns_dir = False
        self._dir = None
        self._spillq: Optional[queue.Queue] = None
        self._spiller: Optional[threading.Thread] = None
        if self.disk_bytes:
            if disk_dir is None:
                self._dir = tempfile.mkdtemp(prefix="repro-bcache-")
                self._owns_dir = True
            else:
                self._dir = str(disk_dir)
                os.makedirs(self._dir, exist_ok=True)
        # stats: the per-instance ints below are canonical (stats() reads
        # them under the lock); the obs registry carries the process-wide
        # mirror, bumped per event outside the lock
        self.mem_hits = 0
        self.disk_hits = 0
        self.misses = 0

    # -- decoded tier ----------------------------------------------------

    def get_decoded(self, key: tuple) -> Optional[bytes]:
        with self._lock:
            raw = self._mem.get(key)
            if raw is not None:
                self._mem.move_to_end(key)
                self.mem_hits += 1
        if raw is not None:
            obs.counter("client.cache", tier="mem", event="hit").inc()
            return raw
        return None

    def put_decoded(self, key: tuple, raw: bytes) -> None:
        raw = bytes(raw)
        if not self.mem_bytes or len(raw) > self.mem_bytes:
            return
        evicted = 0
        with self._lock:
            old = self._mem.pop(key, None)
            if old is not None:
                self._mem_used -= len(old)
            self._mem[key] = raw
            self._mem_used += len(raw)
            while self._mem_used > self.mem_bytes and self._mem:
                _k, v = self._mem.popitem(last=False)
                self._mem_used -= len(v)
                evicted += 1
            used = self._mem_used
        if evicted:
            obs.counter("client.cache", tier="mem", event="evict").inc(evicted)
        obs.gauge("client.cache_used", tier="mem").set(used)

    # -- wire tier -------------------------------------------------------

    def _fname(self, key: tuple) -> str:
        h = hashlib.sha1(repr(key).encode()).hexdigest()
        return os.path.join(self._dir, h + ".wire")

    def get_wire(self, key: tuple) -> Optional[tuple[bytes, dict]]:
        """The spilled ``(wire_payload, meta_json)`` for ``key``; None on
        miss (including a cache file deleted underneath us)."""
        with self._lock:
            rec = self._disk.get(key)
            if rec is None:
                return None
            self._disk.move_to_end(key)
            fname, _size, meta = rec
        try:
            with open(fname, "rb") as f:
                payload = f.read()
        except OSError:
            with self._lock:
                r = self._disk.pop(key, None)
                if r is not None:
                    self._disk_used -= r[1]
            return None
        with self._lock:
            self.disk_hits += 1
        obs.counter("client.cache", tier="disk", event="hit").inc()
        return payload, dict(meta)

    def put_wire(self, key: tuple, payload, meta_json: dict) -> None:
        if not self.disk_bytes:
            return
        payload = bytes(payload)
        if len(payload) > self.disk_bytes:
            return
        fname = self._fname(key)
        tmp = fname + ".tmp"
        try:
            with open(tmp, "wb") as f:
                f.write(payload)
            os.replace(tmp, fname)
        except OSError:
            return                      # a full cache disk is not an error
        evict = []
        with self._lock:
            old = self._disk.pop(key, None)
            if old is not None:
                self._disk_used -= old[1]
            self._disk[key] = (fname, len(payload), dict(meta_json))
            self._disk_used += len(payload)
            while self._disk_used > self.disk_bytes and self._disk:
                _k, (fn, sz, _m) = self._disk.popitem(last=False)
                self._disk_used -= sz
                evict.append(fn)
            used = self._disk_used
        if evict:
            obs.counter("client.cache", tier="disk",
                        event="evict").inc(len(evict))
        obs.gauge("client.cache_used", tier="disk").set(used)
        for fn in evict:
            try:
                os.remove(fn)
            except OSError:
                pass

    def put_wire_async(self, key: tuple, payload, meta_json: dict) -> None:
        """Queue a spill write for the background writer.  Non-blocking:
        when the queue is full the entry is dropped (advisory cache) so a
        slow disk can never stall the caller's socket pipeline."""
        if not self.disk_bytes:
            return
        with self._lock:
            if self._spillq is None:
                self._spillq = queue.Queue(maxsize=64)
                self._spiller = threading.Thread(
                    target=self._spill_loop, daemon=True,
                    name="repro-bcache-spill")
                self._spiller.start()
            q = self._spillq
        try:
            q.put_nowait((key, bytes(payload), dict(meta_json)))
        except queue.Full:
            pass

    def _spill_loop(self) -> None:
        q = self._spillq                # close() nulls the attribute
        while True:
            item = q.get()
            try:
                if item is None:
                    return
                self.put_wire(*item)
            finally:
                q.task_done()

    def flush(self) -> None:
        """Block until queued async spills hit the disk tier (tests and
        deterministic shutdowns)."""
        with self._lock:
            q = self._spillq
        if q is not None:
            q.join()

    def drop(self, key: tuple) -> None:
        """Remove ``key`` from both tiers — the corrupt-basket quarantine
        path: a cached payload that failed its content checksum must not
        be served again."""
        fn = None
        with self._lock:
            raw = self._mem.pop(key, None)
            if raw is not None:
                self._mem_used -= len(raw)
            rec = self._disk.pop(key, None)
            if rec is not None:
                fn, sz, _m = rec
                self._disk_used -= sz
        if fn is not None:
            try:
                os.remove(fn)
            except OSError:
                pass

    # -- bookkeeping -----------------------------------------------------

    def record_miss(self) -> None:
        with self._lock:
            self.misses += 1
        obs.counter("client.cache", event="miss").inc()

    def stats(self) -> dict:
        """Consistent snapshot: every counter and byte total is read under
        the one lock, so hits/used/items always describe the same instant."""
        with self._lock:
            return {"mem_hits": self.mem_hits, "disk_hits": self.disk_hits,
                    "misses": self.misses, "mem_used": self._mem_used,
                    "disk_used": self._disk_used,
                    "mem_items": len(self._mem),
                    "disk_items": len(self._disk)}

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._mem_used = 0
            files = [fn for fn, _sz, _m in self._disk.values()]
            self._disk.clear()
            self._disk_used = 0
        for fn in files:
            try:
                os.remove(fn)
            except OSError:
                pass

    def close(self) -> None:
        with self._lock:
            q, self._spillq = self._spillq, None
            spiller, self._spiller = self._spiller, None
        if q is not None:
            q.put(None)
            spiller.join(timeout=5)
        self.clear()
        if self._owns_dir and self._dir and os.path.isdir(self._dir):
            shutil.rmtree(self._dir, ignore_errors=True)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
