"""``python -m repro.remote`` — serve a directory of BasketFiles.

Quickstart::

    PYTHONPATH=src python -m repro.remote /data/shards --port 9147
    # clients:
    #   RemoteBasketFile("repro://host:9147/events.bskt").read_branch("Jet_pt")
    #   TokenPipeline(["repro://host:9147/shard0.bskt", ...], ...)

``--port 0`` binds an ephemeral port; the bound address is printed as the
first stdout line (``serving ROOT on HOST:PORT``) so scripts and tests can
scrape it.
"""

from __future__ import annotations

import argparse
import logging
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.remote",
        description="Serve a directory of BasketFiles over RBSP "
                    "(vectored coalesced reads + wire transcoding).")
    ap.add_argument("root", help="directory of .bskt containers to export")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=9147,
                    help="TCP port (0 = ephemeral; printed on stdout)")
    ap.add_argument("--workers", type=int, default=4,
                    help="shared CompressionEngine width for transcoding")
    ap.add_argument("--transcode", dest="transcode", action="store_true",
                    default=True, help="allow wire transcoding (default)")
    ap.add_argument("--no-transcode", dest="transcode", action="store_false",
                    help="always ship archive payloads verbatim")
    ap.add_argument("--max-gap", type=int, default=64 << 10,
                    help="coalesce reads across holes up to this many bytes")
    ap.add_argument("--max-span", type=int, default=8 << 20,
                    help="cap one coalesced pread at this many bytes")
    ap.add_argument("--max-inflight", type=int, default=32,
                    help="concurrent request executions before queueing")
    ap.add_argument("--queue-depth", type=int, default=128,
                    help="admission queue depth before shedding RESP_BUSY")
    ap.add_argument("--idle-timeout", type=float, default=600.0,
                    help="close connections idle this many seconds")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=logging.DEBUG if args.verbose else logging.INFO,
        format="%(asctime)s %(name)s %(levelname)s %(message)s")
    from repro.remote import BasketServer
    server = BasketServer(args.root, host=args.host, port=args.port,
                          workers=args.workers, transcode=args.transcode,
                          max_gap=args.max_gap, max_span=args.max_span,
                          max_inflight=args.max_inflight,
                          admit_queue=args.queue_depth,
                          idle_timeout=args.idle_timeout)
    print(f"serving {server.root} on {server.host}:{server.port}",
          flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
