"""Typed failure taxonomy for the remote tier (DESIGN.md §14).

Every way a remote read can fail maps onto exactly one class here, and
every class answers two questions: *is it safe to retry* and *is the
connection still usable*.  The retry loop in ``remote.client`` switches
on these — a connect refusal, a dead-peer timeout, and a garbled frame
are all retried against the :class:`EndpointPool` (reads are idempotent),
while an application error from the server (missing branch, stale
generation, bad path) surfaces immediately: retrying it would return the
same answer and hide a real bug.

The taxonomy double-inherits from the builtin exception the old code
raised (``TimeoutError``, ``ConnectionError``, ``RuntimeError``) so
callers written against PR 5 — ``except RuntimeError`` around a fetch,
``pytest.raises(RuntimeError, match="stale generation")`` — keep
working unchanged.
"""

from __future__ import annotations

from . import protocol as P

__all__ = [
    "RemoteError", "RemoteTimeout", "RemoteConnectError",
    "RemoteServerError", "StaleGenerationError", "ServerBusy",
    "ReplicaMismatchError", "RepairFailedError", "classify_error",
    "RETRYABLE",
]


class RemoteError(Exception):
    """Base class for every remote-tier failure."""


class RemoteTimeout(RemoteError, TimeoutError):
    """A connect/send/recv exceeded its deadline (dead or stalled peer)."""


class RemoteConnectError(RemoteError, ConnectionError):
    """TCP connect to an endpoint failed (refused, unreachable, reset)."""


class RemoteServerError(RemoteError, RuntimeError):
    """The server answered ``RESP_ERROR`` — an application-level failure
    (bad path, unknown branch, out-of-range basket).  Not retried: the
    request itself is wrong, not the transport."""


class StaleGenerationError(RemoteServerError):
    """The served file was atomically replaced since the catalog was
    fetched; the caller must re-open to get the new TOC."""


class ServerBusy(RemoteError):
    """The server shed this request (``RESP_BUSY``).  Carries the
    server's suggested ``retry_after`` in seconds; the client retry loop
    honours it instead of its own backoff schedule."""

    def __init__(self, msg: str = "server busy", retry_after: float = 0.05):
        super().__init__(msg)
        self.retry_after = float(retry_after)


class ReplicaMismatchError(RemoteError):
    """A failover/hedge endpoint serves a *different* file under the same
    path (branch set or basket checksums disagree with the catalog this
    reader opened).  The endpoint is quarantined — silently mixing
    replicas with divergent content is the one thing a failover layer
    must never do."""


class RepairFailedError(RemoteError):
    """A repair pass (scrub heal, anti-entropy reconcile, ``bscrub``)
    finished with damage it could not fix — every parity stripe and every
    replica was tried.  Carries the surviving ``(branch, index)`` list so
    the operator knows exactly which bytes the fleet has lost."""

    def __init__(self, msg: str, remaining=()):
        super().__init__(msg)
        self.remaining = [tuple(r) for r in remaining]


def classify_error(exc: BaseException) -> str:
    """Map a transport failure onto its retry-reason label — the value
    of the ``reason`` tag on ``remote.retries`` counters."""
    if isinstance(exc, ServerBusy):
        return "busy"
    if isinstance(exc, RemoteTimeout):
        return "timeout"
    if isinstance(exc, RemoteConnectError):
        return "connect"
    if isinstance(exc, ReplicaMismatchError):
        return "mismatch"
    if isinstance(exc, P.ProtocolError):
        return "frame"
    if isinstance(exc, (ConnectionResetError, BrokenPipeError)):
        return "reset"
    if isinstance(exc, EOFError):
        return "reset"
    if isinstance(exc, OSError):
        return "io"
    return "other"


# transport-level failures the client retries against the pool; server
# application errors (RemoteServerError) are deliberately absent
RETRYABLE = (RemoteTimeout, RemoteConnectError, ReplicaMismatchError,
             P.ProtocolError, ServerBusy, EOFError, OSError)
