"""Wire transcoding: re-encode archive-tier baskets for read-bound clients.

The paper's online/offline split stores data at archive operating points
(lzma / high-level zstd: maximum ratio, slow decode) while analysis clients
are decode-throughput-bound.  A basket service can split the difference
per request: decode the archive codec *server-side* (once, amortized over
every client) and ship the basket re-encoded in a decode-cheap wire codec
(lz4 / zstd-fast / identity).

The mechanism reuses the whole existing stack:

* only the entropy codec is swapped — the preconditioner stage (shuffle /
  delta / bitshuffle) is preserved in the wire metadata, so the client's
  normal ``unpack_basket`` path (PR 2's vectorized cores, PR 3's
  decompress-into) decodes wire baskets with zero new code;
* the basket's raw-byte adler32 travels unchanged through the transcode
  (the raw bytes are the same), so the client's checksum verification is
  end-to-end: it would catch a server-side transcoding bug, not just wire
  corruption;
* whether transcoding *pays* is decided by a PR 4 :class:`Objective`
  blend over the client's **effective read rate** — a basket must cross
  the link (``comp_len`` bytes at ``link_mbps``) and then decode
  (``orig_len`` bytes at the codec's decode rate), so

      eff_rate = orig_len / (comp_len/link + orig_len/decode_rate)

  and the score is ``w_ratio·log(ratio) + w_read·log(eff_rate)`` with the
  *actual* transcoded sizes.  Ratio-bound objectives (``min_bytes``,
  ``production``) keep the archive bytes; read-bound ones (``analysis``,
  ``max_read_tput``) ship whichever wire codec wins the blend — identity
  on fast links (decode is the bottleneck), a real wire codec as the
  declared link gets slower (wire bytes start to dominate), the archive
  bytes again when its ratio advantage beats everything the link can
  save.
"""

from __future__ import annotations

import math
import time
from typing import Iterable, Optional, Sequence

from repro import obs
from repro.core import basket as _basket
from repro.core import codec as _codec
from repro.tune.model import Objective, resolve_objective

__all__ = ["WIRE_DECODE_MBPS", "WIRE_LEVELS", "wire_candidates",
           "score_wire", "transcode_basket", "transcode_many"]

# Nominal client-side decode throughput (MB/s) per codec — the decision
# rule's read-axis constants.  These are order-of-magnitude anchors from
# the fig_entropy / fig3 benchmark family (C codecs release the GIL and
# run at memory-ish speeds; the from-scratch vectorized cores are 1-2
# orders slower; lzma is the archive-tier outlier), not live measurements:
# the rule needs a stable *ranking*, and a served workload must not make
# per-request decisions from noisy one-shot timings.
WIRE_DECODE_MBPS: dict[str, float] = {
    "none": 8000.0,          # memcpy
    "zstd-fast": 900.0,      # libzstd, negative levels
    "zstd": 700.0,           # libzstd
    "zlib": 250.0,
    "lz4": 120.0,            # our vectorized two-pass token decoder
    "repro-zstd": 30.0,
    "repro-deflate": 25.0,
    "repro-deflate-ref": 25.0,
    "lzma": 60.0,
}
if not _codec.HAVE_ZSTD:
    # offline fallback: "zstd"/"zstd-fast" are backed by the pure-Python
    # large-window engine (DESIGN.md §4) — the decision rule must rank
    # what will actually run, not what the codec name suggests
    WIRE_DECODE_MBPS["zstd"] = WIRE_DECODE_MBPS["repro-zstd"]
    WIRE_DECODE_MBPS["zstd-fast"] = 40.0

# The link speed assumed when the request doesn't declare one (MB/s —
# ~10GbE).  Clients on slower links declare it per request; it shifts the
# effective-rate optimum from identity toward real wire codecs.
DEFAULT_LINK_MBPS = 1000.0

# The level each codec is *encoded at for the wire*: cheapest useful level
# — wire encoding happens per request, so encode cost is server latency.
WIRE_LEVELS: dict[str, int] = {
    "none": 0, "lz4": 1, "zstd-fast": 1, "zstd": 1, "zlib": 1,
}

DEFAULT_ACCEPT: tuple[str, ...] = ("zstd-fast", "lz4", "none")


def _rate(algo: str) -> float:
    return WIRE_DECODE_MBPS.get(algo, 50.0)


def effective_read_mbps(orig_len: int, comp_len: int, algo: str,
                        link_mbps: float = DEFAULT_LINK_MBPS) -> float:
    """Client-perceived MB/s of raw bytes for one basket: the wire bytes
    cross the link, then the raw bytes come out of the decoder — the two
    serial stages every remote read pays."""
    orig = max(int(orig_len), 1)
    t = max(int(comp_len), 1) / (max(link_mbps, 1e-6) * 1e6) \
        + orig / (_rate(algo) * 1e6)
    return orig / t / 1e6


def score_wire(objective: Objective, orig_len: int, comp_len: int,
               algo: str, link_mbps: float = DEFAULT_LINK_MBPS) -> float:
    """The objective's score for shipping this basket as ``comp_len``
    bytes of ``algo``: ratio axis from actual sizes, read axis from the
    effective (link + decode) rate.  (The write axis is server-side cost,
    not part of what the *client* optimizes — it is bounded by the
    prefilter.)"""
    ratio = orig_len / max(comp_len, 1)
    return (objective.w_ratio * math.log(max(ratio, 1e-9))
            + objective.w_read * math.log(
                effective_read_mbps(orig_len, comp_len, algo, link_mbps)))


def wire_candidates(meta_json: dict, objective, accept: Sequence[str],
                    link_mbps: float = DEFAULT_LINK_MBPS) -> list[str]:
    """Prefilter: which accepted wire codecs are worth *encoding* for this
    basket?  Transcoding is considered only when

    * the objective is read-bound (``w_read > w_ratio`` — a ratio-bound
      client asked for the archive bytes, don't burn server CPU), and
    * the candidate could beat the source's actual effective read rate
      even in the worst case for wire bytes (its compressed size unknown
      until encoded, so assume incompressible: ``stored_len`` on the
      wire).  A codec that loses *then* can never win after paying real
      encode work — e.g. re-encoding zstd-fast into the slower pure-Python
      lz4 is pruned before any CPU is spent.
    """
    obj = resolve_objective(objective)
    if obj.w_read <= obj.w_ratio:
        return []
    src = meta_json.get("algo", "none")
    if src == "none":
        return []                       # already the cheapest decode
    orig = int(meta_json["orig_len"])
    stored = int(meta_json["stored_len"])
    src_eff = effective_read_mbps(orig, int(meta_json["comp_len"]), src,
                                  link_mbps)
    return [a for a in accept
            if a in _codec.CODECS and a != src
            and effective_read_mbps(orig, stored, a, link_mbps) > src_eff]


def transcode_basket(payload, meta_json: dict,
                     dictionary: Optional[bytes], objective,
                     accept: Sequence[str] = DEFAULT_ACCEPT,
                     link_mbps: float = DEFAULT_LINK_MBPS
                     ) -> tuple[bytes, dict]:
    """Re-encode one basket payload for the wire if the objective says it
    pays; returns ``(wire_payload, wire_meta_json)`` — the input pair
    unchanged when keeping the archive bytes wins.

    Only the entropy-codec stage is swapped: the archive codec is decoded
    to the *preconditioned* byte stream (no precond inversion — that stays
    on the client, where the PR 3 decode-into path fuses it with the
    destination scatter), then re-encoded with each candidate wire codec;
    the actually-measured sizes feed the objective score.  The raw-byte
    checksum and entry bookkeeping are copied through untouched.
    """
    cands = wire_candidates(meta_json, objective, accept, link_mbps)
    if not cands:
        obs.counter("transcode.decisions", wire="pruned").inc()
        return payload, meta_json
    t0 = time.perf_counter()
    obj = resolve_objective(objective)
    src = meta_json["algo"]
    orig_len = int(meta_json["orig_len"])
    stored_len = int(meta_json["stored_len"])
    d = dictionary if meta_json.get("has_dict") else None
    staged = _codec.get_codec(src).decompress(bytes(payload), stored_len, d)
    if len(staged) != stored_len:
        raise ValueError(
            f"transcode decode produced {len(staged)} bytes, "
            f"expected stored_len {stored_len}")
    best = (score_wire(obj, orig_len, int(meta_json["comp_len"]), src,
                       link_mbps),
            payload, meta_json)
    # identity first (free — `staged` is already in hand), then the real
    # codecs; before paying a candidate's encode, bound its best possible
    # score (ratio can't beat the archive's at wire levels, effective
    # rate can't beat its decode rate) — a candidate whose ceiling loses
    # to the standing best is skipped without encoding a byte
    src_ratio = max(orig_len / max(int(meta_json["comp_len"]), 1), 1.0)
    for algo in sorted(cands, key=lambda a: a != "none"):
        if algo != "none":
            ceiling = (obj.w_ratio * math.log(src_ratio)
                       + obj.w_read * math.log(_rate(algo)))
            if ceiling <= best[0]:
                continue
        level = WIRE_LEVELS.get(algo, 1)
        wp = _codec.get_codec(algo).compress(staged, level, None) \
            if algo != "none" else staged
        s = score_wire(obj, orig_len, len(wp), algo, link_mbps)
        if s > best[0]:
            wm = dict(meta_json)
            wm.update(algo=algo, level=level, comp_len=len(wp),
                      has_dict=False)
            best = (s, wp, wm)
    won = best[2]["algo"] if best[2] is not meta_json else "kept"
    obs.counter("transcode.decisions", wire=won).inc()
    obs.histogram("transcode.s", src=src).observe(time.perf_counter() - t0)
    return best[1], best[2]


def transcode_many(items: Iterable[tuple], objective,
                   accept: Sequence[str] = DEFAULT_ACCEPT,
                   engine=None,
                   link_mbps: float = DEFAULT_LINK_MBPS
                   ) -> list[tuple[bytes, dict]]:
    """Transcode a vectored request's baskets, in order.

    ``items`` yields ``(payload, meta_json, dictionary)``.  With an
    ``engine`` (the server's shared :class:`CompressionEngine`), baskets
    transcode concurrently on its thread pool — the C archive codecs
    (lzma/zstd/zlib) release the GIL while decoding, which is where the
    time goes."""
    items = list(items)
    if engine is not None and len(items) > 1:
        futs = [engine.submit(transcode_basket, p, m, d, objective, accept,
                              link_mbps)
                for p, m, d in items]
        return [f.result() for f in futs]
    return [transcode_basket(p, m, d, objective, accept, link_mbps)
            for p, m, d in items]


def verify_transcode(payload, meta_json: dict, wire_payload,
                     wire_meta: dict, dictionary=None) -> bool:
    """Debug/test helper: both payloads must decode to identical raw
    bytes (same checksum, same content)."""
    a = _basket.unpack_basket(bytes(payload),
                              _basket.BasketMeta.from_json(meta_json),
                              dictionary)
    b = _basket.unpack_basket(bytes(wire_payload),
                              _basket.BasketMeta.from_json(wire_meta),
                              dictionary if wire_meta.get("has_dict") else None)
    return a == b
