"""repro.remote — the basket-granular content service (DESIGN.md §12).

The distribution layer over the local stack: an xrootd-analogue server
exports directories of BasketFiles and answers *vectored* basket requests
coalesced into large sequential preads; clients mirror the ``BasketFile``
read API over the wire with readahead, per-request wire transcoding
(archive codecs re-encoded decode-cheap for read-bound objectives), and a
tiered decoded/wire cache keyed by the file's (st_dev, st_ino) generation.

Entry points:

* :class:`BasketServer` — serve a directory (``python -m repro.remote`` /
  ``tools/bserve.py`` are the CLI);
* :class:`RemoteBasketFile` / :func:`connect` — open a
  ``repro://host:port/path`` URL with the local reader API;
* :class:`TieredCache` — the client cache, shareable across files;
* :class:`EndpointPool` — replica endpoints with health tracking, shared
  across files for failover and hedged reads;
* ``repro.data.pipeline.TokenPipeline`` accepts ``repro://`` shard URLs
  directly, and :class:`repro.io.prefetch.PrefetchReader` accepts a
  ``RemoteBasketFile`` wherever a local ``BasketFile`` goes.

Failure semantics (DESIGN.md §14) live in :mod:`repro.remote.errors`:
typed timeouts/connect errors, ``ServerBusy`` shedding, replica mismatch,
and the retry classification the client's backoff policy keys on.
"""

from .cache import TieredCache, basket_key
from .client import (EndpointPool, RemoteBasketFile, connect, fetch_catalog,
                     request_scrub)
from .errors import (RemoteConnectError, RemoteError, RemoteServerError,
                     RemoteTimeout, RepairFailedError, ReplicaMismatchError,
                     ServerBusy, StaleGenerationError)
from .protocol import ProtocolError, coalesce, format_url, parse_url
from .server import BasketServer

__all__ = [
    "BasketServer", "RemoteBasketFile", "connect", "fetch_catalog",
    "request_scrub", "TieredCache",
    "basket_key", "EndpointPool", "ProtocolError", "coalesce", "parse_url",
    "format_url", "RemoteError", "RemoteTimeout", "RemoteConnectError",
    "RemoteServerError", "StaleGenerationError", "ServerBusy",
    "ReplicaMismatchError", "RepairFailedError",
]
