"""The basket server — an xrootd-analogue content service for BasketFiles.

One process exports a directory tree of containers.  Every connection is a
handler thread (threaded socket server), but the expensive shared state is
engine-wide, exactly like the local stack:

* **one** :class:`~repro.io.engine.CompressionEngine` serves every
  connection's transcode work (the C archive codecs release the GIL, so a
  vectored request's baskets decode across the pool);
* **one** fd per container via ``repro.io.fdcache`` — a thousand clients
  hitting one file share a single descriptor and positional ``pread``s;
* **one** catalog entry per open container (TOC + tuning decisions +
  generation), revalidated by ``(st_dev, st_ino)`` on every touch, so an
  atomically-replaced file flips to a new catalog generation instead of
  serving baskets sliced with the old TOC.

The request that matters is ``READV``: many (branch, basket) ranges per
round-trip.  The server maps them to on-disk byte ranges, coalesces those
into large sequential ``pread``s (:func:`repro.remote.protocol.coalesce`),
slices each basket back out of the merged buffers, optionally transcodes
archive-tier payloads for the wire (``repro.remote.transcode``), and
answers with one frame.  Request vectorization + coalescing is where the
latency win comes from (arXiv:1804.03326's vector-read argument); the
per-request transcode is where the archive/analysis split is served from
one copy of the data.
"""

from __future__ import annotations

import logging
import os
import socketserver
import threading
import time
from typing import Optional

from repro import obs
from repro.core.bfile import BasketFile
from repro.io import fdcache
from repro.io.engine import CompressionEngine

from . import protocol as P
from . import transcode as T

__all__ = ["BasketServer"]

_LOG = logging.getLogger("repro.remote")


class _Catalog:
    """One open container: reader (TOC), generation, decoded dictionaries."""

    __slots__ = ("bf", "generation", "dicts")

    def __init__(self, abspath: str):
        # verify=False: the server never decodes raw bytes on the plain
        # path (transcode verifies content equality via stored_len and the
        # client re-verifies the raw checksum end-to-end)
        self.bf = BasketFile(abspath, verify=False)
        self.generation = self.bf.generation
        self.dicts = {name: self.bf._dictionary(entry)
                      for name, entry in self.bf.branches.items()}


class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        srv: "BasketServer" = self.server.basket_server
        peer = "%s:%s" % (self.client_address[0], self.client_address[1])
        seq = 0                     # per-connection request sequence
        while True:
            try:
                ftype, body, _payload = P.read_frame(self.rfile)
            except EOFError:
                return
            except P.ProtocolError as e:
                # malformed frame: answer once, then drop the connection —
                # framing is gone, nothing later on this stream is trusted
                obs.counter("server.errors", verb="protocol").inc()
                self._reply(P.RESP_ERROR, {"error": f"protocol: {e}"})
                return
            seq += 1
            verb = P.VERB_NAMES.get(ftype, str(ftype))
            t0 = time.perf_counter()
            try:
                with obs.trace.span("rbsp.serve", cat="server", verb=verb):
                    if ftype == P.REQ_PING:
                        self._reply(P.RESP_PING, {"ok": True})
                    elif ftype == P.REQ_CATALOG:
                        self._reply(P.RESP_CATALOG, srv._catalog_body(body))
                    elif ftype == P.REQ_READV:
                        rbody, payload = srv._readv(body)
                        self._reply(P.RESP_READV, rbody, payload)
                    elif ftype == P.REQ_STATS:
                        self._reply(P.RESP_STATS, srv._stats_body(body))
                    else:
                        self._reply(P.RESP_ERROR,
                                    {"error": f"unexpected frame type {ftype}"})
                obs.counter("server.requests", verb=verb).inc()
                obs.histogram("server.request_s", verb=verb).observe(
                    time.perf_counter() - t0)
            except BrokenPipeError:
                return
            except Exception as e:   # per-request fault isolation
                obs.counter("server.errors", verb=verb).inc()
                _LOG.warning("request failed (peer=%s seq=%d verb=%s): %r",
                             peer, seq, verb, e)
                try:
                    self._reply(P.RESP_ERROR, {"error": str(e)})
                except OSError:
                    return

    def _reply(self, ftype: int, body: dict, payload: bytes = b"") -> None:
        self.wfile.write(P.pack_frame(ftype, body, payload))
        self.wfile.flush()


class _TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True
    # small request/response frames must not sit in Nagle/delayed-ACK
    # limbo — a vectored protocol lives or dies by per-round-trip latency
    disable_nagle_algorithm = True


class BasketServer:
    """Serve a directory of BasketFiles over RBSP.

    ``port=0`` binds an ephemeral port (read it back from :attr:`port`) —
    the test/benchmark loopback pattern.  ``transcode=False`` disables
    wire transcoding server-wide regardless of what clients request.
    """

    def __init__(self, root: str, host: str = "127.0.0.1", port: int = 0,
                 workers: int = 4, transcode: bool = True,
                 max_gap: int = 64 << 10, max_span: int = 8 << 20,
                 engine: Optional[CompressionEngine] = None):
        self.root = os.path.abspath(root)
        if not os.path.isdir(self.root):
            raise NotADirectoryError(self.root)
        self.transcode = bool(transcode)
        self.max_gap = int(max_gap)
        self.max_span = int(max_span)
        self.engine = engine if engine is not None \
            else CompressionEngine(workers)
        self._owns_engine = engine is None
        self._catalogs: dict[str, _Catalog] = {}
        self._cat_lock = threading.Lock()
        self._tcp = _TCPServer((host, port), _Handler)
        self._tcp.basket_server = self
        self._thread: Optional[threading.Thread] = None
        self._serving = False
        self._closed = False
        # stats (under _stat_lock)
        self._stat_lock = threading.Lock()
        self.stats = {"requests": 0, "baskets_served": 0, "preads": 0,
                      "bytes_disk": 0, "bytes_wire": 0, "transcoded": 0}
        self._stats_gen = 0           # bumps per STATS response (under lock)
        self._t_start = time.time()

    # -- lifecycle -------------------------------------------------------

    @property
    def host(self) -> str:
        return self._tcp.server_address[0]

    @property
    def port(self) -> int:
        return self._tcp.server_address[1]

    def url(self, rel_path: str) -> str:
        return P.format_url(self.host, self.port, rel_path)

    def start(self) -> "BasketServer":
        """Serve on a daemon thread (the embedded / test mode)."""
        self._serving = True
        self._thread = threading.Thread(target=self._tcp.serve_forever,
                                        daemon=True, name="repro-bserve")
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        """Serve on the calling thread (the CLI mode)."""
        self._serving = True
        self._tcp.serve_forever()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._serving:
            # shutdown() blocks on an event only serve_forever() sets —
            # calling it on a bound-but-never-served server deadlocks
            self._tcp.shutdown()
        self._tcp.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
        with self._cat_lock:
            cats, self._catalogs = list(self._catalogs.values()), {}
        for c in cats:
            c.bf.close()
        if self._owns_engine:
            self.engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()

    # -- catalog ---------------------------------------------------------

    def _resolve(self, rel: str) -> str:
        """Map a request path onto the export root; reject escapes."""
        rel = str(rel)
        norm = os.path.normpath(rel)
        if os.path.isabs(norm) or norm.startswith("..") or norm == ".":
            raise ValueError(f"invalid path {rel!r}")
        return os.path.join(self.root, norm)

    def _catalog(self, rel: str) -> _Catalog:
        """The open container for ``rel``, revalidated by generation: a
        replaced file atomically swaps to a fresh catalog (the old reader
        is closed, releasing its cached fd — long-lived servers must not
        pin unlinked inodes)."""
        abspath = self._resolve(rel)
        with self._cat_lock:
            cat = self._catalogs.get(rel)
            if cat is not None:
                try:
                    if fdcache.generation(abspath) == cat.generation:
                        return cat
                except OSError:
                    pass
                del self._catalogs[rel]
                cat.bf.close()
            cat = _Catalog(abspath)
            self._catalogs[rel] = cat
            return cat

    def _catalog_body(self, body: dict) -> dict:
        cat = self._catalog(body["path"])
        return {
            "path": body["path"],
            "generation": list(cat.generation),
            "branches": cat.bf.branches,
            # canonical JSON sorts keys; the TOC's branch order is API
            # (branch_names() mirrors the write order), so carry it aside
            "order": list(cat.bf.branches),
            "tuning": cat.bf.tuning,
            "transcode": self.transcode,
        }

    # -- observability ---------------------------------------------------

    def _stats_body(self, body: dict) -> dict:
        """The ``STATS`` response: generation-stamped snapshot of the
        process-wide obs registry plus this server's stats dict.  The
        generation is a per-server monotonic counter so a monitor can
        tell two polls apart (and detect a restarted server by a reset).
        ``"trace": true`` drains the span ring into the response — each
        buffered event leaves the server exactly once."""
        with self._stat_lock:
            self._stats_gen += 1
            gen = self._stats_gen
            server_stats = dict(self.stats)
        out = {"gen": gen, "pid": os.getpid(),
               "uptime_s": time.time() - self._t_start,
               "server": server_stats,
               "metrics": obs.snapshot()}
        if body.get("trace"):
            out["trace_events"] = obs.trace.drain()
        return out

    # -- vectored reads --------------------------------------------------

    def _readv(self, body: dict) -> tuple[dict, bytes]:
        rel = body["path"]
        cat = self._catalog(rel)
        gen = body.get("generation")
        if gen is not None and tuple(gen) != cat.generation:
            raise ValueError(
                f"stale generation {tuple(gen)} for {rel!r} "
                f"(current {cat.generation}); re-open the catalog")
        abspath = self._resolve(rel)
        wants = body.get("baskets") or []
        ranges = []
        metas = []
        for branch, idx in wants:
            entry = cat.bf.branches.get(branch)
            if entry is None:
                raise KeyError(f"no branch {branch!r} in {rel!r}")
            idx = int(idx)
            if not 0 <= idx < len(entry["baskets"]):
                raise IndexError(f"basket {idx} out of range for "
                                 f"{branch!r} ({len(entry['baskets'])})")
            b = entry["baskets"][idx]
            ranges.append((int(b["offset"]), int(b["meta"]["comp_len"])))
            metas.append(dict(b["meta"]))

        # per-branch access telemetry: the repacker's input signal.  One
        # locked add per (path, branch) pair per request, not per basket.
        per_branch: dict[str, int] = {}
        for branch, _idx in wants:
            per_branch[branch] = per_branch.get(branch, 0) + 1
        for branch, n in per_branch.items():
            obs.counter("server.reads", path=rel, branch=branch).inc(n)

        merged = P.coalesce(ranges, self.max_gap, self.max_span)
        payloads: list[Optional[bytes]] = [None] * len(wants)
        disk_bytes = 0
        with obs.trace.span("server.pread", cat="server", path=rel,
                            preads=len(merged)):
            for off, ln, members in merged:
                buf = fdcache.pread(abspath, off, ln, expect=cat.generation)
                disk_bytes += ln
                for i in members:
                    r_off, r_len = ranges[i]
                    payloads[i] = buf[r_off - off: r_off - off + r_len]

        n_trans = 0
        wire = body.get("wire")
        if wire and self.transcode:
            accept = wire.get("accept") or list(T.DEFAULT_ACCEPT)
            objective = wire.get("objective", "max_read_tput")
            link = float(wire.get("link_mbps") or T.DEFAULT_LINK_MBPS)
            items = [(payloads[i], metas[i], cat.dicts[wants[i][0]])
                     for i in range(len(wants))]
            out = T.transcode_many(items, objective, accept,
                                   engine=self.engine, link_mbps=link)
            for i, (wp, wm) in enumerate(out):
                n_trans += wm is not metas[i]    # kept baskets pass through
                payloads[i], metas[i] = wp, wm

        resp_baskets = []
        for (branch, idx), m, p in zip(wants, metas, payloads):
            resp_baskets.append({"branch": branch, "index": int(idx),
                                 "len": len(p), "meta": m})
        payload = b"".join(payloads)
        with self._stat_lock:
            self.stats["requests"] += 1
            self.stats["baskets_served"] += len(wants)
            self.stats["preads"] += len(merged)
            self.stats["bytes_disk"] += disk_bytes
            self.stats["bytes_wire"] += len(payload)
            self.stats["transcoded"] += n_trans
        obs.counter("server.baskets_served").inc(len(wants))
        obs.counter("server.bytes_disk").inc(disk_bytes)
        obs.counter("server.bytes_wire").inc(len(payload))
        obs.histogram("server.readv_baskets").observe(len(wants))
        return {"path": rel, "generation": list(cat.generation),
                "baskets": resp_baskets}, payload
