"""The basket server — an xrootd-analogue content service for BasketFiles.

One process exports a directory tree of containers.  Every connection is a
handler thread (threaded socket server), but the expensive shared state is
engine-wide, exactly like the local stack:

* **one** :class:`~repro.io.engine.CompressionEngine` serves every
  connection's transcode work (the C archive codecs release the GIL, so a
  vectored request's baskets decode across the pool);
* **one** fd per container via ``repro.io.fdcache`` — a thousand clients
  hitting one file share a single descriptor and positional ``pread``s;
* **one** catalog entry per open container (TOC + tuning decisions +
  generation), revalidated by ``(st_dev, st_ino)`` on every touch, so an
  atomically-replaced file flips to a new catalog generation instead of
  serving baskets sliced with the old TOC.

The request that matters is ``READV``: many (branch, basket) ranges per
round-trip.  The server maps them to on-disk byte ranges, coalesces those
into large sequential ``pread``s (:func:`repro.remote.protocol.coalesce`),
slices each basket back out of the merged buffers, optionally transcodes
archive-tier payloads for the wire (``repro.remote.transcode``), and
answers with one frame.  Request vectorization + coalescing is where the
latency win comes from (arXiv:1804.03326's vector-read argument); the
per-request transcode is where the archive/analysis split is served from
one copy of the data.

Degradation under load is graceful, not accidental (DESIGN.md §14): a
bounded admission gate (``max_inflight`` concurrent requests, then a
bounded wait queue) sheds excess work with ``RESP_BUSY`` + a load-scaled
retry-after instead of queueing unboundedly until every client times out;
idle connections are reaped after ``idle_timeout``; and ``close()`` drains
— in-flight requests finish (bounded by ``drain_timeout``) before
lingering connections are forcibly closed.
"""

from __future__ import annotations

import logging
import os
import socket
import socketserver
import threading
import time
from typing import Optional

from repro import obs
from repro.core.bfile import BasketFile
from repro.io import fdcache
from repro.io.engine import CompressionEngine

from . import protocol as P
from . import transcode as T

__all__ = ["BasketServer"]

_LOG = logging.getLogger("repro.remote")


class _Catalog:
    """One open container: reader (TOC), generation, decoded dictionaries."""

    __slots__ = ("bf", "generation", "dicts")

    def __init__(self, abspath: str, heal=None):
        # verify=False: the server never decodes raw bytes on the plain
        # path (transcode verifies content equality via stored_len and the
        # client re-verifies the raw checksum end-to-end).  heal="auto"
        # (the self-healing server) arms in-place parity reconstruction
        # for the verify-on-serve path (_readv) and the scrubber.
        self.bf = BasketFile(abspath, verify=False, heal=heal)
        self.generation = self.bf.generation
        self.dicts = {name: self.bf._dictionary(entry)
                      for name, entry in self.bf.branches.items()}


class _Handler(socketserver.StreamRequestHandler):
    def setup(self):
        super().setup()
        srv: "BasketServer" = self.server.basket_server
        # per-connection idle reaping: a client that stops talking (or a
        # half-open TCP ghost) releases its handler thread instead of
        # pinning it forever
        if srv.idle_timeout:
            self.connection.settimeout(srv.idle_timeout)
        srv._register(self.connection)

    def finish(self):
        self.server.basket_server._unregister(self.connection)
        try:
            super().finish()
        except OSError:
            pass                    # drain force-closed the socket under us

    def handle(self):
        srv: "BasketServer" = self.server.basket_server
        peer = "%s:%s" % (self.client_address[0], self.client_address[1])
        seq = 0                     # per-connection request sequence
        while not srv._draining.is_set():
            try:
                ftype, body, _payload = P.read_frame(self.rfile)
            except EOFError:
                return
            except (socket.timeout, TimeoutError):
                obs.counter("server.idle_closed").inc()
                return
            except P.ProtocolError as e:
                # malformed frame: answer once, then drop the connection —
                # framing is gone, nothing later on this stream is trusted
                obs.counter("server.errors", verb="protocol").inc()
                try:
                    self._reply(P.RESP_ERROR, {"error": f"protocol: {e}"})
                except OSError:
                    pass
                return
            except OSError:
                return              # force-closed mid-read (drain)
            seq += 1
            verb = P.VERB_NAMES.get(ftype, str(ftype))
            if not srv._admit():
                # saturated: shed with a load-scaled retry-after rather
                # than queueing until every waiting client times out
                obs.counter("server.shed").inc()
                try:
                    self._reply(P.RESP_BUSY, {"error": "busy",
                                              "retry_after_s":
                                              srv._retry_after()})
                except OSError:
                    return
                continue
            t0 = time.perf_counter()
            try:
                # adopt the caller's traceparent (if any rode in) so the
                # serve span — and every span below it, pread/transcode/
                # engine — chains into the client's trace (DESIGN.md §16)
                with obs.context.activated(body.get("tp")), \
                        obs.trace.span("rbsp.serve", cat="server", verb=verb):
                    if ftype == P.REQ_PING:
                        self._reply(P.RESP_PING, {"ok": True})
                    elif ftype == P.REQ_CATALOG:
                        self._reply(P.RESP_CATALOG, srv._catalog_body(body))
                    elif ftype == P.REQ_READV:
                        rbody, payload = srv._readv(body)
                        self._reply(P.RESP_READV, rbody, payload)
                    elif ftype == P.REQ_STATS:
                        self._reply(P.RESP_STATS, srv._stats_body(body))
                    elif ftype == P.REQ_SCRUB:
                        self._reply(P.RESP_SCRUB, srv._scrub_body(body))
                    elif ftype == P.REQ_PROF:
                        self._reply(P.RESP_PROF, srv._prof_body(body))
                    else:
                        self._reply(P.RESP_ERROR,
                                    {"error": f"unexpected frame type {ftype}"})
                obs.counter("server.requests", verb=verb).inc()
                obs.histogram("server.request_s", verb=verb).observe(
                    time.perf_counter() - t0)
                if srv.heatlog is not None:
                    srv.heatlog.maybe_flush()
            except BrokenPipeError:
                return
            except (socket.timeout, TimeoutError):
                return              # peer stopped reading our reply
            except Exception as e:   # per-request fault isolation
                obs.counter("server.errors", verb=verb).inc()
                _LOG.warning("request failed (peer=%s seq=%d verb=%s): %r",
                             peer, seq, verb, e)
                try:
                    self._reply(P.RESP_ERROR, {"error": str(e)})
                except OSError:
                    return
            finally:
                srv._finish_request()

    def _reply(self, ftype: int, body: dict, payload: bytes = b"") -> None:
        self.wfile.write(P.pack_frame(ftype, body, payload))
        self.wfile.flush()


class _TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True
    # small request/response frames must not sit in Nagle/delayed-ACK
    # limbo — a vectored protocol lives or dies by per-round-trip latency
    disable_nagle_algorithm = True


class BasketServer:
    """Serve a directory of BasketFiles over RBSP.

    ``port=0`` binds an ephemeral port (read it back from :attr:`port`) —
    the test/benchmark loopback pattern.  ``transcode=False`` disables
    wire transcoding server-wide regardless of what clients request.

    Load/lifecycle knobs: at most ``max_inflight`` requests execute
    concurrently; up to ``admit_queue`` more wait (each at most
    ``admit_timeout`` seconds) before being shed with ``RESP_BUSY``;
    connections idle longer than ``idle_timeout`` are closed; ``close()``
    lets in-flight requests finish for up to ``drain_timeout`` seconds
    before force-closing what remains.

    Self-healing (DESIGN.md §15): ``heal="auto"`` makes READV
    verify-on-serve — every basket slice is decode-verified before it
    goes on the wire, and a failing one is reconstructed in place from
    its parity stripe (repro.repair) rather than served corrupt.
    ``scrub_mbps`` additionally runs a background :class:`Scrubber`
    thread over the export root at that byte-rate budget (started with
    the server, drained with ``close()``); the RBSP ``SCRUB`` verb
    inspects/triggers it.
    """

    def __init__(self, root: str, host: str = "127.0.0.1", port: int = 0,
                 workers: int = 4, transcode: bool = True,
                 max_gap: int = 64 << 10, max_span: int = 8 << 20,
                 engine: Optional[CompressionEngine] = None,
                 max_inflight: int = 32, admit_queue: int = 128,
                 admit_timeout: float = 5.0, idle_timeout: float = 600.0,
                 drain_timeout: float = 10.0, heal: Optional[str] = None,
                 scrub_mbps: Optional[float] = None,
                 scrub_interval: float = 30.0,
                 heat: bool = True, heat_halflife_s: float = 3600.0,
                 heat_flush_s: float = 30.0,
                 slo=True):
        self.root = os.path.abspath(root)
        if not os.path.isdir(self.root):
            raise NotADirectoryError(self.root)
        self.transcode = bool(transcode)
        self.max_gap = int(max_gap)
        self.max_span = int(max_span)
        self.max_inflight = max(int(max_inflight), 1)
        self.admit_queue = max(int(admit_queue), 0)
        self.admit_timeout = float(admit_timeout)
        self.idle_timeout = float(idle_timeout)
        self.drain_timeout = float(drain_timeout)
        if heal not in (None, "auto"):
            raise ValueError(f"heal must be None or 'auto', got {heal!r}")
        self.heal = heal
        self._scrubber = None
        if scrub_mbps is not None:
            from repro.repair import Scrubber
            self._scrubber = Scrubber(self.root, mbps=scrub_mbps or None,
                                      heal=heal is not None,
                                      interval=scrub_interval)
        # durable access-heat telemetry + rolling SLO verdicts (§16).
        # heat=False turns the sidecars off (read-only serving roots);
        # slo may be False/None, True (defaults), or a list of SLOSpec.
        from repro.obs.heat import HeatLog
        from repro.obs.slo import SLOEngine
        self.heatlog = HeatLog(halflife_s=heat_halflife_s,
                               flush_interval_s=heat_flush_s) \
            if heat else None
        if slo is True:
            self.slo_engine: Optional[SLOEngine] = SLOEngine()
        elif slo:
            self.slo_engine = SLOEngine(slo)
        else:
            self.slo_engine = None
        self.engine = engine if engine is not None \
            else CompressionEngine(workers)
        self._owns_engine = engine is None
        self._catalogs: dict[str, _Catalog] = {}
        self._cat_lock = threading.Lock()
        # admission gate: a semaphore bounds concurrency; the queued
        # counter bounds how many may *wait* for a slot
        self._sem = threading.BoundedSemaphore(self.max_inflight)
        self._load_cond = threading.Condition()
        self._inflight = 0
        self._queued = 0
        self._draining = threading.Event()
        self._conns: set = set()
        self._conn_lock = threading.Lock()
        self._tcp = _TCPServer((host, port), _Handler)
        self._tcp.basket_server = self
        self._thread: Optional[threading.Thread] = None
        self._serving = False
        self._closed = False
        # stats (under _stat_lock)
        self._stat_lock = threading.Lock()
        self.stats = {"requests": 0, "baskets_served": 0, "preads": 0,
                      "bytes_disk": 0, "bytes_wire": 0, "transcoded": 0}
        self._stats_gen = 0           # bumps per STATS response (under lock)
        self._t_start = time.time()

    # -- admission / load shedding ---------------------------------------

    def _register(self, conn) -> None:
        with self._conn_lock:
            self._conns.add(conn)

    def _unregister(self, conn) -> None:
        with self._conn_lock:
            self._conns.discard(conn)

    def _admit(self) -> bool:
        """Take an execution slot, waiting in the bounded admission queue
        when the pool is saturated.  False means the request must be shed."""
        if self._sem.acquire(blocking=False):
            with self._load_cond:
                self._inflight += 1
            return True
        with self._load_cond:
            if self._queued >= self.admit_queue or self._draining.is_set():
                return False
            self._queued += 1
        ok = self._sem.acquire(timeout=self.admit_timeout)
        with self._load_cond:
            self._queued -= 1
            if ok:
                self._inflight += 1
        return ok

    def _finish_request(self) -> None:
        with self._load_cond:
            self._inflight -= 1
            self._load_cond.notify_all()
        self._sem.release()

    def _retry_after(self) -> float:
        """The shed response's suggested delay, scaled with queue depth so
        a deeper backlog spreads retries further out."""
        with self._load_cond:
            q = self._queued
        return round(min(1.0, 0.02 + 0.01 * q), 4)

    # -- lifecycle -------------------------------------------------------

    @property
    def host(self) -> str:
        return self._tcp.server_address[0]

    @property
    def port(self) -> int:
        return self._tcp.server_address[1]

    def url(self, rel_path: str) -> str:
        return P.format_url(self.host, self.port, rel_path)

    def start(self) -> "BasketServer":
        """Serve on a daemon thread (the embedded / test mode)."""
        self._serving = True
        self._thread = threading.Thread(target=self._tcp.serve_forever,
                                        daemon=True, name="repro-bserve")
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        """Serve on the calling thread (the CLI mode)."""
        self._serving = True
        self._tcp.serve_forever()

    def close(self) -> None:
        """Drain-then-close: stop accepting, let in-flight requests finish
        (bounded by ``drain_timeout``), then force-close lingering
        connections so blocked reads unblock and handler threads exit."""
        if self._closed:
            return
        self._closed = True
        self._draining.set()
        if self._scrubber is not None:
            self._scrubber.close()
        if self._serving:
            # shutdown() blocks on an event only serve_forever() sets —
            # calling it on a bound-but-never-served server deadlocks
            self._tcp.shutdown()
        self._tcp.server_close()
        deadline = time.monotonic() + self.drain_timeout
        with self._load_cond:
            while self._inflight > 0:
                remain = deadline - time.monotonic()
                if remain <= 0:
                    _LOG.warning("drain timeout with %d requests in flight",
                                 self._inflight)
                    break
                self._load_cond.wait(timeout=remain)
        # idle handlers are still blocked in read_frame; yank their
        # sockets so the threads exit instead of waiting out idle_timeout
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=5)
        if self.heatlog is not None:   # final durable fold of access heat
            self.heatlog.flush()
        with self._cat_lock:
            cats, self._catalogs = list(self._catalogs.values()), {}
        for c in cats:
            c.bf.close()
        if self._owns_engine:
            self.engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()

    # -- catalog ---------------------------------------------------------

    def _resolve(self, rel: str) -> str:
        """Map a request path onto the export root; reject escapes."""
        rel = str(rel)
        norm = os.path.normpath(rel)
        if os.path.isabs(norm) or norm.startswith("..") or norm == ".":
            raise ValueError(f"invalid path {rel!r}")
        return os.path.join(self.root, norm)

    def _catalog(self, rel: str) -> _Catalog:
        """The open container for ``rel``, revalidated by generation: a
        replaced file atomically swaps to a fresh catalog (the old reader
        is closed, releasing its cached fd — long-lived servers must not
        pin unlinked inodes)."""
        abspath = self._resolve(rel)
        with self._cat_lock:
            cat = self._catalogs.get(rel)
            if cat is not None:
                try:
                    if fdcache.generation(abspath) == cat.generation:
                        return cat
                except OSError:
                    pass
                del self._catalogs[rel]
                cat.bf.close()
            cat = _Catalog(abspath, heal=self.heal)
            self._catalogs[rel] = cat
            return cat

    def _catalog_body(self, body: dict) -> dict:
        cat = self._catalog(body["path"])
        return {
            "path": body["path"],
            "generation": list(cat.generation),
            "branches": cat.bf.branches,
            # canonical JSON sorts keys; the TOC's branch order is API
            # (branch_names() mirrors the write order), so carry it aside
            "order": list(cat.bf.branches),
            "tuning": cat.bf.tuning,
            "transcode": self.transcode,
        }

    # -- observability ---------------------------------------------------

    @staticmethod
    def _filter_snapshot(snap: dict, prefixes) -> dict:
        """Restrict a registry snapshot to metric-name prefixes (labels
        are part of the key but prefixes match the *name*)."""
        if isinstance(prefixes, str):
            prefixes = [prefixes]
        pfx = tuple(str(p) for p in prefixes)
        out = {}
        for kind, metrics in snap.items():
            out[kind] = {k: v for k, v in metrics.items()
                         if k.startswith(pfx)}
        return out

    def _stats_body(self, body: dict) -> dict:
        """The ``STATS`` response: generation-stamped snapshot of the
        process-wide obs registry plus this server's stats dict.  The
        generation is a per-server monotonic counter so a monitor can
        tell two polls apart (and detect a restarted server by a reset).
        ``"trace": true`` drains the span ring into the response — each
        buffered event leaves the server exactly once.  ``"filter"`` (a
        metric-name prefix or list of prefixes) trims the shipped
        registry; a bare poll still gets everything.  ``"heat": true``
        includes the access-heat snapshot.  Each poll also ticks the SLO
        engine, whose rolling verdicts ride the ``"slo"`` key."""
        with self._stat_lock:
            self._stats_gen += 1
            gen = self._stats_gen
            server_stats = dict(self.stats)
        snap = obs.snapshot()
        out = {"gen": gen, "pid": os.getpid(),
               "uptime_s": time.time() - self._t_start,
               "server": server_stats,
               "metrics": snap}
        if self.slo_engine is not None:
            with self._stat_lock:
                self.slo_engine.tick(snap)
                out["slo"] = self.slo_engine.evaluate()
        flt = body.get("filter")
        if flt:
            out["metrics"] = self._filter_snapshot(snap, flt)
        if body.get("heat") and self.heatlog is not None:
            out["heat"] = self.heatlog.snapshot()
        if body.get("trace"):
            out["trace_events"] = obs.trace.drain()
        if body.get("profile"):
            # the --watch profiler section's input: status + per-function
            # self counts, never the full fold table (that is PROF fetch)
            pstat = obs.profile.status()
            pstat["self"] = obs.profile.self_counts()
            out["profile"] = pstat
        return out

    # -- continuous profiling control (PROF verb) ------------------------

    def _prof_body(self, body: dict) -> dict:
        """The ``PROF`` verb (DESIGN.md §17): ``start``/``stop`` manage
        this process's sampling profiler, ``status`` reports it, and
        ``fetch`` ships the profile document (fold table + span trace ids
        + memory watermarks; ``reset: true`` drains, so successive fetches
        cover disjoint windows)."""
        action = body.get("action", "status")
        if action == "start":
            hz = float(body.get("hz") or obs.profile.DEFAULT_HZ)
            started = obs.profile.start(hz=hz, mem=body.get("mem") or False)
            return {"started": started, "profile": obs.profile.status()}
        if action == "stop":
            obs.profile.stop()
            return {"stopped": True, "profile": obs.profile.status()}
        if action == "status":
            return {"profile": obs.profile.status()}
        if action == "fetch":
            # fold the pool workers' samples in first so a remote
            # flamegraph includes process-pool stacks, like collect_obs
            self.engine.collect_obs()
            return {"profile": obs.profile.snapshot(
                reset=bool(body.get("reset")))}
        raise ValueError(f"unknown prof action {action!r}")

    # -- self-healing control (SCRUB verb) -------------------------------

    def _scrub_body(self, body: dict) -> dict:
        """The ``SCRUB`` verb: ``status`` / ``trigger`` poke the
        background scrubber; ``scrub`` runs one synchronous pass (of a
        single container when ``path`` is given, else the whole root) on
        this request's thread and returns the reports."""
        action = body.get("action", "status")
        if action == "status":
            return {"scrubber": self._scrubber.status()
                    if self._scrubber is not None else None,
                    "heal": self.heal}
        if action == "trigger":
            if self._scrubber is None:
                raise ValueError("no background scrubber configured "
                                 "(start the server with scrub_mbps=)")
            self._scrubber.trigger()
            return {"triggered": True}
        if action == "scrub":
            rel = body.get("path")
            if self._scrubber is not None:
                reports = self._scrubber.scrub_now(rel)
            else:
                from repro.repair import scrub_container
                if rel is not None:
                    reports = [scrub_container(self._resolve(rel),
                                               heal=self.heal is not None)]
                else:
                    reports = []
                    for dirpath, _d, files in os.walk(self.root):
                        for fn in sorted(files):
                            if fn.endswith(".bskt"):
                                reports.append(scrub_container(
                                    os.path.join(dirpath, fn),
                                    heal=self.heal is not None))
            for r in reports:
                r["path"] = os.path.relpath(r["path"], self.root) \
                    if os.path.isabs(r["path"]) else r["path"]
            return {"reports": reports}
        raise ValueError(f"unknown scrub action {action!r}")

    # -- vectored reads --------------------------------------------------

    def _readv(self, body: dict) -> tuple[dict, bytes]:
        with obs.profile.mem_phase("server.readv"):
            return self._readv_inner(body)

    def _readv_inner(self, body: dict) -> tuple[dict, bytes]:
        rel = body["path"]
        cat = self._catalog(rel)
        gen = body.get("generation")
        if gen is not None and tuple(gen) != cat.generation:
            raise ValueError(
                f"stale generation {tuple(gen)} for {rel!r} "
                f"(current {cat.generation}); re-open the catalog")
        abspath = self._resolve(rel)
        wants = body.get("baskets") or []
        ranges = []
        metas = []
        for branch, idx in wants:
            entry = cat.bf.branches.get(branch)
            if entry is None:
                raise KeyError(f"no branch {branch!r} in {rel!r}")
            idx = int(idx)
            if not 0 <= idx < len(entry["baskets"]):
                raise IndexError(f"basket {idx} out of range for "
                                 f"{branch!r} ({len(entry['baskets'])})")
            b = entry["baskets"][idx]
            ranges.append((int(b["offset"]), int(b["meta"]["comp_len"])))
            metas.append(dict(b["meta"]))

        # per-branch access telemetry: the repacker's input signal.  One
        # locked add per (path, branch) pair per request, not per basket;
        # the heat log additionally folds basket indices + byte volume
        # into its durable per-container EWMA state.
        per_branch: dict[str, list] = {}    # branch -> [idx list, bytes]
        for i, (branch, idx) in enumerate(wants):
            rec = per_branch.setdefault(branch, [[], 0])
            rec[0].append(int(idx))
            rec[1] += ranges[i][1]
        for branch, (idxs, nbytes) in per_branch.items():
            obs.counter("server.reads", path=rel, branch=branch).inc(
                len(idxs))
            if self.heatlog is not None:
                self.heatlog.record(abspath, branch, idxs, nbytes)

        merged = P.coalesce(ranges, self.max_gap, self.max_span)
        payloads: list[Optional[bytes]] = [None] * len(wants)
        disk_bytes = 0
        with obs.trace.span("server.pread", cat="server", path=rel,
                            preads=len(merged)):
            for off, ln, members in merged:
                buf = fdcache.pread(abspath, off, ln, expect=cat.generation)
                disk_bytes += ln
                for i in members:
                    r_off, r_len = ranges[i]
                    payloads[i] = buf[r_off - off: r_off - off + r_len]

        if self.heal is not None:
            # verify-on-serve: a slice that fails its decode-verify is
            # healed from parity (in place — the generation survives) and
            # re-read before it ever reaches the wire.  Best-effort: an
            # unhealable basket (double-damaged stripe, no sidecar) is
            # served as-is so the *client's* end-to-end checksum + cross-
            # replica quarantine takes over — a hard error here would turn
            # damage one replica can't fix into damage no replica serves.
            from repro.core.bfile import CorruptBasketError
            for i, (branch, idx) in enumerate(wants):
                try:
                    payloads[i] = cat.bf.ensure_payload(branch, int(idx),
                                                        payloads[i])
                except CorruptBasketError as e:
                    _LOG.warning("verify-on-serve: unhealable basket "
                                 "served damaged: %s", e)

        n_trans = 0
        wire = body.get("wire")
        if wire and self.transcode:
            accept = wire.get("accept") or list(T.DEFAULT_ACCEPT)
            objective = wire.get("objective", "max_read_tput")
            link = float(wire.get("link_mbps") or T.DEFAULT_LINK_MBPS)
            items = [(payloads[i], metas[i], cat.dicts[wants[i][0]])
                     for i in range(len(wants))]
            out = T.transcode_many(items, objective, accept,
                                   engine=self.engine, link_mbps=link)
            for i, (wp, wm) in enumerate(out):
                n_trans += wm is not metas[i]    # kept baskets pass through
                payloads[i], metas[i] = wp, wm

        resp_baskets = []
        for (branch, idx), m, p in zip(wants, metas, payloads):
            resp_baskets.append({"branch": branch, "index": int(idx),
                                 "len": len(p), "meta": m})
        payload = b"".join(payloads)
        with self._stat_lock:
            self.stats["requests"] += 1
            self.stats["baskets_served"] += len(wants)
            self.stats["preads"] += len(merged)
            self.stats["bytes_disk"] += disk_bytes
            self.stats["bytes_wire"] += len(payload)
            self.stats["transcoded"] += n_trans
        obs.counter("server.baskets_served").inc(len(wants))
        obs.counter("server.bytes_disk").inc(disk_bytes)
        obs.counter("server.bytes_wire").inc(len(payload))
        obs.histogram("server.readv_baskets").observe(len(wants))
        return {"path": rel, "generation": list(cat.generation),
                "baskets": resp_baskets}, payload
