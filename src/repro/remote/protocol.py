"""RBSP — the repro basket-service wire protocol (DESIGN.md §12).

The xrootd analogue's framing layer: length-prefixed frames carrying a
canonical-JSON body plus an optional binary payload, shared verbatim by
client and server.  Every payload carries a frame-level adler32 so a
truncated or corrupted wire fails at the frame boundary — *before* any
basket metadata is trusted — and every basket inside the payload still
carries its own raw-byte checksum from the container, so content integrity
is verified end-to-end even across wire transcoding.

Frame layout (little-endian)::

    [4B magic "RBP1"][1B type][4B body_len][8B payload_len]
    [4B adler32(payload)][body_len JSON bytes][payload_len bytes]

The JSON body is canonical (sorted keys, no whitespace) so a given request
or response has exactly one byte encoding — the property the golden
wire-frame test pins so the protocol cannot drift silently.

Frame types::

    REQ_CATALOG   {"path"}                               -> RESP_CATALOG
    REQ_READV     {"path", "generation", "baskets":      -> RESP_READV
                   [[branch, index], ...], "wire": null
                   | {"objective", "accept"}}
    REQ_PING      {}                                     -> RESP_PING
    REQ_STATS     {} | {"trace": true}                   -> RESP_STATS
    REQ_SCRUB     {"action": "status"}                   -> RESP_SCRUB
                  | {"action": "trigger"}
                  | {"action": "scrub", "path"?}
    REQ_PROF      {"action": "status"}                   -> RESP_PROF
                  | {"action": "start", "hz"?, "mem"?}
                  | {"action": "stop"}
                  | {"action": "fetch", "reset"?}
    RESP_ERROR    {"error"}   (any request may answer this)
    RESP_BUSY     {"error": "busy", "retry_after_s"}
                  (load shedding: the server's admission queue is
                  saturated; retry after the suggested delay)

``REQ_STATS`` is the observability verb (DESIGN.md §13): the server
answers with a generation-stamped canonical-JSON snapshot of its obs
registry plus the per-server ``stats`` dict — no path required, so a
monitor can point at a bare host:port.  ``"trace": true`` additionally
drains the server's span ring into ``"trace_events"``.

``REQ_PROF`` is the continuous-profiling control verb (DESIGN.md §17):
``start``/``stop`` manage the server's sampling profiler (``hz`` sets
the sample rate, ``mem`` arms memory watermarks), ``status`` reports it,
and ``fetch`` ships the collapsed-stack fold table (``reset: true``
drains it, so successive fetches cover disjoint windows) — the
``obstat --prof`` flamegraph capture path.

``REQ_SCRUB`` is the self-healing control verb (DESIGN.md §15):
``status`` snapshots the server's background scrubber, ``trigger`` wakes
it for an immediate sweep, and ``scrub`` runs one synchronous scrub of a
single container (or the whole export root) on the request thread —
the operator's "prove it is clean *now*" hook (``tools/bscrub.py``).

``REQ_READV`` is the vectored read: many (branch, basket) ranges per
round-trip.  The server coalesces them into large sequential ``pread``s
(:func:`coalesce`) and answers with one payload holding the concatenated
basket payloads plus per-basket metadata (possibly transcoded for the
wire) in the body.
"""

from __future__ import annotations

import json
import struct
from typing import Optional

from repro.core.checksum import adler32_hw

__all__ = [
    "MAGIC", "ProtocolError",
    "REQ_CATALOG", "REQ_READV", "REQ_PING", "REQ_STATS", "REQ_SCRUB",
    "REQ_PROF",
    "RESP_CATALOG", "RESP_READV", "RESP_PING", "RESP_STATS", "RESP_SCRUB",
    "RESP_PROF", "RESP_BUSY", "RESP_ERROR",
    "VERB_NAMES",
    "pack_frame", "read_frame", "recv_exact",
    "coalesce", "parse_url", "format_url",
]

MAGIC = b"RBP1"
_HEADER = struct.Struct("<4sBIQI")       # magic, type, body_len, payload_len, payload_sum

# request types
REQ_CATALOG = 1
REQ_READV = 2
REQ_PING = 3
REQ_STATS = 4
REQ_SCRUB = 5
REQ_PROF = 6
# response types
RESP_CATALOG = 16
RESP_READV = 17
RESP_PING = 18
RESP_STATS = 19
RESP_SCRUB = 20
RESP_PROF = 21
RESP_BUSY = 30
RESP_ERROR = 31

_TYPES = {REQ_CATALOG, REQ_READV, REQ_PING, REQ_STATS, REQ_SCRUB, REQ_PROF,
          RESP_CATALOG, RESP_READV, RESP_PING, RESP_STATS, RESP_SCRUB,
          RESP_PROF, RESP_BUSY, RESP_ERROR}

# human-readable verb names for metric labels and error log lines
VERB_NAMES = {REQ_CATALOG: "catalog", REQ_READV: "readv",
              REQ_PING: "ping", REQ_STATS: "stats", REQ_SCRUB: "scrub",
              REQ_PROF: "prof"}

# sanity bounds: a malformed header must fail fast, not allocate gigabytes
MAX_BODY = 64 << 20
MAX_PAYLOAD = 4 << 30


class ProtocolError(ValueError):
    """Malformed, truncated, or corrupted wire frame."""


def pack_frame(ftype: int, body: dict, payload: bytes = b"") -> bytes:
    """Encode one frame.  The body is canonical JSON (sorted keys, compact
    separators) so identical logical frames are identical bytes."""
    if ftype not in _TYPES:
        raise ProtocolError(f"unknown frame type {ftype}")
    bj = json.dumps(body, sort_keys=True, separators=(",", ":")).encode()
    head = _HEADER.pack(MAGIC, ftype, len(bj), len(payload),
                        adler32_hw(payload))
    return head + bj + bytes(payload)


def recv_exact(rfile, n: int) -> bytes:
    """Read exactly ``n`` bytes from a file-like socket reader; raises
    :class:`ProtocolError` on a short read (peer vanished mid-frame)."""
    chunks = []
    got = 0
    while got < n:
        b = rfile.read(n - got)
        if not b:
            raise ProtocolError(
                f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(b)
        got += len(b)
    return chunks[0] if len(chunks) == 1 else b"".join(chunks)


def read_frame(rfile) -> tuple[int, dict, bytes]:
    """Read one frame; returns ``(type, body, payload)``.

    Raises :class:`ProtocolError` for bad magic, unknown type, oversized
    lengths, truncation, undecodable body, or payload checksum mismatch —
    and ``EOFError`` for a clean end-of-stream (no bytes at all)."""
    head = rfile.read(_HEADER.size)
    if not head:
        raise EOFError("end of stream")
    while len(head) < _HEADER.size:
        # unbuffered readers (the hedging client's raw SocketIO) may
        # return a partial header on a segment boundary; loop, and treat
        # EOF mid-header as the truncation it is
        more = rfile.read(_HEADER.size - len(head))
        if not more:
            raise ProtocolError(f"truncated header ({len(head)} bytes)")
        head += more
    magic, ftype, body_len, payload_len, payload_sum = _HEADER.unpack(head)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r}")
    if ftype not in _TYPES:
        raise ProtocolError(f"unknown frame type {ftype}")
    if body_len > MAX_BODY or payload_len > MAX_PAYLOAD:
        raise ProtocolError(
            f"frame too large (body {body_len}, payload {payload_len})")
    try:
        body = json.loads(recv_exact(rfile, body_len)) if body_len else {}
    except (ValueError, UnicodeDecodeError) as e:
        raise ProtocolError(f"undecodable frame body: {e}") from None
    if not isinstance(body, dict):
        raise ProtocolError("frame body must be a JSON object")
    payload = recv_exact(rfile, payload_len) if payload_len else b""
    if adler32_hw(payload) != payload_sum:
        raise ProtocolError("payload checksum mismatch (corrupt frame)")
    return ftype, body, payload


# ---------------------------------------------------------------------------
# request coalescing
# ---------------------------------------------------------------------------

def coalesce(ranges, max_gap: int = 64 << 10,
             max_span: int = 8 << 20) -> list[tuple[int, int, list[int]]]:
    """Merge byte ranges into large sequential reads.

    ``ranges`` is a sequence of ``(offset, length)``; returns
    ``[(offset, length, member_indices), ...]`` sorted by offset, where
    each merged read covers every member range.  Two ranges merge when the
    gap between them is ≤ ``max_gap`` (reading a small hole sequentially
    beats a second seek/syscall) and the merged span stays ≤ ``max_span``
    (bounding per-read buffer memory).  Members keep their index into the
    input sequence so the caller can slice each basket back out.
    """
    order = sorted(range(len(ranges)), key=lambda i: (ranges[i][0], ranges[i][1]))
    out: list[tuple[int, int, list[int]]] = []
    for i in order:
        off, ln = int(ranges[i][0]), int(ranges[i][1])
        if out:
            c_off, c_len, members = out[-1]
            end = c_off + c_len
            if off - end <= max_gap and max(end, off + ln) - c_off <= max_span:
                out[-1] = (c_off, max(end, off + ln) - c_off, members + [i])
                continue
        out.append((off, ln, [i]))
    return out


# ---------------------------------------------------------------------------
# repro:// URLs
# ---------------------------------------------------------------------------

def parse_url(url: str) -> tuple[str, int, str]:
    """``repro://host:port/rel/path.bskt`` -> ``(host, port, "rel/path.bskt")``."""
    if not url.startswith("repro://"):
        raise ValueError(f"not a repro:// URL: {url!r}")
    rest = url[len("repro://"):]
    hostport, sep, path = rest.partition("/")
    host, _, port = hostport.rpartition(":")
    if not host or not port or not sep or not path:
        raise ValueError(f"malformed repro:// URL: {url!r} "
                         "(want repro://host:port/path)")
    return host, int(port), path


def format_url(host: str, port: int, path: str) -> str:
    return f"repro://{host}:{port}/{path.lstrip('/')}"
