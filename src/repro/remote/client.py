"""RemoteBasketFile — the networked mirror of ``BasketFile``'s read API.

Opens a ``repro://host:port/path`` URL, fetches the catalog (TOC + tuning
decisions + generation) once, and then serves ``read_branch`` /
``read_entries`` / ``read_basket_raw`` with the same semantics and the
same bytes as a local :class:`~repro.core.bfile.BasketFile` on the
server's copy.  The mechanics under the mirror:

* **vectored requests** — basket wants are batched (``batch_baskets`` per
  round-trip) so the server can coalesce them into sequential preads; a
  bulk branch read pipelines the next batch's request behind the current
  batch's response, hiding one link latency per batch;
* **wire negotiation** — ``wire="auto"`` asks the server to transcode
  archive-tier payloads into decode-cheap codecs when the declared
  ``objective`` says it pays (``repro.remote.transcode``); the basket's
  raw checksum is verified after decode, end-to-end across the transcode;
* **zero-copy decode** — wire payloads decode straight into the
  destination array slice (``unpack_basket_into``, the PR 3 plane);
* **tiered cache** — an optional :class:`~repro.remote.cache.TieredCache`
  keyed by (path, generation, branch, index) serves decoded re-reads from
  memory and cold re-opens from spilled wire payloads;
* **prefetch integration** — :meth:`submit_baskets` makes this object a
  valid source for :class:`repro.io.prefetch.PrefetchReader`.

Failure semantics (DESIGN.md §14): every socket operation carries the
per-request ``timeout`` and raises typed errors (``RemoteTimeout``,
``RemoteConnectError``, ...).  Transport failures are retried with
capped exponential backoff + jitter against an :class:`EndpointPool`
that round-robins replicas with health tracking — a dead endpoint is
cooled down and the read fails over to the next replica (whose catalog
is verified content-compatible before any basket is trusted).  READV
waits may be *hedged*: after a p99-derived delay a second replica gets
the same request and the first good frame wins, the loser is cancelled.
A basket that decodes but fails its content adler32 is quarantined and
re-fetched (preferring another replica); if every replica serves the
same damage a structured ``CorruptBasketError`` surfaces.  Server
application errors (missing branch, stale generation) are never retried.
All of it is counted: ``remote.retries{reason}``,
``remote.hedge{outcome}``.
"""

from __future__ import annotations

import base64
import queue
import random
import select
import socket
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Optional, Sequence, Union

import numpy as np

from repro import obs
from repro.core.basket import (BasketMeta, ChecksumError, byte_offsets,
                               join_baskets, unpack_basket,
                               unpack_basket_into)
from repro.core.bfile import CorruptBasketError

from . import protocol as P
from .cache import TieredCache, basket_key
from .errors import (RemoteConnectError, RemoteServerError, RemoteTimeout,
                     ReplicaMismatchError, ServerBusy, StaleGenerationError,
                     classify_error)
from .transcode import DEFAULT_ACCEPT

__all__ = ["RemoteBasketFile", "EndpointPool", "connect", "fetch_stats",
           "fetch_catalog", "request_scrub", "request_prof"]

# transport-level failures worth a retry (reads are idempotent); server
# application errors (RemoteServerError) deliberately excluded
_TRANSPORT = (RemoteTimeout, RemoteConnectError, ReplicaMismatchError,
              P.ProtocolError, EOFError, OSError)


def connect(url: str, **kw) -> "RemoteBasketFile":
    """Open a ``repro://host:port/path`` URL."""
    return RemoteBasketFile(url, **kw)


def fetch_stats(host: str, port: int, *, trace: bool = False,
                filter: Union[None, str, Sequence[str]] = None,
                heat: bool = False, profile: bool = False,
                timeout: float = 10.0) -> dict:
    """One STATS round-trip against a bare ``host:port`` — no catalog, no
    container path, so a monitor (``python -m repro.obs``) can poll any
    live server without knowing what it exports.

    ``filter`` is a metric-name prefix (or list of prefixes) applied
    server-side so a poller ships only the slice it renders; ``heat=True``
    also requests the server's access-heat snapshot; ``profile=True``
    requests the profiler's status + per-function self counts (the
    ``--watch`` profiler section — the full fold table ships over PROF).
    A bare poll (no kwargs) sends the same empty body as always."""
    conn = _Conn(host, int(port), timeout)
    try:
        body: dict = {}
        if trace:
            body["trace"] = True
        if filter is not None:
            body["filter"] = filter if isinstance(filter, str) \
                else list(filter)
        if heat:
            body["heat"] = True
        if profile:
            body["profile"] = True
        tp = obs.context.current_traceparent()
        if tp:
            body["tp"] = tp
        conn.send(P.pack_frame(P.REQ_STATS, body))
        ftype, rbody, _payload = conn.recv_frame()
        if ftype == P.RESP_ERROR:
            raise RemoteServerError(f"server error: {rbody.get('error')}")
        if ftype != P.RESP_STATS:
            raise P.ProtocolError(f"expected frame {P.RESP_STATS}, got {ftype}")
        return rbody
    finally:
        conn.close()


def _one_shot(host: str, port: int, req: int, body: dict, resp: int,
              timeout: float) -> dict:
    """One request/response round-trip on a throwaway connection."""
    conn = _Conn(host, int(port), timeout)
    try:
        tp = obs.context.current_traceparent()
        if tp and "tp" not in body:
            body = dict(body, tp=tp)
        conn.send(P.pack_frame(req, body))
        ftype, rbody, _payload = conn.recv_frame()
        if ftype == P.RESP_ERROR:
            raise RemoteServerError(f"server error: {rbody.get('error')}")
        if ftype != resp:
            raise P.ProtocolError(f"expected frame {resp}, got {ftype}")
        return rbody
    finally:
        conn.close()


def fetch_catalog(host: str, port: int, path: str, *,
                  timeout: float = 10.0) -> dict:
    """One CATALOG round-trip — the anti-entropy reconciler's diff input
    (per-basket checksums without opening a full RemoteBasketFile)."""
    return _one_shot(host, port, P.REQ_CATALOG, {"path": str(path)},
                     P.RESP_CATALOG, timeout)


def request_scrub(host: str, port: int, *, action: str = "status",
                  path: Optional[str] = None,
                  timeout: float = 300.0) -> dict:
    """One SCRUB round-trip: ``action`` is ``status`` / ``trigger`` /
    ``scrub`` (synchronous — size the timeout for a full verify pass of
    the target when scrubbing)."""
    body: dict = {"action": action}
    if path is not None:
        body["path"] = str(path)
    return _one_shot(host, port, P.REQ_SCRUB, body, P.RESP_SCRUB, timeout)


def request_prof(host: str, port: int, *, action: str = "status",
                 hz: Optional[float] = None, mem=False, reset: bool = False,
                 timeout: float = 30.0) -> dict:
    """One PROF round-trip: ``action`` is ``start`` (``hz`` sets the
    sample rate, ``mem`` arms memory watermarks) / ``stop`` / ``status``
    / ``fetch`` (``reset=True`` drains the server's fold table, so
    successive fetches cover disjoint windows).  A ``fetch`` returns the
    profile document under ``"profile"`` — feed it to
    :func:`repro.obs.profile.collapsed` / :func:`~repro.obs.profile.speedscope`."""
    body: dict = {"action": action}
    if hz is not None:
        body["hz"] = float(hz)
    if mem:
        body["mem"] = mem if isinstance(mem, str) else True
    if reset:
        body["reset"] = True
    return _one_shot(host, port, P.REQ_PROF, body, P.RESP_PROF, timeout)


def _as_endpoint(ep) -> tuple[str, int]:
    if isinstance(ep, str):
        host, _, port = ep.rpartition(":")
        if not host or not port:
            raise ValueError(f"endpoint {ep!r} is not host:port")
        return host, int(port)
    host, port = ep
    return str(host), int(port)


class EndpointPool:
    """Round-robin replica endpoints with health tracking.

    ``pick()`` rotates over endpoints currently believed healthy;
    ``report(ep, ok)`` feeds connect/request outcomes back.  A failing
    endpoint is cooled down (skipped) for ``cooldown`` seconds, doubling
    per consecutive failure up to 8×, so a dead replica costs one probe
    per cooldown window instead of one per request.  When *every*
    endpoint is down the least-recently-condemned one is returned anyway
    — the pool degrades to plain retry rather than deadlocking.  Health
    state is shared: one pool may serve many ``RemoteBasketFile``s."""

    def __init__(self, endpoints, cooldown: float = 2.0):
        eps = [_as_endpoint(e) for e in endpoints]
        if not eps:
            raise ValueError("EndpointPool needs at least one endpoint")
        self._eps = eps
        self.cooldown = float(cooldown)
        self._lock = threading.Lock()
        self._fails = {ep: 0 for ep in eps}
        self._down_until = {ep: 0.0 for ep in eps}
        self._i = 0

    def __len__(self) -> int:
        return len(self._eps)

    @property
    def endpoints(self) -> list[tuple[str, int]]:
        return list(self._eps)

    def pick(self, exclude=()) -> tuple[str, int]:
        exclude = set(exclude)
        now = time.monotonic()
        with self._lock:
            n = len(self._eps)
            order = [(self._i + k) % n for k in range(n)]
            usable = [j for j in order if self._eps[j] not in exclude]
            healthy = [j for j in usable if self._down_until[self._eps[j]] <= now]
            if healthy:
                j = healthy[0]
            elif usable:
                # everything (non-excluded) is down: probe the one whose
                # cooldown expires soonest — never deadlock
                j = min(usable, key=lambda k: self._down_until[self._eps[k]])
            else:
                j = order[0]
            self._i = (j + 1) % n
            return self._eps[j]

    def report(self, ep, ok: bool) -> None:
        ep = _as_endpoint(ep)
        with self._lock:
            if ep not in self._fails:
                return
            if ok:
                self._fails[ep] = 0
                self._down_until[ep] = 0.0
            else:
                self._fails[ep] += 1
                backoff = self.cooldown * min(2 ** (self._fails[ep] - 1), 8)
                self._down_until[ep] = time.monotonic() + backoff
            up = sum(1 for e in self._eps
                     if self._down_until[e] <= time.monotonic())
        obs.gauge("remote.endpoints_up").set(up)

    def healthy(self) -> list[tuple[str, int]]:
        now = time.monotonic()
        with self._lock:
            return [e for e in self._eps if self._down_until[e] <= now]


class _Conn:
    """One RBSP connection with socket-level deadlines.

    Unbuffered reader (``makefile(buffering=0)``): no userspace read-ahead,
    so ``select()`` on the raw socket is an exact "response pending" test —
    the property the hedging race depends on."""

    __slots__ = ("host", "port", "sock", "rfile")

    def __init__(self, host: str, port: int, timeout: Optional[float]):
        try:
            self.sock = socket.create_connection((host, int(port)),
                                                 timeout=timeout)
        except (socket.timeout, TimeoutError) as e:
            raise RemoteTimeout(
                f"connect to {host}:{port} timed out after {timeout}s") from e
        except OSError as e:
            raise RemoteConnectError(
                f"connect to {host}:{port} failed: {e}") from e
        self.sock.settimeout(timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.rfile = self.sock.makefile("rb", buffering=0)
        self.host, self.port = str(host), int(port)

    @property
    def ep(self) -> tuple[str, int]:
        return (self.host, self.port)

    def send(self, frame: bytes) -> None:
        try:
            self.sock.sendall(frame)
        except (socket.timeout, TimeoutError) as e:
            raise RemoteTimeout(
                f"send to {self.host}:{self.port} timed out") from e

    def recv_frame(self) -> tuple[int, dict, bytes]:
        try:
            return P.read_frame(self.rfile)
        except (socket.timeout, TimeoutError) as e:
            raise RemoteTimeout(
                f"recv from {self.host}:{self.port} timed out "
                f"(dead or stalled peer)") from e

    def close(self) -> None:
        for c in (self.rfile, self.sock):
            try:
                c.close()
            except OSError:
                pass


class RemoteBasketFile:
    """Read one served BasketFile over RBSP (see module docstring).

    ``wire``: ``"auto"`` negotiates transcoding under ``objective`` with
    the default accept list; ``None``/``False`` forces plain archive
    payloads; a sequence of codec names is an explicit accept list.

    Robustness knobs: ``endpoints`` lists replica ``host:port`` pairs (or
    an :class:`EndpointPool` shared across files); ``timeout`` bounds
    every connect/send/recv; ``retries`` caps consecutive fruitless
    transport retries (backoff ``backoff``·2ⁿ capped at ``backoff_max``,
    ±50 % jitter); ``busy_retries`` separately caps RESP_BUSY shed-retry
    loops (the server names its own retry-after); ``hedge`` enables
    hedged READV waits — ``"auto"`` derives the hedge delay from this
    client's observed p99 READV wait, a float pins it in seconds."""

    def __init__(self, url: Optional[str] = None, *, host: Optional[str] = None,
                 port: Optional[int] = None, path: Optional[str] = None,
                 endpoints=None,
                 wire="auto", objective: str = "max_read_tput",
                 accept: Optional[Sequence[str]] = None,
                 link_mbps: Optional[float] = None,
                 cache: Optional[TieredCache] = None,
                 batch_baskets: int = 32, verify: bool = True,
                 timeout: float = 30.0, retries: int = 3,
                 backoff: float = 0.05, backoff_max: float = 1.0,
                 busy_retries: int = 8,
                 hedge: Union[None, str, float] = None,
                 propagate: bool = True):
        if url is not None:
            host, port, path = P.parse_url(url)
        if endpoints is not None:
            pool = endpoints if isinstance(endpoints, EndpointPool) \
                else EndpointPool(endpoints)
            if host is None:
                host, port = pool.endpoints[0]
        else:
            if host is None or port is None:
                raise ValueError("need a repro:// url, host=/port=, "
                                 "or endpoints=")
            pool = EndpointPool([(host, port)])
        if path is None:
            raise ValueError("need a container path")
        self.host, self.port, self.path = host, int(port), str(path)
        self._pool = pool
        self.verify = verify
        self.batch_baskets = max(int(batch_baskets), 1)
        self.cache = cache
        self.timeout = float(timeout)
        self.retries = max(int(retries), 0)
        self.busy_retries = max(int(busy_retries), 0)
        self.backoff = float(backoff)
        self.backoff_max = float(backoff_max)
        self._hedge = hedge
        self.propagate = bool(propagate)
        self._rng = random.Random()
        self._rtts: deque = deque(maxlen=128)   # READV wait samples (s)
        if wire is None or wire is False:
            self._wire = None
        else:
            if accept is not None:
                acc = list(accept)
            elif isinstance(wire, str) and wire != "auto":
                acc = [wire]                       # wire="lz4" etc.
            elif not isinstance(wire, (str, bool)):
                acc = list(wire)                   # explicit accept list
            else:
                acc = list(DEFAULT_ACCEPT)
            self._wire = {"objective": objective, "accept": acc}
            if link_mbps is not None:
                # declared link speed shifts the server's transcode optimum
                # (identity on fast links, real codecs as bytes get dear)
                self._wire["link_mbps"] = float(link_mbps)
        self._io_lock = threading.Lock()    # serializes the socket
        self._fetch_lock = threading.Lock()  # lazy fetcher-thread init
        self._conn: Optional[_Conn] = None
        self._gen_by_ep: dict[tuple[str, int], tuple] = {}
        self.branches: Optional[dict] = None
        self._closed = False
        # background fetcher (lazy): serves submit_baskets waves
        self._fetchq: Optional[queue.Queue] = None
        self._fetcher: Optional[threading.Thread] = None
        try:
            # the opening catalog fetch retries across the pool, so one
            # dead replica does not fail the open
            self._with_retry(self._locked_ensure)
        except BaseException:
            # a failed open must not leak the connected socket (probing
            # loops over shard URLs would leak one fd per missing file)
            self._hard_close_conn()
            raise
        # cache namespace: the endpoint qualifies the path — two servers
        # exporting same-named files (whose inodes can collide across
        # hosts) must never share entries in a shared TieredCache
        self._cache_ns = f"{self.host}:{self.port}/{self.path}"

    # -- BasketFile API mirror ------------------------------------------

    def branch_names(self) -> list[str]:
        return list(self.branches)

    def tuning_decisions(self) -> dict[str, dict]:
        return dict(self.tuning)

    def _dictionary(self, entry: dict) -> Optional[bytes]:
        d = entry.get("dictionary")
        return base64.b64decode(d) if d else None

    def compressed_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["comp_len"]
                   for n in names for b in self.branches[n]["baskets"])

    def raw_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["orig_len"]
                   for n in names for b in self.branches[n]["baskets"])

    # -- connection management ------------------------------------------

    def _locked_ensure(self):
        with self._io_lock:
            return self._ensure_conn()

    def _ensure_conn(self) -> _Conn:
        """The live primary connection, establishing (and adopting the
        endpoint's catalog generation) if needed.  Call under _io_lock."""
        if self._conn is not None:
            return self._conn
        ep = self._pool.pick()
        try:
            conn = _Conn(ep[0], ep[1], self.timeout)
        except (RemoteTimeout, RemoteConnectError):
            self._pool.report(ep, False)
            raise
        try:
            gen = self._adopt_ep(conn)
        except RemoteServerError:
            conn.close()
            raise                      # app error: the endpoint is healthy
        except BaseException:
            conn.close()
            self._pool.report(ep, False)
            raise
        self._pool.report(ep, True)
        self.generation = gen          # the primary endpoint's generation
        self._conn = conn
        return conn

    def _adopt_ep(self, conn: _Conn) -> tuple:
        """The catalog generation for ``conn``'s endpoint — fetched and
        content-verified on first contact, cached after.  Failing over to
        (or hedging against) a replica that serves *different* content
        under the same path raises :class:`ReplicaMismatchError` instead
        of silently mixing files."""
        gen = self._gen_by_ep.get(conn.ep)
        if gen is not None:
            return gen
        conn.send(P.pack_frame(P.REQ_CATALOG, {"path": self.path}))
        body, _ = self._recv_on(conn, P.RESP_CATALOG)
        gen = tuple(body["generation"])
        if self.branches is None:
            # first catalog: adopt as this reader's canonical TOC
            order = body.get("order") or list(body["branches"])
            self.branches = {n: body["branches"][n] for n in order}
            self.tuning = body.get("tuning", {})
            self.server_transcode = bool(body.get("transcode", False))
        else:
            self._check_compat(conn.ep, body)
        self._gen_by_ep[conn.ep] = gen
        return gen

    def _check_compat(self, ep, body: dict) -> None:
        """Replicas must agree on *content*: same branches, same basket
        row ranges, same raw lengths and checksums.  Offsets and wire
        compression may differ (a replica may be repacked)."""
        bs = body.get("branches") or {}
        if set(bs) != set(self.branches):
            raise ReplicaMismatchError(
                f"replica {ep[0]}:{ep[1]} serves different branches for "
                f"{self.path!r}")
        for n, e in self.branches.items():
            o = bs[n]
            if (o.get("dtype") != e["dtype"]
                    or list(o.get("shape") or []) != list(e["shape"])
                    or len(o.get("baskets") or []) != len(e["baskets"])):
                raise ReplicaMismatchError(
                    f"replica {ep[0]}:{ep[1]} branch {n!r} layout differs")
            for a, b in zip(e["baskets"], o["baskets"]):
                am, bm = a["meta"], b["meta"]
                if (am["orig_len"], am["checksum"], am["entry_start"]) != \
                        (bm["orig_len"], bm["checksum"], bm["entry_start"]):
                    raise ReplicaMismatchError(
                        f"replica {ep[0]}:{ep[1]} branch {n!r} content "
                        "differs (checksum mismatch)")

    def _drop_conn(self, report: bool = True) -> None:
        with self._io_lock:
            conn, self._conn = self._conn, None
        if conn is not None:
            if report:
                self._pool.report(conn.ep, False)
            conn.close()

    def _hard_close_conn(self) -> None:
        conn, self._conn = self._conn, None
        if conn is not None:
            conn.close()

    # -- retry machinery -------------------------------------------------

    def _count_retry(self, reason: str) -> None:
        obs.counter("remote.retries", reason=reason).inc()

    def _sleep_backoff(self, attempt: int, delay: Optional[float] = None) -> None:
        d = delay if delay is not None \
            else min(self.backoff * (2 ** attempt), self.backoff_max)
        time.sleep(max(d, 0.001) * (0.5 + self._rng.random()))

    def _with_retry(self, op):
        """Run ``op`` (which uses the connection under _io_lock), retrying
        transport failures with backoff+jitter against the pool and
        RESP_BUSY sheds on the server's own schedule.  Application errors
        surface immediately."""
        attempt = busy = 0
        while True:
            try:
                return op()
            except ServerBusy as e:
                # the frame was consumed; the connection is still in sync
                if busy >= self.busy_retries:
                    raise
                busy += 1
                self._count_retry("busy")
                self._sleep_backoff(0, min(e.retry_after, 1.0))
            except RemoteServerError:
                raise
            except _TRANSPORT as e:
                self._drop_conn()
                if attempt >= self.retries:
                    raise
                self._count_retry(classify_error(e))
                self._sleep_backoff(attempt)
                attempt += 1

    # -- wire ------------------------------------------------------------

    def _send_on(self, conn: _Conn, ftype: int, body: dict) -> None:
        frame = P.pack_frame(ftype, body)
        obs.counter("rbsp.tx_bytes").inc(len(frame))
        conn.send(frame)

    def _recv_on(self, conn: _Conn, want: int) -> tuple[dict, bytes]:
        ftype, body, payload = conn.recv_frame()
        obs.counter("rbsp.rx_payload_bytes").inc(len(payload))
        if ftype == P.RESP_BUSY:
            raise ServerBusy(
                f"server busy: {body.get('error', 'shed')}",
                retry_after=float(body.get("retry_after_s", 0.05)))
        if ftype == P.RESP_ERROR:
            msg = f"server error: {body.get('error')}"
            if "stale generation" in str(body.get("error", "")):
                raise StaleGenerationError(msg)
            raise RemoteServerError(msg)
        if ftype != want:
            raise P.ProtocolError(f"expected frame {want}, got {ftype}")
        return body, payload

    def _request(self, ftype: int, body: dict, want: Optional[int] = None
                 ) -> tuple[dict, bytes]:
        if want is None:
            want = {P.REQ_CATALOG: P.RESP_CATALOG, P.REQ_READV: P.RESP_READV,
                    P.REQ_PING: P.RESP_PING,
                    P.REQ_STATS: P.RESP_STATS}[ftype]
        verb = P.VERB_NAMES.get(ftype, str(ftype))

        def op():
            t0 = time.perf_counter()
            # root=propagate: the request span minted here is the parent
            # the server adopts from the body's "tp" (DESIGN.md §16)
            with obs.trace.span("rbsp.request", cat="client", verb=verb,
                                root=self.propagate):
                sbody = body
                tp = obs.context.current_traceparent() if self.propagate \
                    else None
                if tp:
                    sbody = dict(body, tp=tp)
                with self._io_lock:
                    conn = self._ensure_conn()
                    self._send_on(conn, ftype, sbody)
                    out = self._recv_on(conn, want)
            obs.histogram("rbsp.rtt_s", verb=verb).observe(
                time.perf_counter() - t0)
            return out

        return self._with_retry(op)

    def ping(self) -> bool:
        return bool(self._request(P.REQ_PING, {})[0].get("ok"))

    def server_stats(self, trace: bool = False,
                     filter: Union[None, str, Sequence[str]] = None,
                     heat: bool = False) -> dict:
        """The server's STATS snapshot over this connection (DESIGN.md
        §13): generation-stamped obs registry + server stats dict;
        ``trace=True`` also drains the server's span ring, ``filter``
        restricts metrics to a name prefix (or prefixes), ``heat=True``
        includes the access-heat snapshot."""
        body: dict = {}
        if trace:
            body["trace"] = True
        if filter is not None:
            body["filter"] = filter if isinstance(filter, str) \
                else list(filter)
        if heat:
            body["heat"] = True
        return self._request(P.REQ_STATS, body)[0]

    def _readv_body(self, name: str, idxs: Sequence[int], gen) -> dict:
        body = {"path": self.path, "generation": list(gen),
                "baskets": [[name, int(i)] for i in idxs],
                "wire": self._wire}
        if self.propagate:
            tp = obs.context.current_traceparent()
            if tp:
                body["tp"] = tp
        return body

    def _split_response(self, body: dict, payload: bytes
                        ) -> list[tuple[bytes, dict]]:
        out, pos = [], 0
        for b in body["baskets"]:
            ln = int(b["len"])
            if pos + ln > len(payload):
                raise P.ProtocolError("response payload shorter than "
                                      "declared basket lengths")
            out.append((payload[pos:pos + ln], b["meta"]))
            pos += ln
        if pos != len(payload):
            raise P.ProtocolError("response payload longer than declared "
                                  "basket lengths")
        return out

    def _resync(self, conn: _Conn, inflight: int) -> None:
        """Drain responses for requests already on the wire after one of
        them failed — a pipelined connection must never be left a response
        behind (the next caller would read an orphaned RESP_READV as its
        own and silently scatter the wrong baskets).  If draining itself
        fails the stream state is unknowable: drop the connection so the
        next use reconnects cleanly."""
        try:
            for _ in range(inflight):
                conn.recv_frame()
        except Exception:
            conn.close()
            if self._conn is conn:
                self._conn = None

    # -- hedged READV ----------------------------------------------------

    def _hedge_delay(self) -> Optional[float]:
        h = self._hedge
        if not h:
            return None
        if h == "auto":
            if len(self._rtts) < 16:
                return None            # not enough signal yet
            s = sorted(self._rtts)
            return max(0.001, s[min(len(s) - 1, int(0.99 * len(s)))])
        return float(h)

    def _race_hedge(self, conn: _Conn, name: str, group: Sequence[int]):
        """The primary READV wait exceeded the hedge delay: fire the same
        request at a second replica (preferring a different endpoint) and
        race the two sockets; first good frame wins, the loser is closed.
        Returns ``(body, payload, primary_won)`` or ``None`` when the
        hedge could not be launched (caller falls back to the primary)."""
        ep = self._pool.pick(exclude={conn.ep})
        try:
            h = _Conn(ep[0], ep[1], self.timeout)
        except (RemoteTimeout, RemoteConnectError):
            self._pool.report(ep, False)
            obs.counter("remote.hedge", outcome="error").inc()
            return None
        try:
            hgen = self._adopt_ep(h)
            self._send_on(h, P.REQ_READV, self._readv_body(name, group, hgen))
        except BaseException:
            h.close()
            obs.counter("remote.hedge", outcome="error").inc()
            return None
        obs.counter("remote.hedge", outcome="fired").inc()
        deadline = time.monotonic() + self.timeout
        try:
            while True:
                remain = deadline - time.monotonic()
                if remain <= 0:
                    raise RemoteTimeout(
                        f"hedged readv wait exceeded {self.timeout}s")
                r, _, _ = select.select([conn.sock, h.sock], [], [], remain)
                if conn.sock in r:
                    # primary answered first: cancel the hedge (loser's
                    # response dies with its one-shot connection)
                    h.close()
                    obs.counter("remote.hedge", outcome="lose").inc()
                    return (*self._recv_on(conn, P.RESP_READV), True)
                if h.sock in r:
                    try:
                        body, payload = self._recv_on(h, P.RESP_READV)
                    except Exception:
                        h.close()
                        obs.counter("remote.hedge", outcome="error").inc()
                        return None    # bad hedge: wait out the primary
                    h.close()
                    obs.counter("remote.hedge", outcome="win").inc()
                    return body, payload, False
        except BaseException:
            h.close()
            raise

    def _recv_readv(self, conn: _Conn, name: str, group: Sequence[int]):
        """One READV response, hedged when configured.  Returns
        ``(body, payload, primary_won)``; ``primary_won=False`` means the
        caller must retire the primary connection (its response for this
        group is orphaned in flight)."""
        delay = self._hedge_delay()
        if delay is not None:
            r, _, _ = select.select([conn.sock], [], [], delay)
            if not r:
                res = self._race_hedge(conn, name, group)
                if res is not None:
                    return res
        return (*self._recv_on(conn, P.RESP_READV), True)

    # -- vectored fetch --------------------------------------------------

    def fetch_wire(self, name: str, idxs: Sequence[int],
                   on_batch=None) -> list[tuple[bytes, dict]]:
        """Fetch wire ``(payload, meta_json)`` pairs for baskets ``idxs``
        of branch ``name`` — batched into vectored requests, each batch's
        request pipelined behind the previous batch's response.

        ``on_batch(batch_idxs, pairs)`` streams each batch to the caller
        as its response lands (decode overlaps the next batch's transfer
        and only one batch of wire bytes is ever held); without it the
        pairs for all ``idxs`` are returned as one list.  Batches may be
        re-ordered across retries/shed-redos — ``on_batch`` consumers
        must scatter by index, which every in-tree consumer does.

        Transport failures retry with backoff against the pool, resuming
        from the first undelivered batch; RESP_BUSY sheds re-queue just
        the shed batches after the server's retry-after; a hedge win
        rotates the connection and continues without burning a retry."""
        idxs = list(idxs)
        if not idxs:
            return []
        out: dict[int, tuple[bytes, dict]] = {}

        def deliver(bidxs, pairs):
            if self.cache is not None:
                # async spill: the background writer does the file I/O —
                # a slow disk must not stall the pipeline
                for i, (p, m) in zip(bidxs, pairs):
                    self.cache.put_wire_async(self._key(name, i), p, m)
            if on_batch is not None:
                on_batch(bidxs, pairs)
            else:
                for i, pr in zip(bidxs, pairs):
                    out[i] = pr

        wait_h = obs.histogram("rbsp.readv_wait_s")
        pending = idxs
        attempt = busy_attempt = 0
        # root=propagate: every READV sent below (pipelined rounds and
        # hedges alike) carries this span's id as "tp", so server-side
        # readv/pread spans hang off one client fetch span per call
        with obs.trace.span("rbsp.fetch_wire", cat="client", branch=name,
                            baskets=len(idxs), root=self.propagate):
            while pending:
                done: list[int] = []
                busy: list[int] = []
                busy_delay = 0.0
                hedge_rotate = False
                err: Optional[BaseException] = None
                try:
                    with self._io_lock:
                        hedge_rotate = self._fetch_round(
                            name, pending, deliver, wait_h, done, busy,
                            lambda d: None)
                        busy_delay = self._last_busy_delay
                except RemoteServerError:
                    raise            # app error (already resynced)
                except ServerBusy as e:
                    # shed during (re)connect adoption — connection gone
                    err = e
                    busy_delay = e.retry_after
                except _TRANSPORT as e:
                    err = e
                    self._drop_conn()
                delivered = set(done)
                pending = [i for i in pending if i not in delivered]
                if err is not None:
                    if isinstance(err, ServerBusy):
                        if busy_attempt >= self.busy_retries:
                            raise err
                        busy_attempt += 1
                        self._count_retry("busy")
                        self._sleep_backoff(0, min(busy_delay, 1.0))
                        continue
                    if done:
                        attempt = 0  # progress resets the fruitless count
                    if attempt >= self.retries:
                        raise err
                    self._count_retry(classify_error(err))
                    self._sleep_backoff(attempt)
                    attempt += 1
                    continue
                if hedge_rotate:
                    # hedge won: the primary has an orphaned response in
                    # flight — rotate connections, keep going (progress
                    # was made; this is not a failure)
                    self._drop_conn(report=False)
                    continue
                if busy:
                    if busy_attempt >= self.busy_retries:
                        raise ServerBusy(
                            "server busy (shed retries exhausted)",
                            retry_after=busy_delay)
                    busy_attempt += 1
                    self._count_retry("busy")
                    self._sleep_backoff(0, min(max(busy_delay, 0.005), 1.0))
                    pending = busy
                    continue
                break
        if on_batch is None:
            return [out[i] for i in idxs]
        return []

    def _fetch_round(self, name, todo, deliver, wait_h, done, busy,
                     _unused) -> bool:
        """One pipelined pass over ``todo`` on the primary connection.
        Appends delivered idxs to ``done`` and shed idxs to ``busy`` (so
        the caller knows the exact frontier even when this raises mid-
        round).  Returns True when a hedge win means the caller must
        rotate the connection.  Call under _io_lock."""
        self._last_busy_delay = 0.0
        conn = self._ensure_conn()
        gen = self._gen_by_ep[conn.ep]
        groups = [todo[i:i + self.batch_baskets]
                  for i in range(0, len(todo), self.batch_baskets)]
        # pipeline: request g+1 is on the wire while we block on g's
        # response — the server answers a connection's requests in
        # order, so responses arrive in group order
        sent = consumed = 0
        self._send_on(conn, P.REQ_READV, self._readv_body(name, groups[0], gen))
        sent += 1
        for g in range(len(groups)):
            if g + 1 < len(groups):
                self._send_on(conn, P.REQ_READV,
                              self._readv_body(name, groups[g + 1], gen))
                sent += 1
            t0 = time.perf_counter()
            try:
                body, payload, primary = self._recv_readv(
                    conn, name, groups[g])
            except ServerBusy as e:
                # this group was shed at admission; later pipelined groups
                # get their own answers — keep consuming them
                consumed += 1
                busy.extend(groups[g])
                self._last_busy_delay = max(self._last_busy_delay,
                                            e.retry_after)
                continue
            except RemoteServerError:
                consumed += 1
                self._resync(conn, sent - consumed)
                raise
            if primary:
                consumed += 1
                dt = time.perf_counter() - t0
                wait_h.observe(dt)
                self._rtts.append(dt)
            pairs = self._split_response(body, payload)
            deliver(groups[g], pairs)
            done.extend(groups[g])
            if not primary:
                return True          # hedge won: rotate the connection
        return False

    # -- decode ----------------------------------------------------------

    def _key(self, name: str, i: int) -> tuple:
        return basket_key(self._cache_ns, self.generation, name, i)

    def _decode(self, name: str, payload, meta_json: dict,
                verify: Optional[bool] = None) -> bytes:
        entry = self.branches[name]
        meta = BasketMeta.from_json(meta_json)
        d = self._dictionary(entry) if meta.has_dict else None
        return unpack_basket(bytes(payload), meta, d,
                             verify=self.verify if verify is None else verify)

    def _decode_into(self, name: str, payload, meta_json: dict, out) -> int:
        entry = self.branches[name]
        meta = BasketMeta.from_json(meta_json)
        d = self._dictionary(entry) if meta.has_dict else None
        return unpack_basket_into(payload, meta, out, d, verify=self.verify)

    # -- corrupt-basket quarantine ---------------------------------------

    def _refetch_raw(self, name: str, i: int,
                     verify: Optional[bool] = None) -> bytes:
        """A basket decoded but failed its content adler32: drop any
        cached copy, rotate to another replica, and re-fetch until a copy
        verifies.  If every attempt serves the same damage, raise the
        structured :class:`CorruptBasketError` naming branch/index/offset."""
        last: Optional[BaseException] = None
        for _ in range(max(2, len(self._pool))):
            self._count_retry("corrupt")
            if self.cache is not None:
                self.cache.drop(self._key(name, i))
            # prefer a different replica for the refetch: round-robin
            # rotates on reconnect
            self._drop_conn(report=False)
            try:
                (p, m), = self.fetch_wire(name, [i])
                raw = self._decode(name, p, m, True)
            except ChecksumError as e:
                last = e
                continue
            except _TRANSPORT as e:
                last = e
                continue
            if self.cache is not None:
                self.cache.put_decoded(self._key(name, i), raw)
            return raw
        b = self.branches[name]["baskets"][i]
        raise CorruptBasketError(self._cache_ns, name, i,
                                 int(b.get("offset", -1)), cause=last)

    def read_basket_raw(self, name: str, i: int) -> bytes:
        """Decoded raw bytes of one basket (cache-aware, quarantining)."""
        if self.cache is not None:
            raw = self.cache.get_decoded(self._key(name, i))
            if raw is not None:
                return raw
            w = self.cache.get_wire(self._key(name, i))
            if w is not None:
                try:
                    raw = self._decode(name, *w)
                except ChecksumError:
                    return self._refetch_raw(name, i)
                self.cache.put_decoded(self._key(name, i), raw)
                return raw
            self.cache.record_miss()
        (p, m), = self.fetch_wire(name, [i])
        try:
            raw = self._decode(name, p, m)
        except ChecksumError:
            return self._refetch_raw(name, i)
        if self.cache is not None:
            self.cache.put_decoded(self._key(name, i), raw)
        return raw

    def read_basket_into(self, name: str, i: int, out) -> int:
        """Fetch + decode basket ``i`` directly into ``out``."""
        if self.cache is not None:
            raw = self.cache.get_decoded(self._key(name, i))
            if raw is None:
                w = self.cache.get_wire(self._key(name, i))
                if w is not None:
                    try:
                        return self._decode_into(name, w[0], w[1], out)
                    except ChecksumError:
                        raw = self._refetch_raw(name, i)
                else:
                    self.cache.record_miss()
            if raw is not None:
                b = np.frombuffer(raw, dtype=np.uint8)
                np.asarray(out).reshape(-1).view(np.uint8)[:b.size] = b
                return b.size
        (p, m), = self.fetch_wire(name, [i])
        try:
            return self._decode_into(name, p, m, out)
        except ChecksumError:
            raw = self._refetch_raw(name, i)
            b = np.frombuffer(raw, dtype=np.uint8)
            np.asarray(out).reshape(-1).view(np.uint8)[:b.size] = b
            return b.size

    # -- bulk reads ------------------------------------------------------

    def _classify(self, name: str, idxs: Sequence[int]):
        """Partition indices into (decoded-hit, wire-hit, fetch) against
        the cache; returns (decoded {i: raw}, wires {i: (payload, meta)},
        missing [i])."""
        decoded, wires, missing = {}, {}, []
        if self.cache is None:
            return decoded, wires, list(idxs)
        for i in idxs:
            k = self._key(name, i)
            raw = self.cache.get_decoded(k)
            if raw is not None:
                decoded[i] = raw
                continue
            w = self.cache.get_wire(k)
            if w is not None:
                wires[i] = w
            else:
                self.cache.record_miss()
                missing.append(i)
        return decoded, wires, missing

    def read_branch(self, name: str, workers: Optional[int] = None) -> np.ndarray:
        """Whole-branch read, byte-identical to the local
        ``BasketFile.read_branch`` of the served file.  The destination is
        allocated once; cached decoded baskets scatter-copy, everything
        else decodes wire payloads straight into its slice.  Baskets that
        fail their content checksum are re-fetched (another replica when
        available) after the bulk fetch completes."""
        entry = self.branches[name]
        n = len(entry["baskets"])
        out = np.empty(tuple(entry["shape"]), dtype=np.dtype(entry["dtype"]))
        offs, total = byte_offsets(b["meta"]["orig_len"]
                                   for b in entry["baskets"])
        if total != out.nbytes:     # malformed TOC: copying fallback
            chunks = [self.read_basket_raw(name, i) for i in range(n)]
            return join_baskets(chunks, entry["dtype"], tuple(entry["shape"]))
        flat = out.reshape(-1).view(np.uint8)
        lens = [b["meta"]["orig_len"] for b in entry["baskets"]]
        # populate the decoded tier only when the whole branch fits in half
        # the memory budget — a bulk scan of a huge branch must not cycle
        # the LRU (the TTreeCache scan-pollution rule read_all follows too)
        keep = self.cache is not None and self.cache.mem_bytes \
            and total <= self.cache.mem_bytes // 2
        decoded, wires, missing = self._classify(name, range(n))
        for i, raw in decoded.items():
            flat[offs[i]:offs[i] + lens[i]] = np.frombuffer(raw, np.uint8)
        corrupt: list[int] = []

        def _land(i: int, p, m) -> None:
            try:
                self._decode_into(name, p, m, flat[offs[i]:offs[i] + lens[i]])
            except ChecksumError:
                # collected, not refetched inline: this runs inside the
                # fetch pipeline's lock — refetching here would deadlock
                corrupt.append(i)
                return
            if keep:
                self.cache.put_decoded(
                    self._key(name, i), bytes(flat[offs[i]:offs[i] + lens[i]]))

        for i, (p, m) in wires.items():
            _land(i, p, m)
        if missing:
            # streamed: each batch decodes into its slices as its response
            # lands — decode overlaps the next batch's transfer, and only
            # one batch of wire payloads is ever held in memory
            self.fetch_wire(name, missing, on_batch=lambda bidxs, pairs: [
                _land(i, p, m) for i, (p, m) in zip(bidxs, pairs)])
        for i in corrupt:
            raw = self._refetch_raw(name, i)
            flat[offs[i]:offs[i] + lens[i]] = np.frombuffer(raw, np.uint8)
        return out

    def read_entries(self, name: str, start: int, stop: int) -> np.ndarray:
        """Row-range read touching only the covering baskets."""
        entry = self.branches[name]
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"])
        cover, first_entry, total = [], None, 0
        for i, b in enumerate(entry["baskets"]):
            m = b["meta"]
            if m["entry_start"] + m["entry_count"] <= start \
                    or m["entry_start"] >= stop:
                continue
            if first_entry is None:
                first_entry = m["entry_start"]
            cover.append((i, total, m["orig_len"]))
            total += m["orig_len"]
        if not cover:
            return np.zeros((0,) + shape[1:], dtype=dtype)
        row_elems = int(np.prod(shape[1:], dtype=np.int64)) or 1
        rows = total // (dtype.itemsize * row_elems)
        arr = np.empty((rows,) + shape[1:], dtype=dtype)
        flat = arr.reshape(-1).view(np.uint8)
        idxs = [i for i, _o, _l in cover]
        decoded, wires, missing = self._classify(name, idxs)
        fetched = dict(zip(missing, self.fetch_wire(name, missing))) \
            if missing else {}
        for i, off, ln in cover:
            if i in decoded:
                flat[off:off + ln] = np.frombuffer(decoded[i], np.uint8)
            else:
                p, m = wires[i] if i in wires else fetched[i]
                try:
                    self._decode_into(name, p, m, flat[off:off + ln])
                except ChecksumError:
                    raw = self._refetch_raw(name, i)
                    flat[off:off + ln] = np.frombuffer(raw, np.uint8)
        return arr[start - first_entry: stop - first_entry].copy()

    # -- PrefetchReader source hook --------------------------------------

    def submit_baskets(self, name: str, idxs: Sequence[int],
                       verify: Optional[bool] = None) -> list[Future]:
        """Schedule decoded-bytes futures for baskets ``idxs`` — the
        remote-source hook ``PrefetchReader`` batches its read-ahead
        through.  Each call is one wave: a background fetch thread turns
        it into one vectored request (cache-aware), so waves queued while
        a fetch is in flight ride the connection back-to-back.  ``verify``
        overrides this file's checksum setting for the wave (the reader's
        own ``verify=`` knob)."""
        futs = [Future() for _ in idxs]
        if idxs:
            self._fetch_queue().put((name, list(idxs), futs, verify))
        return futs

    def _fetch_queue(self) -> queue.Queue:
        with self._fetch_lock:
            if self._fetchq is None:
                self._fetchq = queue.Queue()
                self._fetcher = threading.Thread(
                    target=self._fetch_loop, daemon=True,
                    name="repro-remote-fetch")
                self._fetcher.start()
            return self._fetchq

    def _fetch_loop(self) -> None:
        while True:
            item = self._fetchq.get()
            if item is None:
                return
            name, idxs, futs, verify = item
            fut_of = dict(zip(idxs, futs))
            corrupt: list[int] = []

            def _deliver(i: int, payload, meta_json) -> None:
                try:
                    raw = self._decode(name, payload, meta_json, verify)
                except ChecksumError:
                    corrupt.append(i)   # refetched after the wave lands
                    return
                if self.cache is not None:
                    self.cache.put_decoded(self._key(name, i), raw)
                fut_of[i].set_result(raw)

            try:
                decoded, wires, missing = self._classify(name, idxs)
                for i, raw in decoded.items():
                    fut_of[i].set_result(raw)
                for i, (p, m) in wires.items():
                    _deliver(i, p, m)
                if missing:
                    # streamed: each batch's futures resolve as its
                    # response lands, so a whole-branch wave never holds
                    # more than one batch of wire payloads (the consumer
                    # scatters resolved baskets while later batches are
                    # still in flight)
                    self.fetch_wire(name, missing,
                                    on_batch=lambda bidxs, pairs: [
                                        _deliver(i, p, m)
                                        for i, (p, m) in zip(bidxs, pairs)])
                for i in corrupt:
                    fut_of[i].set_result(self._refetch_raw(name, i, verify))
            except BaseException as e:
                for fut in futs:
                    if not fut.done():
                        fut.set_exception(e)

    # -- lifecycle -------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._fetchq is not None:
            self._fetchq.put(None)
            self._fetcher.join(timeout=5)
        # close without taking _io_lock: a holder blocked in a dead recv
        # gets its socket yanked (failing fast) instead of us deadlocking
        self._hard_close_conn()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
