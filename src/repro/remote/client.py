"""RemoteBasketFile — the networked mirror of ``BasketFile``'s read API.

Opens a ``repro://host:port/path`` URL, fetches the catalog (TOC + tuning
decisions + generation) once, and then serves ``read_branch`` /
``read_entries`` / ``read_basket_raw`` with the same semantics and the
same bytes as a local :class:`~repro.core.bfile.BasketFile` on the
server's copy.  The mechanics under the mirror:

* **vectored requests** — basket wants are batched (``batch_baskets`` per
  round-trip) so the server can coalesce them into sequential preads; a
  bulk branch read pipelines the next batch's request behind the current
  batch's response, hiding one link latency per batch;
* **wire negotiation** — ``wire="auto"`` asks the server to transcode
  archive-tier payloads into decode-cheap codecs when the declared
  ``objective`` says it pays (``repro.remote.transcode``); the basket's
  raw checksum is verified after decode, end-to-end across the transcode;
* **zero-copy decode** — wire payloads decode straight into the
  destination array slice (``unpack_basket_into``, the PR 3 plane);
* **tiered cache** — an optional :class:`~repro.remote.cache.TieredCache`
  keyed by (path, generation, branch, index) serves decoded re-reads from
  memory and cold re-opens from spilled wire payloads;
* **prefetch integration** — :meth:`submit_baskets` makes this object a
  valid source for :class:`repro.io.prefetch.PrefetchReader`: scheduled
  indices are fetched by a background thread as ONE vectored request per
  wave, which is how the data pipeline overlaps remote fetch with
  compute.
"""

from __future__ import annotations

import base64
import queue
import socket
import threading
import time
from concurrent.futures import Future
from typing import Optional, Sequence

import numpy as np

from repro import obs
from repro.core.basket import (BasketMeta, byte_offsets, join_baskets,
                               unpack_basket, unpack_basket_into)

from . import protocol as P
from .cache import TieredCache, basket_key
from .transcode import DEFAULT_ACCEPT

__all__ = ["RemoteBasketFile", "connect", "fetch_stats"]


def connect(url: str, **kw) -> "RemoteBasketFile":
    """Open a ``repro://host:port/path`` URL."""
    return RemoteBasketFile(url, **kw)


def fetch_stats(host: str, port: int, *, trace: bool = False,
                timeout: float = 10.0) -> dict:
    """One STATS round-trip against a bare ``host:port`` — no catalog, no
    container path, so a monitor (``python -m repro.obs``) can poll any
    live server without knowing what it exports."""
    sock = socket.create_connection((host, int(port)), timeout=timeout)
    try:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        rfile = sock.makefile("rb")
        body = {"trace": True} if trace else {}
        sock.sendall(P.pack_frame(P.REQ_STATS, body))
        ftype, rbody, _payload = P.read_frame(rfile)
        if ftype == P.RESP_ERROR:
            raise RuntimeError(f"server error: {rbody.get('error')}")
        if ftype != P.RESP_STATS:
            raise P.ProtocolError(f"expected frame {P.RESP_STATS}, got {ftype}")
        return rbody
    finally:
        sock.close()


class RemoteBasketFile:
    """Read one served BasketFile over RBSP (see module docstring).

    ``wire``: ``"auto"`` negotiates transcoding under ``objective`` with
    the default accept list; ``None``/``False`` forces plain archive
    payloads; a sequence of codec names is an explicit accept list.
    """

    def __init__(self, url: Optional[str] = None, *, host: Optional[str] = None,
                 port: Optional[int] = None, path: Optional[str] = None,
                 wire="auto", objective: str = "max_read_tput",
                 accept: Optional[Sequence[str]] = None,
                 link_mbps: Optional[float] = None,
                 cache: Optional[TieredCache] = None,
                 batch_baskets: int = 32, verify: bool = True,
                 timeout: float = 30.0):
        if url is not None:
            host, port, path = P.parse_url(url)
        if host is None or port is None or path is None:
            raise ValueError("need a repro:// url or host=/port=/path=")
        self.host, self.port, self.path = host, int(port), str(path)
        self.verify = verify
        self.batch_baskets = max(int(batch_baskets), 1)
        self.cache = cache
        if wire is None or wire is False:
            self._wire = None
        else:
            if accept is not None:
                acc = list(accept)
            elif isinstance(wire, str) and wire != "auto":
                acc = [wire]                       # wire="lz4" etc.
            elif not isinstance(wire, (str, bool)):
                acc = list(wire)                   # explicit accept list
            else:
                acc = list(DEFAULT_ACCEPT)
            self._wire = {"objective": objective, "accept": acc}
            if link_mbps is not None:
                # declared link speed shifts the server's transcode optimum
                # (identity on fast links, real codecs as bytes get dear)
                self._wire["link_mbps"] = float(link_mbps)
        self._io_lock = threading.Lock()    # serializes the socket
        self._fetch_lock = threading.Lock()  # lazy fetcher-thread init
        self._sock = socket.create_connection((self.host, self.port),
                                              timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._rfile = self._sock.makefile("rb")
        self._closed = False
        # background fetcher (lazy): serves submit_baskets waves
        self._fetchq: Optional[queue.Queue] = None
        self._fetcher: Optional[threading.Thread] = None
        try:
            cat = self._request(P.REQ_CATALOG, {"path": self.path})[0]
        except BaseException:
            # a failed open must not leak the connected socket (probing
            # loops over shard URLs would leak one fd per missing file)
            self._rfile.close()
            self._sock.close()
            raise
        order = cat.get("order") or list(cat["branches"])
        self.branches = {n: cat["branches"][n] for n in order}
        self.tuning = cat.get("tuning", {})
        self.generation = tuple(cat["generation"])
        self.server_transcode = bool(cat.get("transcode", False))
        # cache namespace: the endpoint qualifies the path — two servers
        # exporting same-named files (whose inodes can collide across
        # hosts) must never share entries in a shared TieredCache
        self._cache_ns = f"{self.host}:{self.port}/{self.path}"

    # -- BasketFile API mirror ------------------------------------------

    def branch_names(self) -> list[str]:
        return list(self.branches)

    def tuning_decisions(self) -> dict[str, dict]:
        return dict(self.tuning)

    def _dictionary(self, entry: dict) -> Optional[bytes]:
        d = entry.get("dictionary")
        return base64.b64decode(d) if d else None

    def compressed_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["comp_len"]
                   for n in names for b in self.branches[n]["baskets"])

    def raw_bytes(self, name: Optional[str] = None) -> int:
        names = [name] if name else self.branch_names()
        return sum(b["meta"]["orig_len"]
                   for n in names for b in self.branches[n]["baskets"])

    # -- wire ------------------------------------------------------------

    def _send(self, ftype: int, body: dict) -> None:
        frame = P.pack_frame(ftype, body)
        obs.counter("rbsp.tx_bytes").inc(len(frame))
        self._sock.sendall(frame)

    def _recv(self, want: int) -> tuple[dict, bytes]:
        ftype, body, payload = P.read_frame(self._rfile)
        obs.counter("rbsp.rx_payload_bytes").inc(len(payload))
        if ftype == P.RESP_ERROR:
            raise RuntimeError(f"server error: {body.get('error')}")
        if ftype != want:
            raise P.ProtocolError(f"expected frame {want}, got {ftype}")
        return body, payload

    def _request(self, ftype: int, body: dict, want: Optional[int] = None
                 ) -> tuple[dict, bytes]:
        if want is None:
            want = {P.REQ_CATALOG: P.RESP_CATALOG, P.REQ_READV: P.RESP_READV,
                    P.REQ_PING: P.RESP_PING,
                    P.REQ_STATS: P.RESP_STATS}[ftype]
        verb = P.VERB_NAMES.get(ftype, str(ftype))
        t0 = time.perf_counter()
        with obs.trace.span("rbsp.request", cat="client", verb=verb):
            with self._io_lock:
                self._send(ftype, body)
                out = self._recv(want)
        obs.histogram("rbsp.rtt_s", verb=verb).observe(
            time.perf_counter() - t0)
        return out

    def ping(self) -> bool:
        return bool(self._request(P.REQ_PING, {})[0].get("ok"))

    def server_stats(self, trace: bool = False) -> dict:
        """The server's STATS snapshot over this connection (DESIGN.md
        §13): generation-stamped obs registry + server stats dict;
        ``trace=True`` also drains the server's span ring."""
        body = {"trace": True} if trace else {}
        return self._request(P.REQ_STATS, body)[0]

    def _readv_body(self, name: str, idxs: Sequence[int]) -> dict:
        return {"path": self.path, "generation": list(self.generation),
                "baskets": [[name, int(i)] for i in idxs],
                "wire": self._wire}

    def _split_response(self, body: dict, payload: bytes
                        ) -> list[tuple[bytes, dict]]:
        out, pos = [], 0
        for b in body["baskets"]:
            ln = int(b["len"])
            if pos + ln > len(payload):
                raise P.ProtocolError("response payload shorter than "
                                      "declared basket lengths")
            out.append((payload[pos:pos + ln], b["meta"]))
            pos += ln
        if pos != len(payload):
            raise P.ProtocolError("response payload longer than declared "
                                  "basket lengths")
        return out

    def _resync(self, inflight: int) -> None:
        """Drain responses for requests already on the wire after one of
        them failed — a pipelined connection must never be left a response
        behind (the next caller would read an orphaned RESP_READV as its
        own and silently scatter the wrong baskets).  If draining itself
        fails the stream state is unknowable: poison the socket so every
        later use fails loudly instead of desynchronizing."""
        try:
            for _ in range(inflight):
                P.read_frame(self._rfile)
        except Exception:
            try:
                self._sock.close()
            except OSError:
                pass

    def fetch_wire(self, name: str, idxs: Sequence[int],
                   on_batch=None) -> list[tuple[bytes, dict]]:
        """Fetch wire ``(payload, meta_json)`` pairs for baskets ``idxs``
        of branch ``name`` — batched into vectored requests, each batch's
        request pipelined behind the previous batch's response.

        ``on_batch(batch_idxs, pairs)`` streams each batch to the caller
        as its response lands (decode overlaps the next batch's transfer
        and only one batch of wire bytes is ever held); without it the
        pairs for all ``idxs`` are returned as one list."""
        idxs = list(idxs)
        if not idxs:
            return []
        groups = [idxs[i:i + self.batch_baskets]
                  for i in range(0, len(idxs), self.batch_baskets)]
        out: list[tuple[bytes, dict]] = []
        wait_h = obs.histogram("rbsp.readv_wait_s")
        with obs.trace.span("rbsp.fetch_wire", cat="client", branch=name,
                            baskets=len(idxs), batches=len(groups)), \
                self._io_lock:
            # pipeline: request g+1 is on the wire while we block on g's
            # response — the server answers a connection's requests in
            # order, so responses arrive in group order
            sent = consumed = 0
            try:
                self._send(P.REQ_READV, self._readv_body(name, groups[0]))
                sent += 1
                for g in range(len(groups)):
                    if g + 1 < len(groups):
                        self._send(P.REQ_READV,
                                   self._readv_body(name, groups[g + 1]))
                        sent += 1
                    try:
                        with wait_h.time():
                            body, payload = self._recv(P.RESP_READV)
                    finally:
                        # _recv consumed one frame even when it raised on
                        # a RESP_ERROR; only a transport/framing failure
                        # leaves the frame unconsumed
                        consumed += 1
                    pairs = self._split_response(body, payload)
                    if self.cache is not None:
                        # async spill: the background writer does the file
                        # I/O — a slow disk must not stall the pipeline
                        # (and every _io_lock waiter behind it)
                        for i, (p, m) in zip(groups[g], pairs):
                            self.cache.put_wire_async(
                                self._key(name, i), p, m)
                    if on_batch is not None:
                        on_batch(groups[g], pairs)
                    else:
                        out.extend(pairs)
            except (P.ProtocolError, OSError):
                # framing/transport failure: stream state unknowable —
                # poison the socket so later use fails loudly
                try:
                    self._sock.close()
                except OSError:
                    pass
                raise
            except BaseException:
                self._resync(sent - consumed)
                raise
        return out

    # -- decode ----------------------------------------------------------

    def _key(self, name: str, i: int) -> tuple:
        return basket_key(self._cache_ns, self.generation, name, i)

    def _decode(self, name: str, payload, meta_json: dict,
                verify: Optional[bool] = None) -> bytes:
        entry = self.branches[name]
        meta = BasketMeta.from_json(meta_json)
        d = self._dictionary(entry) if meta.has_dict else None
        return unpack_basket(bytes(payload), meta, d,
                             verify=self.verify if verify is None else verify)

    def _decode_into(self, name: str, payload, meta_json: dict, out) -> int:
        entry = self.branches[name]
        meta = BasketMeta.from_json(meta_json)
        d = self._dictionary(entry) if meta.has_dict else None
        return unpack_basket_into(payload, meta, out, d, verify=self.verify)

    def read_basket_raw(self, name: str, i: int) -> bytes:
        """Decoded raw bytes of one basket (cache-aware)."""
        if self.cache is not None:
            raw = self.cache.get_decoded(self._key(name, i))
            if raw is not None:
                return raw
            w = self.cache.get_wire(self._key(name, i))
            if w is not None:
                raw = self._decode(name, *w)
                self.cache.put_decoded(self._key(name, i), raw)
                return raw
            self.cache.record_miss()
        (p, m), = self.fetch_wire(name, [i])
        raw = self._decode(name, p, m)
        if self.cache is not None:
            self.cache.put_decoded(self._key(name, i), raw)
        return raw

    def read_basket_into(self, name: str, i: int, out) -> int:
        """Fetch + decode basket ``i`` directly into ``out``."""
        if self.cache is not None:
            raw = self.cache.get_decoded(self._key(name, i))
            if raw is None:
                w = self.cache.get_wire(self._key(name, i))
                if w is not None:
                    return self._decode_into(name, w[0], w[1], out)
                self.cache.record_miss()
            else:
                b = np.frombuffer(raw, dtype=np.uint8)
                np.asarray(out).reshape(-1).view(np.uint8)[:b.size] = b
                return b.size
        (p, m), = self.fetch_wire(name, [i])
        return self._decode_into(name, p, m, out)

    # -- bulk reads ------------------------------------------------------

    def _classify(self, name: str, idxs: Sequence[int]):
        """Partition indices into (decoded-hit, wire-hit, fetch) against
        the cache; returns (decoded {i: raw}, wires {i: (payload, meta)},
        missing [i])."""
        decoded, wires, missing = {}, {}, []
        if self.cache is None:
            return decoded, wires, list(idxs)
        for i in idxs:
            k = self._key(name, i)
            raw = self.cache.get_decoded(k)
            if raw is not None:
                decoded[i] = raw
                continue
            w = self.cache.get_wire(k)
            if w is not None:
                wires[i] = w
            else:
                self.cache.record_miss()
                missing.append(i)
        return decoded, wires, missing

    def read_branch(self, name: str, workers: Optional[int] = None) -> np.ndarray:
        """Whole-branch read, byte-identical to the local
        ``BasketFile.read_branch`` of the served file.  The destination is
        allocated once; cached decoded baskets scatter-copy, everything
        else decodes wire payloads straight into its slice."""
        entry = self.branches[name]
        n = len(entry["baskets"])
        out = np.empty(tuple(entry["shape"]), dtype=np.dtype(entry["dtype"]))
        offs, total = byte_offsets(b["meta"]["orig_len"]
                                   for b in entry["baskets"])
        if total != out.nbytes:     # malformed TOC: copying fallback
            chunks = [self.read_basket_raw(name, i) for i in range(n)]
            return join_baskets(chunks, entry["dtype"], tuple(entry["shape"]))
        flat = out.reshape(-1).view(np.uint8)
        lens = [b["meta"]["orig_len"] for b in entry["baskets"]]
        # populate the decoded tier only when the whole branch fits in half
        # the memory budget — a bulk scan of a huge branch must not cycle
        # the LRU (the TTreeCache scan-pollution rule read_all follows too)
        keep = self.cache is not None and self.cache.mem_bytes \
            and total <= self.cache.mem_bytes // 2
        decoded, wires, missing = self._classify(name, range(n))
        for i, raw in decoded.items():
            flat[offs[i]:offs[i] + lens[i]] = np.frombuffer(raw, np.uint8)

        def _land(i: int, p, m) -> None:
            self._decode_into(name, p, m, flat[offs[i]:offs[i] + lens[i]])
            if keep:
                self.cache.put_decoded(
                    self._key(name, i), bytes(flat[offs[i]:offs[i] + lens[i]]))

        for i, (p, m) in wires.items():
            _land(i, p, m)
        if missing:
            # streamed: each batch decodes into its slices as its response
            # lands — decode overlaps the next batch's transfer, and only
            # one batch of wire payloads is ever held in memory
            self.fetch_wire(name, missing, on_batch=lambda bidxs, pairs: [
                _land(i, p, m) for i, (p, m) in zip(bidxs, pairs)])
        return out

    def read_entries(self, name: str, start: int, stop: int) -> np.ndarray:
        """Row-range read touching only the covering baskets."""
        entry = self.branches[name]
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"])
        cover, first_entry, total = [], None, 0
        for i, b in enumerate(entry["baskets"]):
            m = b["meta"]
            if m["entry_start"] + m["entry_count"] <= start \
                    or m["entry_start"] >= stop:
                continue
            if first_entry is None:
                first_entry = m["entry_start"]
            cover.append((i, total, m["orig_len"]))
            total += m["orig_len"]
        if not cover:
            return np.zeros((0,) + shape[1:], dtype=dtype)
        row_elems = int(np.prod(shape[1:], dtype=np.int64)) or 1
        rows = total // (dtype.itemsize * row_elems)
        arr = np.empty((rows,) + shape[1:], dtype=dtype)
        flat = arr.reshape(-1).view(np.uint8)
        idxs = [i for i, _o, _l in cover]
        decoded, wires, missing = self._classify(name, idxs)
        fetched = dict(zip(missing, self.fetch_wire(name, missing))) \
            if missing else {}
        for i, off, ln in cover:
            if i in decoded:
                flat[off:off + ln] = np.frombuffer(decoded[i], np.uint8)
            else:
                p, m = wires[i] if i in wires else fetched[i]
                self._decode_into(name, p, m, flat[off:off + ln])
        return arr[start - first_entry: stop - first_entry].copy()

    # -- PrefetchReader source hook --------------------------------------

    def submit_baskets(self, name: str, idxs: Sequence[int],
                       verify: Optional[bool] = None) -> list[Future]:
        """Schedule decoded-bytes futures for baskets ``idxs`` — the
        remote-source hook ``PrefetchReader`` batches its read-ahead
        through.  Each call is one wave: a background fetch thread turns
        it into one vectored request (cache-aware), so waves queued while
        a fetch is in flight ride the connection back-to-back.  ``verify``
        overrides this file's checksum setting for the wave (the reader's
        own ``verify=`` knob)."""
        futs = [Future() for _ in idxs]
        if idxs:
            self._fetch_queue().put((name, list(idxs), futs, verify))
        return futs

    def _fetch_queue(self) -> queue.Queue:
        with self._fetch_lock:
            if self._fetchq is None:
                self._fetchq = queue.Queue()
                self._fetcher = threading.Thread(
                    target=self._fetch_loop, daemon=True,
                    name="repro-remote-fetch")
                self._fetcher.start()
            return self._fetchq

    def _fetch_loop(self) -> None:
        while True:
            item = self._fetchq.get()
            if item is None:
                return
            name, idxs, futs, verify = item
            fut_of = dict(zip(idxs, futs))

            def _deliver(i: int, payload, meta_json) -> None:
                raw = self._decode(name, payload, meta_json, verify)
                if self.cache is not None:
                    self.cache.put_decoded(self._key(name, i), raw)
                fut_of[i].set_result(raw)

            try:
                decoded, wires, missing = self._classify(name, idxs)
                for i, raw in decoded.items():
                    fut_of[i].set_result(raw)
                for i, (p, m) in wires.items():
                    _deliver(i, p, m)
                if missing:
                    # streamed: each batch's futures resolve as its
                    # response lands, so a whole-branch wave never holds
                    # more than one batch of wire payloads (the consumer
                    # scatters resolved baskets while later batches are
                    # still in flight)
                    self.fetch_wire(name, missing,
                                    on_batch=lambda bidxs, pairs: [
                                        _deliver(i, p, m)
                                        for i, (p, m) in zip(bidxs, pairs)])
            except BaseException as e:
                for fut in futs:
                    if not fut.done():
                        fut.set_exception(e)

    # -- lifecycle -------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._fetchq is not None:
            self._fetchq.put(None)
            self._fetcher.join(timeout=5)
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
