"""Trace-context propagation: W3C-traceparent-style causal identity.

A :class:`SpanContext` is the portable identity of one span — a 16-byte
``trace_id`` shared by every span in one causal tree, an 8-byte
``span_id`` naming this span, and a flags byte (bit 0 = sampled).  It
serializes to the W3C ``traceparent`` layout::

    00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01
    ^  trace_id (32 hex)                 span_id (16 hex)  ^flags

which is what crosses process boundaries: RBSP request bodies carry it
as the ``"tp"`` key (DESIGN.md §16), the engine's pool tasks carry it as
a trailing argument, and a server/worker *activates* the parsed context
so its own spans become children of the remote caller's span.

This module owns only identity and the thread-local activation stack —
no event recording (that is :mod:`repro.obs.trace`) and no metrics
(:mod:`repro.obs.metrics` reads :func:`current` for histogram
exemplars).  Both import this; this imports neither.

Id generation uses a module-level :class:`random.Random` seeded from
``os.urandom`` — ids need uniqueness, not unpredictability, and
``getrandbits`` is ~20x cheaper than an ``os.urandom`` syscall per span.
"""

from __future__ import annotations

import os
import random
import threading
from typing import Optional

__all__ = [
    "SpanContext", "current", "current_traceparent", "push", "pop",
    "activated", "new_trace_id", "new_span_id", "from_traceparent",
]

_rng = random.Random(os.urandom(16))
_rng_lock = threading.Lock()


class _TLS(threading.local):
    """Per-thread activation stack.  The subclass ``__init__`` runs on a
    thread's first access, so ``_tls.stack`` is always a plain attribute
    read — ``getattr(local(), "stack", None)`` on an unset slot raises
    and catches AttributeError internally, ~5x the cost, and the unset
    case is the hot one (every untraced observe/span probes it)."""

    def __init__(self):
        self.stack = []


_tls = _TLS()


def new_trace_id() -> str:
    with _rng_lock:
        return f"{_rng.getrandbits(128):032x}"


def new_span_id() -> str:
    with _rng_lock:
        return f"{_rng.getrandbits(64):016x}"


class SpanContext:
    """One span's identity (immutable value object)."""

    __slots__ = ("trace_id", "span_id", "flags")

    def __init__(self, trace_id: str, span_id: str, flags: int = 1):
        self.trace_id = trace_id
        self.span_id = span_id
        self.flags = flags

    def child(self) -> "SpanContext":
        """A fresh span id under the same trace."""
        return SpanContext(self.trace_id, new_span_id(), self.flags)

    def to_traceparent(self) -> str:
        return f"00-{self.trace_id}-{self.span_id}-{self.flags:02x}"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SpanContext({self.to_traceparent()})"

    def __eq__(self, other) -> bool:
        return (isinstance(other, SpanContext)
                and self.trace_id == other.trace_id
                and self.span_id == other.span_id
                and self.flags == other.flags)


def from_traceparent(tp) -> Optional[SpanContext]:
    """Parse a traceparent string; None for anything malformed (a remote
    peer's bad header must never fail the request it rode in on)."""
    if not isinstance(tp, str):
        return None
    parts = tp.split("-")
    if len(parts) != 4:
        return None
    ver, trace_id, span_id, flags = parts
    if len(ver) != 2 or len(trace_id) != 32 or len(span_id) != 16 \
            or len(flags) != 2:
        return None
    try:
        int(trace_id, 16), int(span_id, 16)
        fl = int(flags, 16)
    except ValueError:
        return None
    if trace_id == "0" * 32 or span_id == "0" * 16:
        return None
    return SpanContext(trace_id, span_id, fl)


def current() -> Optional[SpanContext]:
    """The active span context on this thread, or None."""
    s = _tls.stack
    return s[-1] if s else None


def current_traceparent() -> Optional[str]:
    ctx = current()
    return ctx.to_traceparent() if ctx is not None else None


def push(ctx: SpanContext) -> None:
    _tls.stack.append(ctx)


def pop() -> Optional[SpanContext]:
    s = _tls.stack
    return s.pop() if s else None


class _Activation:
    __slots__ = ("_ctx",)

    def __init__(self, ctx: Optional[SpanContext]):
        self._ctx = ctx

    def __enter__(self):
        if self._ctx is not None:
            push(self._ctx)
        return self._ctx

    def __exit__(self, *a):
        if self._ctx is not None:
            pop()


def activated(ctx) -> _Activation:
    """Context manager making ``ctx`` the ambient parent for the block —
    the adoption point for a remote caller's traceparent.  ``ctx`` may be
    a :class:`SpanContext`, a traceparent string, or None (no-op), so
    callers can pass a request body's ``"tp"`` value straight in."""
    if isinstance(ctx, str):
        ctx = from_traceparent(ctx)
    return _Activation(ctx)
