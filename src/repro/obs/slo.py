"""Rolling-window SLOs over the server's request telemetry.

An :class:`SLOSpec` states an objective for one RBSP verb — "readv p99
stays under 250 ms and the error rate stays inside a 1% budget over a
60 s window".  :class:`SLOEngine` evaluates specs from the *existing*
``server.requests`` / ``server.errors`` / ``server.request_s`` metrics:
the server feeds it monotonic registry snapshots (:meth:`tick`, called
lazily from the STATS path — no extra thread), the engine keeps a
bounded deque of ``(t, extract)`` ticks, and :meth:`evaluate` computes
the *window delta* between the newest tick and the oldest tick still
inside the window.  Deltas — not cumulative totals — are what make the
verdict a rolling view: an error storm an hour ago stops burning the
budget once it leaves the window.

Window semantics (DESIGN.md §16): with ticks at times ``t0 < ... < tn``,
the evaluated interval is ``[max(t0, tn - window_s), tn]`` — at least
two ticks are always retained, so a poller slower than the window still
gets verdicts over its actual poll interval (reported as ``span_s``).
p99 comes from the histogram-delta buckets with bucket-sum refinement
(:func:`repro.obs.metrics.quantile_from_buckets`), so a steady latency
plateau right at a bucket edge is judged at its true value.

Results ride the STATS body (``"slo"`` key) and render in
``obstat --watch``; nothing here takes locks shared with the serving
hot path.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Optional

from repro.obs import metrics as _metrics

__all__ = ["SLOSpec", "SLOEngine", "DEFAULT_SPECS"]


class SLOSpec:
    """One verb's objectives; either bound may be None (not asserted)."""

    __slots__ = ("name", "verb", "p99_s", "error_budget", "window_s")

    def __init__(self, name: str, verb: str, p99_s: Optional[float] = None,
                 error_budget: Optional[float] = 0.01,
                 window_s: float = 60.0):
        self.name = name
        self.verb = verb
        self.p99_s = p99_s
        self.error_budget = error_budget
        self.window_s = float(window_s)

    def to_dict(self) -> dict:
        return {"name": self.name, "verb": self.verb, "p99_s": self.p99_s,
                "error_budget": self.error_budget, "window_s": self.window_s}


# The loopback/LAN operating point the repo's own benches run at; a real
# deployment passes its own specs to BasketServer(slo=[...]).
DEFAULT_SPECS = [
    SLOSpec("readv-latency", "readv", p99_s=0.250),
    SLOSpec("catalog-latency", "catalog", p99_s=0.250),
]


def _hist_delta(cur: dict, old: dict) -> tuple[int, dict, dict]:
    cb, ob = cur.get("buckets", {}), old.get("buckets", {})
    buckets = {}
    for k, v in cb.items():
        d = int(v) - int(ob.get(k, 0))
        if d > 0:
            buckets[k] = d
    cs, os_ = cur.get("bsums", {}), old.get("bsums", {})
    bsums = {k: float(cs.get(k, 0.0)) - float(os_.get(k, 0.0))
             for k in buckets}
    n = int(cur.get("count", 0)) - int(old.get("count", 0))
    return n, buckets, bsums


class SLOEngine:
    """Rolling evaluation of a spec list against snapshot ticks."""

    def __init__(self, specs=None, max_ticks: int = 256):
        self.specs = list(specs) if specs is not None else list(DEFAULT_SPECS)
        self._ticks: deque = deque(maxlen=max_ticks)

    def _extract(self, snap: dict) -> dict:
        """Keep only what evaluation needs (ticks are retained by the
        dozen; shipping whole registries into the deque would bloat)."""
        verbs = {s.verb for s in self.specs}
        hists, counters = {}, {}
        for key, h in (snap.get("hists") or {}).items():
            name, labels = _metrics.parse_key(key)
            if name == "server.request_s" and labels.get("verb") in verbs:
                hists[labels["verb"]] = {
                    "count": int(h.get("count", 0)),
                    "buckets": dict(h.get("buckets", {})),
                    "bsums": dict(h.get("bsums", {}))}
        for key, v in (snap.get("counters") or {}).items():
            name, labels = _metrics.parse_key(key)
            if name in ("server.requests", "server.errors") \
                    and labels.get("verb") in verbs:
                counters[(name, labels["verb"])] = int(v)
        return {"hists": hists, "counters": counters}

    def tick(self, snap: dict, t: Optional[float] = None) -> None:
        """Record one monotonic (non-reset) snapshot observation."""
        t = time.time() if t is None else t
        if self._ticks and t <= self._ticks[-1][0]:
            return
        self._ticks.append((t, self._extract(snap)))
        self._prune(t)

    def _prune(self, now: float) -> None:
        window = max((s.window_s for s in self.specs), default=60.0)
        while len(self._ticks) > 2 and now - self._ticks[1][0] > window:
            self._ticks.popleft()

    def evaluate(self, now: Optional[float] = None) -> list[dict]:
        """Per-spec verdicts over the current window; ``[]`` until two
        ticks exist (no delta to judge)."""
        if len(self._ticks) < 2:
            return []
        now = self._ticks[-1][0] if now is None else now
        t1, cur = self._ticks[-1]
        out = []
        for spec in self.specs:
            # oldest tick still inside this spec's window (always ≥ 1 back,
            # so pollers slower than the window judge their real interval)
            in_window = [(t, e) for t, e in self._ticks
                         if t < t1 and t1 - t <= spec.window_s]
            t0, old = in_window[0] if in_window else self._ticks[-2]
            n, buckets, bsums = _hist_delta(cur["hists"].get(spec.verb, {}),
                                            old["hists"].get(spec.verb, {}))
            reqs = (cur["counters"].get(("server.requests", spec.verb), 0)
                    - old["counters"].get(("server.requests", spec.verb), 0))
            errs = (cur["counters"].get(("server.errors", spec.verb), 0)
                    - old["counters"].get(("server.errors", spec.verb), 0))
            verdict = {"name": spec.name, "verb": spec.verb,
                       "span_s": round(t1 - t0, 3), "window_s": spec.window_s,
                       "requests": max(reqs, 0), "errors": max(errs, 0),
                       "ok": True}
            if n > 0 and spec.p99_s is not None:
                p99 = _metrics.quantile_from_buckets(buckets, 0.99, bsums)
                verdict["p99_s"] = p99
                verdict["p99_limit_s"] = spec.p99_s
                if p99 > spec.p99_s:
                    verdict["ok"] = False
            if reqs > 0 and spec.error_budget is not None:
                rate = errs / reqs
                verdict["error_rate"] = rate
                verdict["error_budget"] = spec.error_budget
                verdict["burn"] = (rate / spec.error_budget
                                   if spec.error_budget > 0 else float("inf"))
                if rate > spec.error_budget:
                    verdict["ok"] = False
            out.append(verdict)
        return out
