"""Span-based tracing with Chrome trace-event export.

``span("engine.pack", algo="zstd")`` wraps a region of code; completed
spans land in a bounded ring buffer (oldest dropped first, so a
long-running server keeps the *recent* window, which is the one a
``--trace`` capture wants).  :func:`export_chrome` writes the ring as
Chrome trace-event JSON (``{"traceEvents": [...]}``) loadable in
Perfetto / ``chrome://tracing``.

Timestamps are microseconds from a module-load ``perf_counter_ns`` epoch,
so spans from one process line up on one timeline.  Thread-pool workers
share the parent's ring; *process*-pool workers have their own ring that
stays in the child (folding variable-size span lists through the pool
result channel would cost more than the data is worth) — only their
metrics fold back.  The enable gate is shared with metrics
(``REPRO_OBS=off`` / :func:`repro.obs.metrics.set_enabled`).
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Optional

from repro.obs import metrics as _metrics

__all__ = ["span", "instant", "drain", "events", "export_chrome",
           "set_capacity", "clear"]

_EPOCH_NS = time.perf_counter_ns()
_DEFAULT_CAPACITY = 65536

_lock = threading.Lock()
_ring: deque = deque(maxlen=_DEFAULT_CAPACITY)
_thread_names: dict[int, str] = {}


def _now_us() -> float:
    return (time.perf_counter_ns() - _EPOCH_NS) / 1e3


def set_capacity(n: int) -> None:
    """Resize the ring (keeps the newest events)."""
    global _ring
    with _lock:
        _ring = deque(_ring, maxlen=int(n))


def clear() -> None:
    with _lock:
        _ring.clear()


def _note_thread() -> int:
    t = threading.current_thread()
    tid = t.ident or 0
    if tid not in _thread_names:
        _thread_names[tid] = t.name
    return tid


class _Span:
    __slots__ = ("name", "cat", "args", "_t0")

    def __init__(self, name: str, cat: str, args: dict):
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = _now_us()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = _now_us()
        if exc_type is not None:
            self.args = dict(self.args, error=exc_type.__name__)
        ev = {"name": self.name, "cat": self.cat, "ph": "X",
              "ts": self._t0, "dur": t1 - self._t0,
              "pid": os.getpid(), "tid": _note_thread()}
        if self.args:
            ev["args"] = self.args
        with _lock:
            _ring.append(ev)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        pass


_NULL_SPAN = _NullSpan()


def span(name: str, cat: str = "repro", **args):
    """Context manager recording one complete ("X") trace event."""
    if not _metrics.enabled():
        return _NULL_SPAN
    return _Span(name, cat, args)


def instant(name: str, cat: str = "repro", **args) -> None:
    """Record a zero-duration marker event."""
    if not _metrics.enabled():
        return
    ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
          "ts": _now_us(), "pid": os.getpid(), "tid": _note_thread()}
    if args:
        ev["args"] = args
    with _lock:
        _ring.append(ev)


def events() -> list[dict]:
    """Copy of the current ring (oldest first), ring left intact."""
    with _lock:
        return list(_ring)


def drain() -> list[dict]:
    """Pop every buffered event (the STATS-verb transport: each event
    crosses the wire exactly once)."""
    with _lock:
        out = list(_ring)
        _ring.clear()
    return out


def export_chrome(path: str, events: Optional[list] = None) -> int:
    """Write Chrome trace-event JSON; returns the event count.

    ``events=None`` drains the live ring; passing an explicit list (e.g.
    one shipped over STATS, or a synthetic one in tests) exports that
    instead.  Thread-name metadata ("M" events) is emitted for every tid
    seen so Perfetto shows "prefetch-0" instead of a bare id."""
    evs = drain() if events is None else list(events)
    tids = {(e.get("pid"), e.get("tid")) for e in evs if "tid" in e}
    meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": _thread_names.get(tid, f"tid-{tid}")}}
            for pid, tid in sorted(tids, key=lambda x: (str(x[0]), str(x[1])))]
    doc = {"traceEvents": meta + evs, "displayTimeUnit": "ms"}
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return len(evs)
