"""Span-based tracing with Chrome trace-event export and cross-process
causal propagation.

``span("engine.pack", algo="zstd")`` wraps a region of code; completed
spans land in a bounded ring buffer (oldest dropped first, so a
long-running server keeps the *recent* window, which is the one a
``--trace`` capture wants).  :func:`export_chrome` writes the ring as
Chrome trace-event JSON (``{"traceEvents": [...]}``) loadable in
Perfetto / ``chrome://tracing``.

Causality (DESIGN.md §16): when a :mod:`repro.obs.context` span context
is active on the thread — either because an enclosing ``span`` opened
one, or because a server adopted a remote caller's traceparent via
``context.activated(body["tp"])`` — each completed span records
``trace_id`` / ``span_id`` / ``parent_id`` in its ``args`` and pushes
its own context while open, so nested spans (local or remote) chain
into one tree.  Spans opened with no ambient context and without
``root=True`` stay id-free, exactly as in PR 6 — zero overhead and no
arg noise for purely local tracing.  :func:`stitch` merges captures
from several processes/hosts into one timeline; :func:`build_tree`
reassembles the parent/child forest for assertions and CLI rendering.

Timestamps are microseconds anchored to the unix epoch (wall clock
sampled once at import, advanced by ``perf_counter_ns`` so the timeline
is monotonic within a process).  Same-host captures therefore line up
when stitched; cross-host skew is whatever NTP leaves behind.
Thread-pool workers share the parent's ring; *process*-pool workers
have their own ring that the engine folds back on ``collect_obs()``
via :func:`drain` + :func:`ingest`.  When the ring is full each
appended event evicts the oldest and bumps the ``obs.trace.dropped``
counter.  The enable gate is shared with metrics (``REPRO_OBS=off`` /
:func:`repro.obs.metrics.set_enabled`).
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Optional

from repro.obs import context as _context
from repro.obs import metrics as _metrics
from repro.obs import profile as _profile

__all__ = ["span", "instant", "drain", "events", "export_chrome",
           "set_capacity", "clear", "ingest", "stitch", "build_tree"]

_WALL_US = time.time_ns() / 1e3
_EPOCH_NS = time.perf_counter_ns()
_DEFAULT_CAPACITY = 65536

_lock = threading.Lock()
_ring: deque = deque(maxlen=_DEFAULT_CAPACITY)
_thread_names: dict[int, str] = {}


def _now_us() -> float:
    return _WALL_US + (time.perf_counter_ns() - _EPOCH_NS) / 1e3


def set_capacity(n: int) -> None:
    """Resize the ring (keeps the newest events)."""
    global _ring
    with _lock:
        _ring = deque(_ring, maxlen=int(n))


def clear() -> None:
    with _lock:
        _ring.clear()


def _note_thread() -> int:
    t = threading.current_thread()
    tid = t.ident or 0
    if tid not in _thread_names:
        _thread_names[tid] = t.name
    return tid


def _append(ev: dict) -> None:
    """Ring append with eviction accounting (caller must NOT hold _lock)."""
    dropped = False
    with _lock:
        if _ring.maxlen is not None and len(_ring) >= _ring.maxlen:
            dropped = True
        _ring.append(ev)
    if dropped:
        _metrics.REGISTRY.counter("obs.trace.dropped").inc()


class _Span:
    __slots__ = ("name", "cat", "args", "_t0", "_ctx", "_parent", "_prof")

    def __init__(self, name: str, cat: str, args: dict, root: bool):
        self.name = name
        self.cat = cat
        self.args = args
        parent = _context.current()
        if parent is not None:
            self._ctx = parent.child()
            self._parent = parent.span_id
        elif root:
            self._ctx = _context.SpanContext(
                _context.new_trace_id(), _context.new_span_id())
            self._parent = None
        else:
            self._ctx = None
            self._parent = None

    def __enter__(self):
        if self._ctx is not None:
            _context.push(self._ctx)
        # span-attributed profiling (§17): while the sampler runs, register
        # this span on the thread so samples carry a span:<name> root frame.
        # The flag is latched per span — a profiler started mid-span must
        # not pop what was never pushed.
        self._prof = _profile._ACTIVE
        if self._prof:
            _profile.note_push(
                self.name,
                self._ctx.trace_id if self._ctx is not None else "")
        self._t0 = _now_us()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = _now_us()
        if self._prof:
            _profile.note_pop()
        if self._ctx is not None:
            _context.pop()
        if exc_type is not None:
            self.args = dict(self.args, error=exc_type.__name__)
        ev = {"name": self.name, "cat": self.cat, "ph": "X",
              "ts": self._t0, "dur": t1 - self._t0,
              "pid": os.getpid(), "tid": _note_thread()}
        if self._ctx is not None:
            ids = {"trace_id": self._ctx.trace_id,
                   "span_id": self._ctx.span_id}
            if self._parent is not None:
                ids["parent_id"] = self._parent
            self.args = dict(self.args, **ids)
        if self.args:
            ev["args"] = self.args
        _append(ev)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        pass


_NULL_SPAN = _NullSpan()


def span(name: str, cat: str = "repro", root: bool = False, **args):
    """Context manager recording one complete ("X") trace event.

    ``root=True`` mints a fresh trace when no context is active (the
    client entry points use this so propagation works without callers
    having to open their own root span); with an ambient context the
    span is its child either way."""
    if not _metrics.enabled():
        return _NULL_SPAN
    return _Span(name, cat, args, root)


def instant(name: str, cat: str = "repro", **args) -> None:
    """Record a zero-duration marker event."""
    if not _metrics.enabled():
        return
    ctx = _context.current()
    if ctx is not None:
        args = dict(args, trace_id=ctx.trace_id, parent_id=ctx.span_id)
    ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
          "ts": _now_us(), "pid": os.getpid(), "tid": _note_thread()}
    if args:
        ev["args"] = args
    _append(ev)


def events() -> list[dict]:
    """Copy of the current ring (oldest first), ring left intact."""
    with _lock:
        return list(_ring)


def drain() -> list[dict]:
    """Pop every buffered event (the STATS-verb transport: each event
    crosses the wire exactly once)."""
    with _lock:
        out = list(_ring)
        _ring.clear()
    return out


def ingest(evs: list) -> int:
    """Fold foreign events (a process-pool worker's drained ring) into
    this process's ring; returns the count folded."""
    n = 0
    for ev in evs or ():
        if isinstance(ev, dict):
            _append(ev)
            n += 1
    return n


def stitch(*captures) -> list[dict]:
    """Merge trace captures from several processes into one timeline.

    Each capture is a list of events or a ``{"traceEvents": [...]}``
    dict (an :func:`export_chrome` document).  Metadata ("M") events are
    deduplicated by (pid, tid, name); real events sort by timestamp.
    Because timestamps are unix-anchored, same-host captures interleave
    correctly without offset fixups."""
    meta: dict[tuple, dict] = {}
    evs: list[dict] = []
    for cap in captures:
        if isinstance(cap, dict):
            cap = cap.get("traceEvents") or []
        for ev in cap:
            if not isinstance(ev, dict):
                continue
            if ev.get("ph") == "M":
                meta.setdefault(
                    (ev.get("pid"), ev.get("tid"), ev.get("name")), ev)
            else:
                evs.append(ev)
    evs.sort(key=lambda e: e.get("ts", 0.0))
    return [meta[k] for k in sorted(meta, key=str)] + evs


def build_tree(evs: list[dict]) -> list[dict]:
    """Reassemble the span forest from propagated ids.

    Returns roots as ``{"name", "event", "children": [...]}`` nodes
    (children ordered by start time).  Events without a ``span_id`` are
    ignored; events whose ``parent_id`` is absent from the capture
    (parent fell off a ring, or the capture window clipped it) become
    roots so nothing silently vanishes."""
    nodes: dict[str, dict] = {}
    order: list[dict] = []
    for ev in sorted(evs, key=lambda e: e.get("ts", 0.0)):
        args = ev.get("args") or {}
        sid = args.get("span_id")
        if not sid:
            continue
        node = {"name": ev.get("name"), "event": ev, "children": []}
        nodes[sid] = node
        order.append(node)
    roots = []
    for node in order:
        pid = (node["event"].get("args") or {}).get("parent_id")
        parent = nodes.get(pid) if pid else None
        if parent is not None and parent is not node:
            parent["children"].append(node)
        else:
            roots.append(node)
    return roots


def export_chrome(path: str, events: Optional[list] = None) -> int:
    """Write Chrome trace-event JSON; returns the event count.

    ``events=None`` drains the live ring; passing an explicit list (e.g.
    one shipped over STATS, or a synthetic one in tests) exports that
    instead.  Thread-name metadata ("M" events) is emitted for every tid
    seen so Perfetto shows "prefetch-0" instead of a bare id."""
    evs = drain() if events is None else list(events)
    tids = {(e.get("pid"), e.get("tid"))
            for e in evs if "tid" in e and e.get("ph") != "M"}
    meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": _thread_names.get(tid, f"tid-{tid}")}}
            for pid, tid in sorted(tids, key=lambda x: (str(x[0]), str(x[1])))]
    doc = {"traceEvents": meta + evs, "displayTimeUnit": "ms"}
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return len(evs)
