"""Continuous sampling profiler: span-attributed flamegraphs plus
per-phase memory watermarks (DESIGN.md §17).

The metrics registry (§13) says *which verb* is slow and the trace ring
(§16) says *which request*, but neither says which *code* burned the
time inside a span.  This module closes that gap with a wall-clock
sampler: a daemon thread wakes at ``hz`` and walks every thread's
current Python stack via ``sys._current_frames()``, folding each
observation into a collapsed-stack dict (``"root;child;leaf" ->
count``) — the flamegraph input format.  Sampling is proportional: a
function's share of samples estimates its share of wall time, and the
cost is one stack walk per thread per tick regardless of how hot the
code is — no per-call instrumentation, safe to leave on in production.

**Span attribution.**  When the profiler is active, ``trace.span``
registers the span name (and trace id, if the span carries one) in a
per-thread registry here, and every sample taken on that thread is
prefixed with a ``span:<name>`` frame.  A fold therefore reads
"``span:rbsp.serve`` spent 41 samples under ``transcode_many`` →
``pack_basket``" — the §16 causal tree extended down to function
granularity.  The registry is a plain dict of per-thread lists mutated
only by the owning thread (GIL-atomic append/pop); the sampler reads
``stack[-1]`` racily and tolerates torn reads — attribution may be off
by one sample at a span boundary, never wrong by more.

**Memory watermarks.**  :func:`mem_phase` wraps a named phase (engine
pack/unpack, server READV, tuner trial matrix, checkpoint save/load,
serve prefill/decode) and records its peak memory: the tracemalloc peak
when tracing is on (exact Python-heap peak, ~2x allocation overhead —
opt in with ``start(mem="tracemalloc")``), else the RSS delta from
``/proc/self/statm`` (free, catches native/numpy allocations tracemalloc
can't see).  Watermarks land both in module state (the flight recorder's
``watermarks`` table) and in the ``mem.phase_peak_bytes{phase=}``
histogram.

**Worker folding.**  Process-pool workers sample into their own module
state; :meth:`repro.io.engine.CompressionEngine.collect_obs` drains it
(:func:`drain` in the child, :func:`ingest` in the parent) exactly like
§16 trace rings, so a flamegraph of a pool workload includes the
workers' stacks.  Remote capture rides the RBSP ``PROF`` verb
(``remote.client.request_prof``).

Everything honors the shared ``REPRO_OBS`` gate: with obs disabled the
sampler skips its tick, ``mem_phase`` returns a shared no-op, and
``trace.span`` never calls in (it checks :data:`_ACTIVE` first).
"""

from __future__ import annotations

import json
import os
import sys
import threading
import time
from typing import Optional

from repro.obs import metrics as _metrics

__all__ = [
    "start", "stop", "active", "status", "snapshot", "drain", "ingest",
    "reset", "collapsed", "speedscope", "self_counts", "mem_phase",
    "watermarks", "note_push", "note_pop", "Profiler",
]

DEFAULT_HZ = 67.0        # deliberately co-prime with common 10/50/100 Hz
                         # periodic work, so sampling doesn't alias with it
MAX_DEPTH = 64

# read directly by trace._Span on every span enter — a module-global bool
# is one dict lookup, cheaper than a call when the profiler is off
_ACTIVE = False

_state_lock = threading.Lock()
_folds: dict[str, int] = {}          # collapsed stack -> sample count
_samples = 0                          # total samples folded locally
_span_traces: dict[str, str] = {}     # span name -> last trace_id seen
_watermarks: dict[str, dict] = {}     # phase -> {peak_bytes, count, src}

# tid -> [(span_name, trace_id), ...]; mutated only by the owning thread
# (append/pop are GIL-atomic), read racily by the sampler
_span_stacks: dict[int, list] = {}

_ctl_lock = threading.Lock()
_profiler: Optional["Profiler"] = None
_mem_active = False
_mem_src = "rss"


# -- span attribution (called from repro.obs.trace) -------------------------

def note_push(name: str, trace_id: str = "") -> None:
    """A span opened on this thread (trace._Span calls this only while
    :data:`_ACTIVE`); subsequent samples carry a ``span:<name>`` root."""
    tid = threading.get_ident()
    st = _span_stacks.get(tid)
    if st is None:
        st = _span_stacks[tid] = []
    st.append((name, trace_id))


def note_pop() -> None:
    tid = threading.get_ident()
    st = _span_stacks.get(tid)
    if st:
        st.pop()


# -- the sampler ------------------------------------------------------------

def _frame_label(frame) -> str:
    co = frame.f_code
    return "%s (%s:%d)" % (co.co_name, os.path.basename(co.co_filename),
                           co.co_firstlineno)


def _walk(frame, max_depth: int) -> list[str]:
    """Leaf-first labels for one thread's stack (bounded depth)."""
    out = []
    while frame is not None and len(out) < max_depth:
        out.append(_frame_label(frame))
        frame = frame.f_back
    return out


class Profiler:
    """The daemon sampler thread.  One per process (module :func:`start` /
    :func:`stop` manage the singleton); constructing one directly is the
    embedded/test mode."""

    def __init__(self, hz: float = DEFAULT_HZ, max_depth: int = MAX_DEPTH):
        self.hz = max(float(hz), 0.1)
        self.max_depth = int(max_depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.started_unix = 0.0

    def start(self) -> "Profiler":
        if self._thread is not None:
            return self
        self.started_unix = time.time()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="obs-profiler")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=2.0)
        self._thread = None

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def _run(self) -> None:
        interval = 1.0 / self.hz
        own = threading.get_ident()
        while not self._stop.wait(interval):
            if not _metrics.enabled():
                continue
            self._sample_once(own)

    def _sample_once(self, own_tid: int) -> None:
        global _samples
        frames = sys._current_frames()
        ticks: dict[str, int] = {}
        for tid, frame in frames.items():
            if tid == own_tid:
                continue
            stack = _walk(frame, self.max_depth)
            if not stack:
                continue
            stack.reverse()                      # root-first for folding
            st = _span_stacks.get(tid)
            if st:
                try:
                    name, trace_id = st[-1]
                except IndexError:               # raced a pop
                    name = trace_id = ""
                if name:
                    stack.insert(0, "span:" + name)
                    if trace_id:
                        _span_traces[name] = trace_id
            key = ";".join(stack)
            ticks[key] = ticks.get(key, 0) + 1
        # prune span stacks of threads that no longer exist (bounded leak
        # otherwise: one empty list per dead traced thread)
        for tid in [t for t, st in list(_span_stacks.items())
                    if not st and t not in frames]:
            _span_stacks.pop(tid, None)
        if not ticks:
            return
        with _state_lock:
            for key, n in ticks.items():
                _folds[key] = _folds.get(key, 0) + n
                _samples += n


# -- memory watermarks ------------------------------------------------------

def _rss_bytes() -> int:
    try:
        with open("/proc/self/statm", "rb") as f:
            return int(f.read().split()[1]) * (os.sysconf("SC_PAGESIZE")
                                               if hasattr(os, "sysconf")
                                               else 4096)
    except (OSError, ValueError, IndexError):
        try:
            import resource
            return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
        except Exception:
            return 0


def _record_watermark(phase: str, peak: int, src: str) -> None:
    with _state_lock:
        w = _watermarks.get(phase)
        if w is None:
            w = _watermarks[phase] = {"peak_bytes": 0, "count": 0, "src": src}
        w["peak_bytes"] = max(int(w["peak_bytes"]), int(peak))
        w["count"] += 1
        w["src"] = src
    # the histogram gives the distribution; the table above the high-water
    # mark the flight recorder dumps
    _metrics.REGISTRY.histogram("mem.phase_peak_bytes",
                                phase=phase).observe(float(peak))


class _MemPhase:
    __slots__ = ("phase", "_tm", "_rss0")

    def __init__(self, phase: str):
        self.phase = phase

    def __enter__(self):
        import tracemalloc
        self._tm = tracemalloc.is_tracing()
        if self._tm:
            try:
                tracemalloc.reset_peak()
            except Exception:        # pre-3.9, or tracing stopped underneath
                self._tm = False
        if not self._tm:
            self._rss0 = _rss_bytes()
        return self

    def __exit__(self, *a):
        if self._tm:
            import tracemalloc
            try:
                peak = tracemalloc.get_traced_memory()[1]
            except Exception:
                return
            _record_watermark(self.phase, peak, "tracemalloc")
        else:
            # RSS high-water of the phase: current RSS at exit vs entry.
            # Coarse (other threads allocate too) but free, and it sees
            # native/numpy buffers tracemalloc cannot.
            _record_watermark(self.phase, max(_rss_bytes(), self._rss0),
                              "rss")


class _NullPhase:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        pass


_NULL_PHASE = _NullPhase()


def mem_phase(phase: str):
    """Context manager recording the peak memory of a named phase.
    A shared no-op unless memory watermarks are armed (``start(mem=...)``)
    and obs is enabled — a cold call is one flag check."""
    if not _mem_active or not _metrics.enabled():
        return _NULL_PHASE
    return _MemPhase(phase)


def watermarks() -> dict:
    with _state_lock:
        return {k: dict(v) for k, v in _watermarks.items()}


# -- lifecycle --------------------------------------------------------------

def start(hz: float = DEFAULT_HZ, mem=False) -> bool:
    """Start (or restart with new settings) the process profiler.

    ``mem`` arms the watermark layer: ``True``/``"rss"`` records RSS
    peaks, ``"tracemalloc"`` additionally starts tracemalloc for exact
    Python-heap peaks (noticeable allocation overhead — profiling
    sessions, not always-on).  Returns False (and does nothing) when obs
    is disabled."""
    global _profiler, _mem_active, _mem_src, _ACTIVE
    if not _metrics.enabled():
        return False
    with _ctl_lock:
        if _profiler is not None:
            _profiler.stop()
        if mem:
            _mem_src = "tracemalloc" if mem == "tracemalloc" else "rss"
            if _mem_src == "tracemalloc":
                import tracemalloc
                if not tracemalloc.is_tracing():
                    tracemalloc.start()
            _mem_active = True
        _profiler = Profiler(hz=hz).start()
        _ACTIVE = True
    return True


def stop() -> None:
    global _profiler, _mem_active, _ACTIVE
    with _ctl_lock:
        _ACTIVE = False
        p, _profiler = _profiler, None
        if p is not None:
            p.stop()
        if _mem_active and _mem_src == "tracemalloc":
            import tracemalloc
            try:
                tracemalloc.stop()
            except Exception:
                pass
        _mem_active = False


def active() -> bool:
    return _ACTIVE


def status() -> dict:
    with _ctl_lock:
        p = _profiler
        hz = p.hz if p is not None else 0.0
        since = p.started_unix if p is not None else 0.0
    with _state_lock:
        n, stacks = _samples, len(_folds)
    return {"active": _ACTIVE, "hz": hz, "samples": n, "stacks": stacks,
            "mem": _mem_src if _mem_active else None,
            "started_unix": since}


# -- fold export / cross-process folding ------------------------------------

def snapshot(reset: bool = False) -> dict:
    """The profile document: fold table + span trace ids + watermarks.
    ``reset=True`` zeroes the folds/samples (the worker-drain transport);
    watermarks are high-water marks and reset with them."""
    with _state_lock:
        doc = {"version": 1, "samples": _samples, "folds": dict(_folds),
               "span_traces": dict(_span_traces),
               "watermarks": {k: dict(v) for k, v in _watermarks.items()}}
        if reset:
            _reset_locked()
    doc["active"] = _ACTIVE
    return doc


def drain() -> dict:
    """Pop the local profile state (each sample crosses a pool/wire
    boundary exactly once — the ``collect_obs`` / PROF-fetch transport)."""
    return snapshot(reset=True)


def _reset_locked() -> None:
    global _samples
    _folds.clear()
    _samples = 0
    _span_traces.clear()
    _watermarks.clear()


def reset() -> None:
    with _state_lock:
        _reset_locked()


def ingest(doc) -> int:
    """Fold a foreign profile document (a worker's :func:`drain`, a PROF
    fetch) into local state; returns the sample count folded."""
    global _samples
    if not isinstance(doc, dict):
        return 0
    folds = doc.get("folds") or {}
    n = 0
    with _state_lock:
        for key, cnt in folds.items():
            if isinstance(key, str) and isinstance(cnt, int) and cnt > 0:
                _folds[key] = _folds.get(key, 0) + cnt
                n += cnt
        _samples += n
        for name, tid in (doc.get("span_traces") or {}).items():
            if isinstance(name, str) and isinstance(tid, str):
                _span_traces[name] = tid
        for phase, w in (doc.get("watermarks") or {}).items():
            if not isinstance(w, dict):
                continue
            cur = _watermarks.get(phase)
            if cur is None:
                cur = _watermarks[phase] = {"peak_bytes": 0, "count": 0,
                                            "src": w.get("src", "rss")}
            cur["peak_bytes"] = max(int(cur["peak_bytes"]),
                                    int(w.get("peak_bytes", 0)))
            cur["count"] += int(w.get("count", 0))
    return n


# -- exporters --------------------------------------------------------------

def collapsed(doc: Optional[dict] = None) -> str:
    """Brendan-Gregg collapsed-stack text (``stack count`` per line) —
    ``flamegraph.pl`` / speedscope / inferno input."""
    folds = (doc or snapshot()).get("folds") or {}
    return "".join(f"{k} {v}\n" for k, v in sorted(folds.items()))


def speedscope(doc: Optional[dict] = None, name: str = "repro") -> dict:
    """The profile as a speedscope ``sampled`` document (open at
    https://speedscope.app or with ``speedscope file.json``)."""
    folds = (doc or snapshot()).get("folds") or {}
    frame_ix: dict[str, int] = {}
    frames: list[dict] = []
    samples: list[list[int]] = []
    weights: list[int] = []
    total = 0
    for key in sorted(folds):
        cnt = int(folds[key])
        stack = []
        for label in key.split(";"):
            ix = frame_ix.get(label)
            if ix is None:
                ix = frame_ix[label] = len(frames)
                frames.append({"name": label})
            stack.append(ix)
        samples.append(stack)
        weights.append(cnt)
        total += cnt
    return {
        "$schema": "https://www.speedscope.app/file-format-schema.json",
        "shared": {"frames": frames},
        "profiles": [{
            "type": "sampled", "name": name, "unit": "none",
            "startValue": 0, "endValue": total,
            "samples": samples, "weights": weights,
        }],
        "name": name, "activeProfileIndex": 0,
        "exporter": "repro.obs.profile",
    }


def self_counts(doc: Optional[dict] = None) -> dict[str, int]:
    """Per-function *self* sample counts (the leaf frame of each fold) —
    what ``obstat --watch`` ranks its top-N functions by."""
    folds = (doc or snapshot()).get("folds") or {}
    out: dict[str, int] = {}
    for key, cnt in folds.items():
        leaf = key.rsplit(";", 1)[-1]
        out[leaf] = out.get(leaf, 0) + int(cnt)
    return out


def write_collapsed(path: str, doc: Optional[dict] = None) -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(collapsed(doc))
    os.replace(tmp, path)


def write_speedscope(path: str, doc: Optional[dict] = None,
                     name: str = "repro") -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(speedscope(doc, name=name), f)
    os.replace(tmp, path)
