"""Process-wide metrics registry: counters, gauges, log2-bucket histograms.

The paper's survey method is measurement — per-algorithm rates and ratios
drive every recommendation — and this module is that method turned into a
permanent runtime fixture.  Design constraints, in order:

* **lock-cheap hot path** — there is no registry-wide lock on the update
  path.  Instrument lookup is one GIL-atomic ``dict.get`` (creation takes
  the registry lock once per key); each instrument owns a tiny lock
  guarding only its own few fields, held for an add or a bucket bump.
  Disabled (``REPRO_OBS=off``) call sites get a shared no-op instrument,
  so the off path is one flag check and an attribute call.

* **mergeable snapshots** — :meth:`Registry.snapshot` is a plain JSON-able
  dict and :meth:`Registry.merge` folds one into another (counters and
  histogram buckets add, gauges last-write-win).  ``snapshot(reset=True)``
  returns a *delta* and zeroes the source, which is what makes folding
  idempotent: process-pool and shm workers snapshot-and-reset their own
  registries and the parent merges the deltas (``CompressionEngine``
  does this on close), so a worker polled twice contributes each event
  exactly once.

* **fixed log2 buckets** — histograms have 96 immutable buckets at
  power-of-two boundaries covering ``[2^-32, 2^63)`` (bucket 0 catches
  zero/underflow, bucket 95 overflow).  One layout for every unit —
  seconds, bytes, basket counts — so snapshots merge without bucket
  negotiation and quantiles come straight from the cumulative counts.
  Each bucket also accumulates the *sum of observed values* ("bsums"),
  so quantile estimates report the bucket's true mean instead of a
  positional guess — exact when a bucket holds one repeated value
  (e.g. every request took 2.0s: p99 is 2.0, not an interpolated 3.1).

* **exemplars** — when a trace context (:mod:`repro.obs.context`) is
  active at ``observe()`` time, the histogram remembers the most recent
  ``(trace_id, span_id, value)`` per bucket.  A p99 read in ``obstat``
  can then name a *concrete slow trace* to go look at, not just a
  latency number.

Keys are canonical strings ``name{k=v,...}`` with sorted label keys
(:func:`format_key` / :func:`parse_key`), so a snapshot serialized as
canonical JSON has exactly one byte encoding — the property the RBSP
``STATS`` verb relies on.
"""

from __future__ import annotations

import math
import os
import threading
import time
from typing import Optional

from repro.obs import context as _context

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY", "NULL",
    "format_key", "parse_key", "bucket_index", "bucket_bounds",
    "quantile_from_buckets", "exemplar_for_quantile",
    "enabled", "set_enabled",
]

_OFF_VALUES = {"off", "0", "false", "no", "disabled"}


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "on").strip().lower() not in _OFF_VALUES


_enabled = _env_enabled()


def enabled() -> bool:
    """Whether observability is on (default; ``REPRO_OBS=off`` disables)."""
    return _enabled


def set_enabled(flag: bool) -> bool:
    """Runtime toggle (tests, the overhead A/B benchmark).  Call sites
    acquire instruments per event, so the toggle applies immediately;
    returns the previous state."""
    global _enabled
    prev, _enabled = _enabled, bool(flag)
    return prev


# ---------------------------------------------------------------------------
# key encoding
# ---------------------------------------------------------------------------

def format_key(name: str, labels: Optional[dict] = None) -> str:
    """Canonical ``name{k=v,...}`` key (sorted label keys, str values)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def parse_key(key: str) -> tuple[str, dict]:
    """Inverse of :func:`format_key` (labels as a plain str->str dict)."""
    if not key.endswith("}") or "{" not in key:
        return key, {}
    name, _, inner = key[:-1].partition("{")
    labels = {}
    for part in inner.split(","):
        if part:
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


# ---------------------------------------------------------------------------
# histogram bucket layout (fixed: merge needs one layout everywhere)
# ---------------------------------------------------------------------------

N_BUCKETS = 96
_EXP_OFFSET = 32        # bucket i covers [2^(i-33), 2^(i-32)); bucket 0 = underflow


def bucket_index(value: float) -> int:
    """The log2 bucket for ``value``: 0 for ``value < 2^-32`` (incl. 0 and
    negatives), 95 for ``value >= 2^63``."""
    if value <= 0.0:
        return 0
    e = math.frexp(value)[1] + _EXP_OFFSET    # 2^(e-1) <= v < 2^e  ->  e
    if e < 0:
        return 0
    return e if e < N_BUCKETS else N_BUCKETS - 1


def bucket_bounds(i: int) -> tuple[float, float]:
    """``[lo, hi)`` covered by bucket ``i`` (bucket 0's lo is 0)."""
    lo = 0.0 if i == 0 else 2.0 ** (i - 1 - _EXP_OFFSET)
    hi = 2.0 ** (i - _EXP_OFFSET)
    return lo, hi


def quantile_from_buckets(buckets: dict, q: float,
                          bsums: Optional[dict] = None) -> float:
    """Estimate the ``q``-quantile from ``{bucket_index: count}`` (string
    or int indices — snapshots carry strings).  0.0 for an empty histogram.

    With ``bsums`` (``{bucket_index: sum_of_values}``, the snapshot's
    ``"bsums"`` key) the selected bucket reports its observed mean,
    clamped to the bucket bounds — *exact* when the bucket holds one
    repeated value, which is what happens at bucket edges (a stream of
    identical 2.0s observations lands entirely in ``[2, 4)`` and
    positional interpolation would report up to 2x high).  Without
    ``bsums`` (older snapshots) it falls back to linear interpolation
    inside the bucket."""
    items = sorted((int(k), int(v)) for k, v in buckets.items() if int(v))
    total = sum(v for _k, v in items)
    if not total:
        return 0.0
    target = max(min(q, 1.0), 0.0) * total
    seen = 0
    for i, n in items:
        if seen + n >= target:
            lo, hi = bucket_bounds(i)
            if bsums is not None:
                s = bsums.get(str(i), bsums.get(i))
                if s is not None:
                    return min(max(float(s) / n, lo), hi)
            frac = (target - seen) / n
            return lo + (hi - lo) * frac
        seen += n
    return bucket_bounds(items[-1][0])[1]


def exemplar_for_quantile(hist_snap: dict, q: float) -> Optional[dict]:
    """The exemplar attached to the bucket containing the ``q``-quantile
    of a histogram *snapshot* (``{"buckets", "exemplars", ...}``), or
    None — the hook that links "p99 is slow" to a concrete trace_id."""
    exemplars = hist_snap.get("exemplars") or {}
    if not exemplars:
        return None
    buckets = hist_snap.get("buckets") or {}
    items = sorted((int(k), int(v)) for k, v in buckets.items() if int(v))
    total = sum(v for _k, v in items)
    if not total:
        return None
    target = max(min(q, 1.0), 0.0) * total
    seen = 0
    pick = items[-1][0]
    for i, n in items:
        if seen + n >= target:
            pick = i
            break
        seen += n
    # walk down from the selected bucket: the nearest annotated bucket at
    # or below the quantile is still a representative slow/fast sample
    for i in range(pick, -1, -1):
        ex = exemplars.get(str(i), exemplars.get(i))
        if ex is not None:
            return ex
    return None


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic count (events, bytes).  ``inc``/``add`` under a per-metric
    lock — no registry involvement on the hot path."""

    __slots__ = ("key", "_lock", "_value")
    kind = "counters"

    def __init__(self, key: str):
        self.key = key
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    add = inc

    @property
    def value(self):
        return self._value

    def _snap(self, reset: bool):
        with self._lock:
            v = self._value
            if reset:
                self._value = 0
        return v

    def _merge(self, v) -> None:
        with self._lock:
            self._value += v


class Gauge:
    """Point-in-time level (queue depth, bytes resident)."""

    __slots__ = ("key", "_lock", "_value")
    kind = "gauges"

    def __init__(self, key: str):
        self.key = key
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v) -> None:
        self._value = v          # single store: GIL-atomic

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n=1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self):
        return self._value

    def _snap(self, reset: bool):
        return self._value       # gauges are levels: reset keeps them

    def _merge(self, v) -> None:
        self._value = v          # last writer wins (child is fresher)


class _Timer:
    __slots__ = ("_h", "_t0")

    def __init__(self, h):
        self._h = h

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self._h.observe(time.perf_counter() - self._t0)


class Histogram:
    """Fixed log2-bucket distribution (see module docstring).

    ``observe(v)`` is one bucket bump + per-bucket/total sums under the
    per-metric lock; ``time()`` is a context manager observing elapsed
    seconds.  With an active trace context the observed value's bucket
    also records a ``{trace_id, span_id, value}`` exemplar (last writer
    wins — the freshest sample is the one worth chasing)."""

    __slots__ = ("key", "_lock", "_buckets", "_bsums", "_count", "_sum",
                 "_exemplars")
    kind = "hists"

    def __init__(self, key: str):
        self.key = key
        self._lock = threading.Lock()
        self._buckets = [0] * N_BUCKETS
        self._bsums = [0.0] * N_BUCKETS
        self._count = 0
        self._sum = 0.0
        self._exemplars: dict[int, dict] = {}

    def observe(self, value: float) -> None:
        i = bucket_index(value)
        # inlined _context.current(): observe is the hottest instrument
        # call and the no-context probe must stay near-free
        s = _context._tls.stack
        ctx = s[-1] if s else None
        with self._lock:
            self._buckets[i] += 1
            self._bsums[i] += value
            self._count += 1
            self._sum += value
            if ctx is not None:
                self._exemplars[i] = {"trace_id": ctx.trace_id,
                                      "span_id": ctx.span_id,
                                      "value": value}

    def time(self) -> _Timer:
        return _Timer(self)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        with self._lock:
            b = {i: n for i, n in enumerate(self._buckets) if n}
            s = {i: v for i, v in enumerate(self._bsums) if self._buckets[i]}
        return quantile_from_buckets(b, q, s)

    def _snap(self, reset: bool):
        with self._lock:
            d = {"count": self._count, "sum": self._sum,
                 "buckets": {str(i): n for i, n in enumerate(self._buckets)
                             if n},
                 "bsums": {str(i): s for i, s in enumerate(self._bsums)
                           if self._buckets[i]}}
            if self._exemplars:
                d["exemplars"] = {str(i): dict(ex)
                                  for i, ex in self._exemplars.items()}
            if reset:
                self._buckets = [0] * N_BUCKETS
                self._bsums = [0.0] * N_BUCKETS
                self._count = 0
                self._sum = 0.0
                self._exemplars = {}
        return d

    def _merge(self, d) -> None:
        with self._lock:
            self._count += int(d.get("count", 0))
            self._sum += float(d.get("sum", 0.0))
            for k, n in d.get("buckets", {}).items():
                self._buckets[int(k)] += int(n)
            for k, s in d.get("bsums", {}).items():
                self._bsums[int(k)] += float(s)
            for k, ex in d.get("exemplars", {}).items():
                if isinstance(ex, dict):
                    self._exemplars[int(k)] = dict(ex)


class _Null:
    """Shared no-op instrument: the entire cost of ``REPRO_OBS=off``."""

    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0

    def inc(self, n=1):
        pass

    add = inc
    dec = inc

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def quantile(self, q):
        return 0.0

    def time(self):
        return _NULL_TIMER


class _NullTimer:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        pass


NULL = _Null()
_NULL_TIMER = _NullTimer()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class Registry:
    """One process's metric namespace (module-level :data:`REGISTRY` is the
    default; tests may instantiate their own)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, labels: Optional[dict]):
        key = format_key(name, labels)
        m = self._metrics.get(key)          # GIL-atomic read, no lock
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(key)
                    self._metrics[key] = m
        if type(m) is not cls:
            raise TypeError(f"{key!r} is a {type(m).__name__}, "
                            f"not a {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def get(self, key: str):
        """The instrument registered under a canonical key, or None."""
        return self._metrics.get(key)

    # -- snapshots -------------------------------------------------------

    def snapshot(self, reset: bool = False) -> dict:
        """JSON-able ``{"counters": {...}, "gauges": {...}, "hists": {...}}``.
        ``reset=True`` zeroes counters/histograms after reading (delta
        snapshots — the child-process folding protocol)."""
        with self._lock:
            metrics = list(self._metrics.values())
        out: dict = {"counters": {}, "gauges": {}, "hists": {}}
        for m in metrics:
            out[m.kind][m.key] = m._snap(reset)
        return out

    def merge(self, snap: dict) -> None:
        """Fold a snapshot (typically a worker's reset-delta) into this
        registry: counters and histogram buckets add, gauges last-write."""
        for kind, cls in (("counters", Counter), ("gauges", Gauge),
                          ("hists", Histogram)):
            for key, val in (snap.get(kind) or {}).items():
                name, labels = parse_key(key)
                self._get(cls, name, labels)._merge(val)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    def render(self, snap: Optional[dict] = None) -> str:
        """Human-readable dump (obstat's one-shot mode)."""
        snap = snap if snap is not None else self.snapshot()
        lines = []
        for key in sorted(snap.get("counters", {})):
            lines.append(f"{key} {snap['counters'][key]}")
        for key in sorted(snap.get("gauges", {})):
            lines.append(f"{key} {snap['gauges'][key]}")
        for key in sorted(snap.get("hists", {})):
            h = snap["hists"][key]
            n = int(h.get("count", 0))
            mean = h.get("sum", 0.0) / n if n else 0.0
            p50 = quantile_from_buckets(h.get("buckets", {}), 0.50,
                                        h.get("bsums"))
            p99 = quantile_from_buckets(h.get("buckets", {}), 0.99,
                                        h.get("bsums"))
            lines.append(f"{key} count={n} mean={mean:.6g} "
                         f"p50={p50:.6g} p99={p99:.6g}")
        return "\n".join(lines)


REGISTRY = Registry()
