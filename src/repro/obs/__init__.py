"""repro.obs — unified metrics, tracing, and access telemetry.

The observability layer for the whole stack (DESIGN.md §13, §16): a
process-wide lock-cheap metrics registry (:mod:`repro.obs.metrics`),
span tracing with Chrome trace-event export and cross-process
traceparent propagation (:mod:`repro.obs.trace`,
:mod:`repro.obs.context`), a persistent access-heat log
(:mod:`repro.obs.heat`), rolling-window SLOs (:mod:`repro.obs.slo`),
a continuous sampling profiler with span-attributed flamegraphs and
memory watermarks (:mod:`repro.obs.profile`), a crash flight recorder
(:mod:`repro.obs.flight`), and RBSP ``STATS``/``PROF`` views served by
:class:`repro.remote.BasketServer` and read by ``python -m repro.obs``
/ ``tools/obstat.py``.

Call-site idiom — acquire the instrument *per event* through the helpers
here, so the ``REPRO_OBS`` gate (env at import, runtime via
:func:`set_enabled`) applies immediately and a disabled site costs one
flag check plus a no-op call::

    from repro import obs

    obs.counter("server.reads", branch=name).inc()
    with obs.histogram("engine.pack_s", algo=cfg.algo).time():
        ...
    with obs.trace.span("ckpt.save", step=step):
        ...

Default-on: instruments are live unless ``REPRO_OBS=off``.  The CI
overhead gate (benchmarks/fig_obs.py) holds the instrumented fig_zerocopy
quick run within 2% of the disabled run.
"""

from __future__ import annotations

from repro.obs import context, flight, metrics, profile, trace
from repro.obs.metrics import (
    NULL, REGISTRY, Registry,
    enabled, set_enabled, format_key, parse_key, quantile_from_buckets,
    exemplar_for_quantile,
)

__all__ = [
    "metrics", "trace", "context", "profile", "flight",
    "REGISTRY", "Registry", "NULL",
    "counter", "gauge", "histogram", "snapshot", "merge",
    "enabled", "set_enabled", "format_key", "parse_key",
    "quantile_from_buckets", "exemplar_for_quantile",
]


def counter(name: str, **labels):
    """Process-wide counter (no-op instrument when obs is disabled)."""
    if not metrics.enabled():
        return NULL
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels):
    if not metrics.enabled():
        return NULL
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels):
    if not metrics.enabled():
        return NULL
    return REGISTRY.histogram(name, **labels)


def snapshot(reset: bool = False) -> dict:
    """Snapshot of the process-wide registry (see Registry.snapshot)."""
    return REGISTRY.snapshot(reset=reset)


def merge(snap: dict) -> None:
    """Fold a worker's delta snapshot into the process-wide registry."""
    REGISTRY.merge(snap)
