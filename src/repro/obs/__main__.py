"""obstat — the observability CLI (``python -m repro.obs``).

Modes against a live :class:`repro.remote.BasketServer` (over the RBSP
``STATS``/``PROF`` verbs — no container path needed, just host:port):

one-shot dump (default)::

    python -m repro.obs HOST:PORT            # rendered
    python -m repro.obs HOST:PORT --json     # raw snapshot JSON

watch (top-N hot branches + per-verb request latency, delta per tick)::

    python -m repro.obs HOST:PORT --watch [--top 10] [--interval 2]

trace capture window (drain, wait, drain -> Chrome trace JSON)::

    python -m repro.obs HOST:PORT --trace out.json [--duration 5]

continuous profiling (DESIGN.md §17; ``capture`` = start, wait
``--duration``, fetch, stop — one-shot flamegraph)::

    python -m repro.obs HOST:PORT --prof capture --prof-out flame.folded
    python -m repro.obs HOST:PORT --prof start [--hz 67] [--mem]
    python -m repro.obs HOST:PORT --prof fetch --prof-out prof.speedscope.json
    python -m repro.obs HOST:PORT --prof stop

stitch multi-process captures into one timeline (DESIGN.md §16)::

    python -m repro.obs --stitch merged.json client.json server.json

render a crash flight-recorder bundle (no target needed)::

    python -m repro.obs --postmortem artifacts/flight/flight-123.json

Without a target, the one-shot mode dumps *this* process's registry —
mostly useful under ``python -m repro.obs --json`` in scripts and tests.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.obs import REGISTRY, metrics, profile, trace

# what --watch renders: poll only these prefixes instead of shipping the
# whole registry each tick (the STATS "filter" key; bare polls unchanged)
WATCH_PREFIXES = ["server.", "remote.", "repair.", "bfile.", "obs."]


def _parse_target(target: str) -> tuple[str, int]:
    host, _, port = target.rpartition(":")
    if not host or not port.isdigit():
        raise SystemExit(f"target must be HOST:PORT, got {target!r}")
    return host, int(port)


def _fetch(target: str, want_trace: bool = False, filter=None,
           want_profile: bool = False) -> dict:
    from repro.remote.client import fetch_stats
    host, port = _parse_target(target)
    return fetch_stats(host, port, trace=want_trace, filter=filter,
                       profile=want_profile)


def _hist_stats(h: dict) -> tuple[int, float, float, float]:
    n = int(h.get("count", 0))
    mean = h.get("sum", 0.0) / n if n else 0.0
    b = h.get("buckets", {})
    s = h.get("bsums")
    return (n, mean, metrics.quantile_from_buckets(b, 0.50, s),
            metrics.quantile_from_buckets(b, 0.99, s))


def _hist_delta(cur: dict, prev: dict) -> dict:
    """Per-tick histogram delta (counts can only grow)."""
    pb = prev.get("buckets", {})
    ps = prev.get("bsums", {})
    buckets = {k: int(v) - int(pb.get(k, 0))
               for k, v in cur.get("buckets", {}).items()
               if int(v) - int(pb.get(k, 0)) > 0}
    bsums = {k: float(cur.get("bsums", {}).get(k, 0.0))
             - float(ps.get(k, 0.0)) for k in buckets}
    d = {"count": int(cur.get("count", 0)) - int(prev.get("count", 0)),
         "sum": float(cur.get("sum", 0.0)) - float(prev.get("sum", 0.0)),
         "buckets": buckets, "bsums": bsums}
    if cur.get("exemplars"):
        d["exemplars"] = cur["exemplars"]
    return d


def hot_branches(counters: dict, prev: dict, top: int) -> list[tuple]:
    """Top-N ``server.reads{...}`` rows by this tick's delta (total read
    count breaks ties, so a cold tick still shows the historical ranking).
    Returns ``[(branch, path, delta, total), ...]``."""
    rows = []
    for key, total in counters.items():
        name, labels = metrics.parse_key(key)
        if name != "server.reads":
            continue
        delta = int(total) - int(prev.get(key, 0))
        rows.append((labels.get("branch", "?"), labels.get("path", "?"),
                     delta, int(total)))
    rows.sort(key=lambda r: (-r[2], -r[3], r[0]))
    return rows[:top]


def fault_rows(counters: dict, prev: dict) -> list[tuple[str, int, int]]:
    """The robustness counters (DESIGN.md §14) as ``(label, delta, total)``
    rows — retries by reason, hedges by outcome, server sheds and idle
    reaps, corrupt-basket quarantines.  Zero-total rows are omitted; a
    healthy system shows nothing here."""
    want = ("remote.retries", "remote.hedge", "server.shed",
            "server.idle_closed", "bfile.corrupt_baskets")
    rows = []
    for key, total in counters.items():
        name, labels = metrics.parse_key(key)
        if name not in want:
            continue
        label = name + "".join(f"[{v}]" for _k, v in sorted(labels.items()))
        rows.append((label, int(total) - int(prev.get(key, 0)), int(total)))
    rows.sort(key=lambda r: (-r[1], -r[2], r[0]))
    return rows


def repair_rows(counters: dict, prev: dict) -> list[tuple[str, int, int]]:
    """Self-healing activity (DESIGN.md §15) as ``(label, delta, total)``
    rows — every counter under the ``repair.`` prefix: in-place heals,
    transient re-read saves, heal failures, scrub progress/finds, and
    anti-entropy pulls.  Zero-total rows are omitted."""
    rows = []
    for key, total in counters.items():
        name, labels = metrics.parse_key(key)
        if not name.startswith("repair."):
            continue
        label = name + "".join(f"[{v}]" for _k, v in sorted(labels.items()))
        rows.append((label, int(total) - int(prev.get(key, 0)), int(total)))
    rows.sort(key=lambda r: (-r[1], -r[2], r[0]))
    return rows


def profiler_rows(prof: dict, prev_prof: dict,
                  top: int) -> list[tuple[str, int, int]]:
    """Top-N functions by *self*-sample delta this tick (total self
    samples breaks ties) from the STATS ``profile.self`` table —
    ``[(function, delta, total), ...]``.  Empty when the profiler is off
    or has no samples, so the section hides like faults/self-healing."""
    if not prof or not prof.get("active"):
        return []
    cur = prof.get("self") or {}
    prev = (prev_prof or {}).get("self") or {}
    rows = [(fn, int(total) - int(prev.get(fn, 0)), int(total))
            for fn, total in cur.items()]
    rows.sort(key=lambda r: (-r[1], -r[2], r[0]))
    return rows[:top]


def _render_watch(snap: dict, prev_snap: dict, body: dict, top: int,
                  interval: float, prev_prof: dict = {}) -> str:
    lines = [f"repro.obs watch — gen {body.get('gen')} pid {body.get('pid')} "
             f"uptime {body.get('uptime_s', 0.0):.0f}s "
             f"(tick {interval:g}s)"]
    srv = body.get("server") or {}
    if srv:
        lines.append("  " + "  ".join(f"{k}={v}" for k, v in sorted(srv.items())))
    faults = fault_rows(snap.get("counters", {}),
                        prev_snap.get("counters", {}))
    if faults:
        lines.append("")
        lines.append("  faults/degradation (delta per tick):")
        for label, delta, total in faults:
            lines.append(f"    {label:<40} +{delta:<8} total {total}")
    repairs = repair_rows(snap.get("counters", {}),
                          prev_snap.get("counters", {}))
    if repairs:
        lines.append("")
        lines.append("  self-healing (delta per tick):")
        for label, delta, total in repairs:
            lines.append(f"    {label:<40} +{delta:<8} total {total}")
    lines.append("")
    lines.append(f"  hot branches (top {top}, reads/tick):")
    rows = hot_branches(snap.get("counters", {}),
                        prev_snap.get("counters", {}), top)
    if not rows:
        lines.append("    (no reads yet)")
    for branch, path, delta, total in rows:
        lines.append(f"    {branch:<24} {path:<28} +{delta:<8} total {total}")
    lines.append("")
    lines.append("  request latency (per verb, this tick):")
    hists = snap.get("hists", {})
    prev_h = prev_snap.get("hists", {})
    any_verb = False
    for key in sorted(hists):
        name, labels = metrics.parse_key(key)
        if name != "server.request_s":
            continue
        d = _hist_delta(hists[key], prev_h.get(key, {}))
        src = d if d["count"] else hists[key]
        n, mean, p50, p99 = _hist_stats(src)
        scope = "tick" if d["count"] else "all"
        ex = metrics.exemplar_for_quantile(src, 0.99)
        ex_s = f" ex={ex['trace_id'][:12]}" if ex else ""
        lines.append(f"    {labels.get('verb', '?'):<8} n={n:<7} ({scope}) "
                     f"mean={mean * 1e3:.3f}ms p50={p50 * 1e3:.3f}ms "
                     f"p99={p99 * 1e3:.3f}ms{ex_s}")
        any_verb = True
    if not any_verb:
        lines.append("    (no requests yet)")
    prof = body.get("profile") or {}
    prows = profiler_rows(prof, prev_prof, top)
    if prows:
        lines.append("")
        lines.append(f"  profiler (self samples/tick, {prof.get('hz', 0):g} Hz, "
                     f"{prof.get('samples', 0)} total):")
        for fn, delta, total in prows:
            lines.append(f"    {fn:<56} +{delta:<8} total {total}")
    slo = body.get("slo")
    if slo:
        lines.append("")
        lines.append("  SLO (rolling window):")
        for v in slo:
            status = "OK " if v.get("ok") else "VIOLATED"
            parts = [f"    {v.get('name', '?'):<20} {status}"]
            if "p99_s" in v:
                parts.append(f"p99={v['p99_s'] * 1e3:.3f}ms"
                             f"/{v['p99_limit_s'] * 1e3:.0f}ms")
            if "error_rate" in v:
                parts.append(f"err={v['errors']}/{v['requests']}"
                             f" burn={v.get('burn', 0.0):.2f}x")
            parts.append(f"span={v.get('span_s', 0.0):.1f}s")
            lines.append(" ".join(parts))
    return "\n".join(lines)


def _render_postmortem(doc: dict) -> str:
    """Human-readable view of a flight-recorder bundle (DESIGN.md §17)."""
    lines = [f"repro flight recorder — {doc.get('reason', '?')}",
             f"  pid {doc.get('pid')}  ts {doc.get('ts', 0.0):.3f}  "
             f"argv {' '.join(doc.get('argv') or []) or '?'}"]
    exc = doc.get("exception")
    if exc:
        lines.append("")
        lines.append(f"  exception: {exc.get('type')}: {exc.get('message')}")
        for ln in "".join(exc.get("traceback") or []).rstrip().splitlines():
            lines.append(f"    {ln}")
    threads = doc.get("threads") or []
    if threads:
        lines.append("")
        lines.append(f"  threads at death ({len(threads)}):")
        for t in threads:
            span = f"  span={t['span']}" if t.get("span") else ""
            tid = f" trace={t['trace_id'][:12]}" if t.get("trace_id") else ""
            lines.append(f"    {t.get('name', '?')}{span}{tid}")
            tail = (t.get("stack") or [])[-2:]
            for frame in "".join(tail).rstrip().splitlines():
                lines.append(f"      {frame.strip()}")
    prof = doc.get("profile") or {}
    selfs = sorted(profile.self_counts(prof).items(),
                   key=lambda kv: -kv[1])[:10]
    if selfs:
        lines.append("")
        lines.append(f"  profile ({prof.get('samples', 0)} samples, "
                     f"top self):")
        for fn, n in selfs:
            lines.append(f"    {fn:<56} {n}")
    marks = doc.get("watermarks") or {}
    if marks:
        lines.append("")
        lines.append("  memory watermarks:")
        for phase, w in sorted(marks.items()):
            lines.append(f"    {phase:<24} peak {w.get('peak_bytes', 0):>12} B"
                         f"  x{w.get('count', 0)} ({w.get('src', '?')})")
    slo = doc.get("slo")
    if slo:
        lines.append("")
        lines.append("  SLO verdicts at death:")
        for v in slo:
            status = "OK " if v.get("ok") else "VIOLATED"
            lines.append(f"    {v.get('name', '?'):<20} {status}")
    n_snap = len(doc.get("snapshots") or [])
    n_ev = len(doc.get("trace_events") or [])
    counters = (doc.get("final_metrics") or {}).get("counters") or {}
    lines.append("")
    lines.append(f"  ring: {n_snap} metric snapshots, {n_ev} trace events, "
                 f"{len(counters)} counters at death")
    return "\n".join(lines)


def _run_prof(target: str, action: str, hz: float, mem: bool,
              duration: float, out: str | None) -> int:
    """The --prof mode: drive a live server's sampling profiler over the
    PROF verb.  ``capture`` is the one-shot flamegraph: start, sample for
    ``duration``, fetch+reset, stop, export."""
    from repro.remote.client import request_prof
    host, port = _parse_target(target)
    kw = {"hz": hz or None, "mem": mem}
    if action == "capture":
        request_prof(host, port, action="start", **kw)
        time.sleep(duration)
        body = request_prof(host, port, action="fetch", reset=True)
        request_prof(host, port, action="stop")
    elif action == "fetch":
        body = request_prof(host, port, action="fetch")
    elif action in ("start", "stop", "status"):
        body = request_prof(host, port, action=action, **kw)
        print(json.dumps(body.get("profile") or body, sort_keys=True))
        return 0
    else:
        raise SystemExit(f"unknown --prof action {action!r}")
    doc = body.get("profile") or {}
    if out:
        if out.endswith(".json"):
            profile.write_speedscope(out, doc, name=target)
        else:
            profile.write_collapsed(out, doc)
        print(f"wrote {doc.get('samples', 0)} samples "
              f"({len(doc.get('folds') or {})} stacks) to {out}")
    else:
        sys.stdout.write(profile.collapsed(doc))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="dump / watch / trace repro observability "
                    "(RBSP STATS verb)")
    ap.add_argument("target", nargs="?", default=None,
                    help="HOST:PORT of a live BasketServer "
                         "(omit: dump this process's registry)")
    ap.add_argument("--json", action="store_true",
                    help="raw snapshot JSON instead of rendered text")
    ap.add_argument("--watch", action="store_true",
                    help="refresh a top-N hot-branch / latency view")
    ap.add_argument("--top", type=int, default=10, metavar="N",
                    help="branches shown in --watch (default 10)")
    ap.add_argument("--interval", type=float, default=2.0, metavar="S",
                    help="--watch poll period (default 2s)")
    ap.add_argument("--count", type=int, default=0, metavar="N",
                    help="stop --watch after N ticks (0 = forever)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="capture a span window to Chrome trace JSON")
    ap.add_argument("--duration", type=float, default=5.0, metavar="S",
                    help="--trace capture window (default 5s)")
    ap.add_argument("--stitch", nargs="+", metavar="JSON", default=None,
                    help="OUT.json CAPTURE.json [CAPTURE.json ...]: merge "
                         "per-process Chrome captures into one timeline")
    ap.add_argument("--prof", metavar="ACTION", default=None,
                    choices=["start", "stop", "status", "fetch", "capture"],
                    help="drive the server's sampling profiler over the "
                         "PROF verb (capture = start/wait --duration/"
                         "fetch/stop)")
    ap.add_argument("--hz", type=float, default=0.0,
                    help="--prof start/capture sample rate "
                         "(default: server default)")
    ap.add_argument("--mem", action="store_true",
                    help="--prof start/capture: arm memory watermarks")
    ap.add_argument("--prof-out", metavar="OUT", default=None,
                    help="--prof fetch/capture output (*.json = speedscope, "
                         "else collapsed stacks; default: stdout)")
    ap.add_argument("--postmortem", metavar="BUNDLE.json", default=None,
                    help="render a crash flight-recorder bundle "
                         "(--json dumps it raw)")
    args = ap.parse_args(argv)

    if args.postmortem is not None:
        from repro.obs import flight
        doc = flight.load_bundle(args.postmortem)
        if args.json:
            json.dump(doc, sys.stdout, sort_keys=True)
            print()
        else:
            print(_render_postmortem(doc))
        return 0

    if args.prof is not None:
        if args.target is None:
            ap.error("--prof needs a HOST:PORT target")
        return _run_prof(args.target, args.prof, args.hz, args.mem,
                         args.duration, args.prof_out)

    if args.stitch is not None:
        if len(args.stitch) < 2:
            ap.error("--stitch needs OUT.json plus at least one capture")
        out_path, inputs = args.stitch[0], args.stitch[1:]
        caps = []
        for path in inputs:
            with open(path) as f:
                caps.append(json.load(f))
        merged = trace.stitch(*caps)
        n = trace.export_chrome(out_path, events=merged)
        print(f"stitched {len(inputs)} captures -> {n} events "
              f"in {out_path}")
        return 0

    if args.trace is not None:
        if args.target is None:
            time.sleep(args.duration)
            n = trace.export_chrome(args.trace)
        else:
            _fetch(args.target, want_trace=True)     # discard pre-window
            time.sleep(args.duration)
            body = _fetch(args.target, want_trace=True)
            n = trace.export_chrome(args.trace,
                                    events=body.get("trace_events") or [])
        print(f"wrote {n} trace events to {args.trace}")
        return 0

    if args.watch:
        if args.target is None:
            ap.error("--watch needs a HOST:PORT target")
        prev: dict = {}
        prev_prof: dict = {}
        tick = 0
        try:
            while True:
                body = _fetch(args.target, filter=WATCH_PREFIXES,
                              want_profile=True)
                snap = body.get("metrics") or {}
                out = _render_watch(snap, prev, body, args.top,
                                    args.interval, prev_prof)
                # ANSI clear+home when interactive; plain append otherwise
                if sys.stdout.isatty():
                    sys.stdout.write("\x1b[2J\x1b[H")
                print(out, flush=True)
                prev = snap
                prev_prof = body.get("profile") or {}
                tick += 1
                if args.count and tick >= args.count:
                    return 0
                time.sleep(args.interval)
        except KeyboardInterrupt:
            return 0

    if args.target is None:
        snap = REGISTRY.snapshot()
        body = {"metrics": snap}
    else:
        body = _fetch(args.target)
        snap = body.get("metrics") or {}
    if args.json:
        json.dump(body, sys.stdout, sort_keys=True)
        print()
    else:
        if "gen" in body:
            print(f"# gen {body['gen']} pid {body.get('pid')} "
                  f"uptime {body.get('uptime_s', 0.0):.0f}s")
        for k, v in sorted((body.get("server") or {}).items()):
            print(f"server.{k} {v}")
        rendered = REGISTRY.render(snap)
        if rendered:
            print(rendered)
    return 0


if __name__ == "__main__":
    sys.exit(main())
