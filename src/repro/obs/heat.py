"""Persistent access-heat telemetry: the repacker's evidence base.

PR 6 gave the server in-memory ``server.reads{path,branch}`` counters;
they die with the process, and the ROADMAP's background-repacker item
needs *durable* per-branch/basket access evidence to drive tier
migration ("Optimizing ROOT IO For Analysis" makes the same point:
layout decisions follow measured access patterns, not guesses).

:class:`HeatLog` keeps, per served container, a per-branch record of

* ``reads`` / ``bytes`` — cumulative basket reads and payload bytes
  (monotonic, survive restarts: the long-term popularity signal),
* ``heat`` — a half-life-decayed EWMA of read counts
  (``heat = heat * 2^(-dt/halflife) + n``): the *recency-weighted*
  signal that distinguishes "hot this hour" from "hot last month",
* ``baskets`` — per-basket read counts, so a repacker can see *which
  region* of a branch is hot, not just that the branch is.

State is folded to a JSON sidecar ``<container>.heat`` next to the
container with the PR 7/8 atomic commit idiom (spool to ``.tmp``,
``fsync`` the file, ``os.replace``, ``fsync`` the directory), so a
crash mid-flush leaves the previous sidecar intact — old-or-new, never
torn.  On first touch of a container the existing sidecar is adopted
(with its ``heat`` decayed across the downtime), so a server restart
resumes the telemetry instead of resetting it.

The server calls :meth:`record` on every READV (cheap: dict updates
under one lock) and :meth:`maybe_flush` opportunistically; STATS
exports :meth:`snapshot` on request (``{"heat": true}``); and
``tools/heatmap.py`` reads either the sidecars or the STATS view.
"""

from __future__ import annotations

import json
import math
import os
import threading
import time
from typing import Optional

from repro import obs

__all__ = ["HeatLog", "SIDECAR_SUFFIX", "load_sidecar", "rank_branches"]

SIDECAR_SUFFIX = ".heat"
_VERSION = 1


def _decay(heat: float, dt: float, halflife_s: float) -> float:
    if dt <= 0.0 or heat == 0.0:
        return heat
    return heat * math.pow(2.0, -dt / halflife_s)


def load_sidecar(path: str) -> Optional[dict]:
    """Parse one ``.heat`` sidecar; None if absent or unreadable (a
    corrupt sidecar must never take down the server — heat is advisory)."""
    try:
        with open(path, "r") as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(doc, dict) or doc.get("version") != _VERSION:
        return None
    if not isinstance(doc.get("branches"), dict):
        return None
    return doc


def rank_branches(doc: dict, now: Optional[float] = None) -> list[tuple]:
    """``[(branch, heat_now, reads, bytes), ...]`` hottest first, with
    each stored heat decayed to ``now``."""
    now = time.time() if now is None else now
    hl = float(doc.get("halflife_s") or 3600.0)
    rows = []
    for branch, rec in (doc.get("branches") or {}).items():
        heat = _decay(float(rec.get("heat", 0.0)),
                      now - float(rec.get("t", now)), hl)
        rows.append((branch, heat, int(rec.get("reads", 0)),
                     int(rec.get("bytes", 0))))
    rows.sort(key=lambda r: (-r[1], -r[2], r[0]))
    return rows


class HeatLog:
    """In-memory heat state for every container a server touches, with
    periodic durable folding to per-container sidecars."""

    def __init__(self, halflife_s: float = 3600.0,
                 flush_interval_s: float = 30.0,
                 max_baskets_per_branch: int = 4096):
        self.halflife_s = float(halflife_s)
        self.flush_interval_s = float(flush_interval_s)
        self.max_baskets_per_branch = int(max_baskets_per_branch)
        self._lock = threading.Lock()
        # abspath -> {"branches": {...}, "dirty": bool, "flushed_t": float}
        self._state: dict[str, dict] = {}

    # -- recording -------------------------------------------------------

    def _load_locked(self, path: str) -> dict:
        st = self._state.get(path)
        if st is not None:
            return st
        st = {"branches": {}, "dirty": False, "flushed_t": time.time()}
        doc = load_sidecar(path + SIDECAR_SUFFIX)
        if doc is not None:
            now = time.time()
            then = float(doc.get("updated_unix", now))
            for branch, rec in doc["branches"].items():
                st["branches"][branch] = {
                    "reads": int(rec.get("reads", 0)),
                    "bytes": int(rec.get("bytes", 0)),
                    "heat": _decay(float(rec.get("heat", 0.0)),
                                   now - float(rec.get("t", then)),
                                   self.halflife_s),
                    "t": now,
                    "baskets": {str(k): int(v) for k, v in
                                (rec.get("baskets") or {}).items()},
                }
            obs.counter("obs.heat.sidecar_loads").inc()
        self._state[path] = st
        return st

    def record(self, path: str, branch: str, baskets, nbytes: int) -> None:
        """Fold one READV's worth of reads: ``baskets`` is an iterable of
        basket indices served for ``branch`` from container ``path``."""
        path = os.path.abspath(path)
        idxs = list(baskets)
        if not idxs:
            return
        now = time.time()
        with self._lock:
            st = self._load_locked(path)
            rec = st["branches"].get(branch)
            if rec is None:
                rec = st["branches"][branch] = {
                    "reads": 0, "bytes": 0, "heat": 0.0, "t": now,
                    "baskets": {}}
            rec["reads"] += len(idxs)
            rec["bytes"] += int(nbytes)
            rec["heat"] = _decay(rec["heat"], now - rec["t"],
                                 self.halflife_s) + len(idxs)
            rec["t"] = now
            bk = rec["baskets"]
            for i in idxs:
                k = str(int(i))
                if k in bk or len(bk) < self.max_baskets_per_branch:
                    bk[k] = bk.get(k, 0) + 1
            st["dirty"] = True

    # -- durability ------------------------------------------------------

    def _commit(self, path: str, branches: dict) -> None:
        from repro.core.bfile import _fsync_dir
        sidecar = path + SIDECAR_SUFFIX
        doc = {"version": _VERSION, "halflife_s": self.halflife_s,
               "updated_unix": time.time(), "container": os.path.basename(path),
               "branches": branches}
        tmp = sidecar + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, sort_keys=True, separators=(",", ":"))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, sidecar)
        _fsync_dir(os.path.dirname(os.path.abspath(sidecar)))
        obs.counter("obs.heat.flushes").inc()

    def flush(self, path: Optional[str] = None) -> int:
        """Commit dirty state (one container, or all) to sidecars now;
        returns the number of sidecars written.  Flush failures (read-only
        media, deleted container dir) are swallowed after counting —
        telemetry must never break serving."""
        with self._lock:
            if path is not None:
                paths = [os.path.abspath(path)]
            else:
                paths = list(self._state)
            work = []
            for p in paths:
                st = self._state.get(p)
                if st is None or not st["dirty"]:
                    continue
                work.append((p, json.loads(json.dumps(st["branches"]))))
                st["dirty"] = False
                st["flushed_t"] = time.time()
        n = 0
        for p, branches in work:
            try:
                self._commit(p, branches)
                n += 1
            except OSError:
                obs.counter("obs.heat.flush_errors").inc()
        return n

    def maybe_flush(self) -> int:
        """Flush containers whose last durable fold is older than the
        flush interval (the server calls this from its request loop)."""
        now = time.time()
        with self._lock:
            due = [p for p, st in self._state.items()
                   if st["dirty"] and
                   now - st["flushed_t"] >= self.flush_interval_s]
        n = 0
        for p in due:
            n += self.flush(p)
        return n

    # -- export ----------------------------------------------------------

    def snapshot(self, top_baskets: int = 8) -> dict:
        """JSON-able view for STATS: per container (abspath), per branch
        aggregates plus the ``top_baskets`` hottest basket indices."""
        now = time.time()
        out: dict = {}
        with self._lock:
            for path, st in self._state.items():
                branches = {}
                for branch, rec in st["branches"].items():
                    hot = sorted(rec["baskets"].items(),
                                 key=lambda kv: (-kv[1], int(kv[0])))
                    branches[branch] = {
                        "reads": rec["reads"], "bytes": rec["bytes"],
                        "heat": _decay(rec["heat"], now - rec["t"],
                                       self.halflife_s),
                        "baskets_hot": dict(hot[:top_baskets]),
                    }
                out[path] = {"halflife_s": self.halflife_s,
                             "branches": branches}
        return out
