"""Crash flight recorder: the stack's black box (DESIGN.md §17).

A :class:`FlightRecorder` keeps a bounded ring of recent metric
snapshots (one per ``interval_s`` tick, ``capacity`` deep) and, when the
process dies — unhandled exception, SIGTERM, or an explicit
:func:`trigger` — dumps a post-mortem bundle: the snapshot ring, the
final registry state, the trace ring, every thread's live stack with its
active span, the profiler's folds and memory watermarks, and SLO
verdicts if an engine is attached.  ``python -m repro.obs --postmortem
bundle.json`` renders it.

Dumping is the crash path, so it must never make the crash worse: every
collection step is individually best-effort (a failure in one section
drops that section, not the bundle), the bundle writes tmp → rename, and
the previously-installed excepthook / SIGTERM handler still runs after
the dump — the recorder observes the death, it does not change it.

The *ticker* honors the ``REPRO_OBS`` gate (a disabled process records
no snapshots), but :func:`dump` itself always works — post-mortem
evidence from a crashing process is wanted precisely when everything
else is going wrong.

Bundle destination: explicit ``path`` > recorder ``dir`` >
``$REPRO_FLIGHT_DIR`` > ``artifacts/flight``.
"""

from __future__ import annotations

import json
import os
import signal
import sys
import threading
import time
import traceback
from collections import deque
from typing import Optional

from repro.obs import context as _context
from repro.obs import metrics as _metrics
from repro.obs import profile as _profile
from repro.obs import trace as _trace

__all__ = ["FlightRecorder", "install", "uninstall", "trigger", "recorder",
           "load_bundle", "DEFAULT_DIR_ENV"]

DEFAULT_DIR_ENV = "REPRO_FLIGHT_DIR"
BUNDLE_KIND = "repro-flight"

_ctl_lock = threading.Lock()
_recorder: Optional["FlightRecorder"] = None


def _best_effort(fn, default=None):
    try:
        return fn()
    except Exception:
        return default


class FlightRecorder:
    """One per process.  ``install()`` arms the death hooks; ``tick()``
    (or the background ticker started by ``start()``) feeds the ring."""

    def __init__(self, dir: Optional[str] = None, interval_s: float = 1.0,
                 capacity: int = 120, slo=None):
        self.dir = dir
        self.interval_s = max(float(interval_s), 0.05)
        self.slo = slo                       # an SLOEngine, or None
        self._ring: deque = deque(maxlen=max(int(capacity), 1))
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._prev_excepthook = None
        self._prev_sigterm = None
        self._installed = False
        self._dumped = False                 # one bundle per death, not two

    # -- the ring --------------------------------------------------------

    def tick(self) -> None:
        """Append one metrics snapshot to the ring (no-op when obs is
        disabled — the ticker must not resurrect a gated registry)."""
        if not _metrics.enabled():
            return
        snap = _best_effort(lambda: _metrics.REGISTRY.snapshot())
        if snap is None:
            return
        with self._lock:
            self._ring.append({"ts": time.time(), "metrics": snap})

    def start(self) -> "FlightRecorder":
        """Start the background ticker thread (daemon)."""
        if self._thread is not None:
            return self

        def _run():
            while not self._stop.wait(self.interval_s):
                self.tick()

        self._thread = threading.Thread(target=_run, daemon=True,
                                        name="obs-flight")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=2.0)

    # -- death hooks -----------------------------------------------------

    def install(self) -> "FlightRecorder":
        """Chain ``sys.excepthook`` and (main thread only) SIGTERM.  The
        previous hooks still run after the dump."""
        if self._installed:
            return self
        self._prev_excepthook = sys.excepthook

        def _hook(exc_type, exc, tb):
            self.dump("unhandled-exception", exc=(exc_type, exc, tb))
            prev = self._prev_excepthook or sys.__excepthook__
            prev(exc_type, exc, tb)

        sys.excepthook = _hook
        try:
            self._prev_sigterm = signal.signal(signal.SIGTERM, self._on_term)
        except ValueError:       # not the main thread: excepthook-only mode
            self._prev_sigterm = None
        self._installed = True
        return self

    def uninstall(self) -> None:
        if not self._installed:
            return
        if self._prev_excepthook is not None:
            sys.excepthook = self._prev_excepthook
        if self._prev_sigterm is not None:
            try:
                signal.signal(signal.SIGTERM, self._prev_sigterm)
            except ValueError:
                pass
        self._installed = False

    def _on_term(self, signum, frame) -> None:
        self.dump("sigterm")
        prev = self._prev_sigterm
        if callable(prev):
            prev(signum, frame)
        elif prev == signal.SIG_DFL:
            # re-deliver with the default disposition so the exit status
            # still says "killed by SIGTERM"
            try:
                signal.signal(signal.SIGTERM, signal.SIG_DFL)
                os.kill(os.getpid(), signal.SIGTERM)
                return
            except (OSError, ValueError):
                pass
            raise SystemExit(128 + int(signum))
        else:
            raise SystemExit(128 + int(signum))

    # -- the bundle ------------------------------------------------------

    def _out_path(self, path: Optional[str]) -> str:
        if path:
            return path
        d = self.dir or os.environ.get(DEFAULT_DIR_ENV) or "artifacts/flight"
        return os.path.join(
            d, f"flight-{os.getpid()}-{int(time.time() * 1000)}.json")

    def _threads_table(self) -> list[dict]:
        names = {t.ident: t.name for t in threading.enumerate()}
        stacks = getattr(_profile, "_span_stacks", {})
        out = []
        for tid, frame in sys._current_frames().items():
            row: dict = {"tid": tid, "name": names.get(tid, f"tid-{tid}")}
            row["stack"] = _best_effort(
                lambda: traceback.format_stack(frame), [])
            st = stacks.get(tid)
            if st:
                try:
                    name, trace_id = st[-1]
                    row["span"] = name
                    if trace_id:
                        row["trace_id"] = trace_id
                except (IndexError, ValueError):
                    pass
            out.append(row)
        return out

    def build_bundle(self, reason: str, exc=None) -> dict:
        """Assemble (but do not write) the post-mortem document.  Every
        section is individually best-effort."""
        with self._lock:
            snaps = list(self._ring)
        doc: dict = {
            "version": 1, "kind": BUNDLE_KIND, "reason": reason,
            "ts": time.time(), "pid": os.getpid(),
            "argv": _best_effort(lambda: list(sys.argv), []),
            "snapshots": snaps,
            "final_metrics": _best_effort(
                lambda: _metrics.REGISTRY.snapshot(), {}),
            "trace_events": _best_effort(lambda: _trace.events(), []),
            "threads": _best_effort(self._threads_table, []),
            "profile": _best_effort(lambda: _profile.snapshot(), {}),
            "watermarks": _best_effort(_profile.watermarks, {}),
        }
        if exc is not None:
            exc_type, exc_val, tb = exc
            doc["exception"] = {
                "type": getattr(exc_type, "__name__", str(exc_type)),
                "message": _best_effort(lambda: str(exc_val), ""),
                "traceback": _best_effort(
                    lambda: traceback.format_exception(exc_type, exc_val,
                                                       tb), []),
            }
        if self.slo is not None:
            doc["slo"] = _best_effort(self.slo.evaluate, None)
        tp = _best_effort(_context.current_traceparent)
        if tp:
            doc["traceparent"] = tp
        return doc

    def dump(self, reason: str, path: Optional[str] = None,
             exc=None, force: bool = False) -> Optional[str]:
        """Write the bundle; returns its path (None if the write failed —
        the crash path never raises).  A recorder dumps once per process
        death; ``force=True`` (the explicit-trigger path) always dumps."""
        with self._lock:
            if self._dumped and not force:
                return None
            self._dumped = not force
        out = self._out_path(path)
        try:
            os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
            doc = self.build_bundle(reason, exc=exc)
            tmp = f"{out}.tmp"
            with open(tmp, "w") as f:
                json.dump(doc, f, default=str)
            os.replace(tmp, out)
        except Exception:
            return None
        sys.stderr.write(f"[repro.obs.flight] {reason}: wrote {out}\n")
        return out


# -- module-level singleton --------------------------------------------------

def install(dir: Optional[str] = None, interval_s: float = 1.0,
            capacity: int = 120, slo=None,
            ticker: bool = True) -> FlightRecorder:
    """Arm the process flight recorder (idempotent: a second call returns
    the existing one)."""
    global _recorder
    with _ctl_lock:
        if _recorder is not None:
            return _recorder
        rec = FlightRecorder(dir=dir, interval_s=interval_s,
                             capacity=capacity, slo=slo).install()
        if ticker:
            rec.start()
        _recorder = rec
        return rec


def uninstall() -> None:
    """Disarm and drop the singleton (tests must not leak hooks into the
    harness)."""
    global _recorder
    with _ctl_lock:
        rec, _recorder = _recorder, None
    if rec is not None:
        rec.stop()
        rec.uninstall()


def recorder() -> Optional[FlightRecorder]:
    return _recorder


def trigger(reason: str = "manual",
            path: Optional[str] = None) -> Optional[str]:
    """Dump a bundle right now (installing a recorder on the fly if none
    is armed) — the operator's "capture the current state" hook."""
    rec = _recorder
    if rec is None:
        rec = FlightRecorder()
        rec.tick()
    return rec.dump(reason, path=path, force=True)


def load_bundle(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("kind") != BUNDLE_KIND:
        raise ValueError(f"{path} is not a {BUNDLE_KIND} bundle "
                         f"(kind={doc.get('kind')!r})")
    return doc
