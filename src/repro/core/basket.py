"""Baskets: the unit of compression (paper Fig. 1).

A *branch* (column) is serialized into one or more *baskets*; each basket is
independently preconditioned + compressed and carries enough metadata to be
decompressed in isolation — that independence is what enables the paper's
"simultaneous read and decompression for multiple physics events"
(thread-pool parallel reads in ``repro.data.reader``).

Basket metadata also carries an adler32 of the uncompressed bytes
(vectorized implementation — the CF-ZLIB checksum path), verified on read.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import codec as _codec
from .checksum import adler32_hw

__all__ = ["BasketMeta", "pack_basket", "unpack_basket", "split_array", "join_baskets"]


@dataclasses.dataclass(frozen=True)
class BasketMeta:
    """Everything needed to decompress one basket in isolation."""

    algo: str
    level: int
    precond: str
    orig_len: int        # raw serialized bytes (pre-preconditioner)
    stored_len: int      # codec-input bytes (post-preconditioner)
    comp_len: int        # on-disk bytes
    checksum: int        # adler32 of raw bytes
    entry_start: int = 0  # first entry (row) covered by this basket
    entry_count: int = 0
    has_dict: bool = False

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "BasketMeta":
        return BasketMeta(**d)


def pack_basket(raw: bytes, cfg: _codec.CompressionConfig,
                entry_start: int = 0, entry_count: int = 0) -> tuple[bytes, BasketMeta]:
    """Precondition + compress one buffer; returns (payload, metadata)."""
    from . import precond as _precond
    staged = _precond.apply_precond(cfg.precond, raw) if cfg.precond != "none" else raw
    payload = _codec.get_codec(cfg.algo).compress(staged, cfg.level, cfg.dictionary) \
        if cfg.enabled else staged
    meta = BasketMeta(
        algo=cfg.algo if cfg.enabled else "none",
        level=cfg.level if cfg.enabled else 0,
        precond=cfg.precond,
        orig_len=len(raw),
        stored_len=len(staged),
        comp_len=len(payload),
        checksum=adler32_hw(raw),
        entry_start=entry_start,
        entry_count=entry_count,
        has_dict=cfg.dictionary is not None,
    )
    return payload, meta


def unpack_basket(payload: bytes, meta: BasketMeta,
                  dictionary: Optional[bytes] = None, verify: bool = True) -> bytes:
    """Invert :func:`pack_basket`; verifies the checksum unless disabled."""
    cfg = _codec.CompressionConfig(
        algo=meta.algo if meta.algo != "none" else "zlib",  # cfg validates algo; level 0 disables
        level=meta.level,
        precond=meta.precond,
        dictionary=dictionary if meta.has_dict else None,
    ) if meta.algo != "none" else _codec.CompressionConfig(algo="none", level=0, precond=meta.precond)
    raw = _codec.decompress(payload, meta.orig_len, cfg, stored_len=meta.stored_len)
    if len(raw) != meta.orig_len:
        raise ValueError(f"basket decoded {len(raw)} bytes, expected {meta.orig_len}")
    if verify and adler32_hw(raw) != meta.checksum:
        raise ValueError("basket checksum mismatch (corrupt data)")
    return raw


# ---------------------------------------------------------------------------
# Array <-> baskets
# ---------------------------------------------------------------------------

def split_array(arr: np.ndarray, target_basket_bytes: int = 1 << 20):
    """Split an array along axis 0 into basket-sized row chunks.

    Yields (entry_start, entry_count, bytes).  Row-granular so each basket
    maps to an entry range — the seekable-restart property the data
    pipeline's checkpoint cursor relies on.
    """
    arr = np.ascontiguousarray(arr)
    if arr.ndim == 0:
        yield 0, 1, arr.tobytes()
        return
    n = arr.shape[0]
    row_bytes = max(1, arr.nbytes // max(n, 1))
    rows_per = max(1, target_basket_bytes // row_bytes)
    for start in range(0, max(n, 1), rows_per):
        stop = min(start + rows_per, n)
        if start >= n:
            break
        yield start, stop - start, arr[start:stop].tobytes()
    if n == 0:
        yield 0, 0, b""


def join_baskets(chunks: list[bytes], dtype: str, shape: tuple) -> np.ndarray:
    buf = b"".join(chunks)
    return np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape).copy()
