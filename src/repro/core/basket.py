"""Baskets: the unit of compression (paper Fig. 1).

A *branch* (column) is serialized into one or more *baskets*; each basket is
independently preconditioned + compressed and carries enough metadata to be
decompressed in isolation — that independence is what enables the paper's
"simultaneous read and decompression for multiple physics events"
(thread-pool parallel reads in ``repro.data.reader``).

Basket metadata also carries an adler32 of the uncompressed bytes
(vectorized implementation — the CF-ZLIB checksum path), verified on read.

Zero-copy data plane: ``split_array`` yields buffer-protocol *views* of the
source array (no per-basket ``tobytes()``), ``pack_basket`` accepts any
buffer-protocol object, and ``unpack_basket_into`` decodes a basket directly
into a caller-provided destination slice — so a branch read allocates its
output array exactly once and baskets scatter into it with no per-basket
``bytes`` and no final concatenation.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import codec as _codec
from .checksum import adler32_hw

__all__ = ["BasketMeta", "ChecksumError",
           "pack_basket", "unpack_basket", "unpack_basket_into",
           "split_array", "join_baskets", "byte_offsets"]


class ChecksumError(ValueError):
    """Decoded basket bytes fail their stored adler32 — corrupt payload.

    A distinct type (not a plain ValueError) so the robustness layer can
    tell *content corruption* apart from caller mistakes: a remote reader
    re-fetches the basket from another replica, a local reader raises a
    structured ``CorruptBasketError`` naming branch/index/offset."""


def byte_offsets(lens) -> tuple[list[int], int]:
    """Destination byte offset of each basket from its ``orig_len``
    (cumulative), plus the total — the scatter map every zero-copy branch
    read uses."""
    offs, pos = [], 0
    for n in lens:
        offs.append(pos)
        pos += int(n)
    return offs, pos


def _nbytes(buf) -> int:
    """Byte length of any buffer-protocol object."""
    if isinstance(buf, (bytes, bytearray)):
        return len(buf)
    return memoryview(buf).nbytes


@dataclasses.dataclass(frozen=True)
class BasketMeta:
    """Everything needed to decompress one basket in isolation."""

    algo: str
    level: int
    precond: str
    orig_len: int        # raw serialized bytes (pre-preconditioner)
    stored_len: int      # codec-input bytes (post-preconditioner)
    comp_len: int        # on-disk bytes
    checksum: int        # adler32 of raw bytes
    entry_start: int = 0  # first entry (row) covered by this basket
    entry_count: int = 0
    has_dict: bool = False

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "BasketMeta":
        return BasketMeta(**d)


def pack_basket(raw, cfg: _codec.CompressionConfig,
                entry_start: int = 0, entry_count: int = 0) -> tuple[bytes, BasketMeta]:
    """Precondition + compress one buffer; returns (payload, metadata).

    ``raw`` may be any buffer-protocol object; it is never copied up front
    (the preconditioner/codec read it through zero-copy views).  The
    returned payload is bytes-like; for the ``none``/``none`` identity
    configuration it may alias ``raw`` itself."""
    from . import precond as _precond
    staged = _precond.apply_precond(cfg.precond, raw) if cfg.precond != "none" else raw
    payload = _codec.get_codec(cfg.algo).compress(staged, cfg.level, cfg.dictionary) \
        if cfg.enabled else staged
    meta = BasketMeta(
        algo=cfg.algo if cfg.enabled else "none",
        level=cfg.level if cfg.enabled else 0,
        precond=cfg.precond,
        orig_len=_nbytes(raw),
        stored_len=_nbytes(staged),
        comp_len=_nbytes(payload),
        checksum=adler32_hw(raw),
        entry_start=entry_start,
        entry_count=entry_count,
        has_dict=cfg.dictionary is not None,
    )
    return payload, meta


def _meta_cfg(meta: BasketMeta, dictionary: Optional[bytes]) -> _codec.CompressionConfig:
    if meta.algo == "none":
        return _codec.CompressionConfig(algo="none", level=0, precond=meta.precond)
    return _codec.CompressionConfig(
        algo=meta.algo,
        level=meta.level,
        precond=meta.precond,
        dictionary=dictionary if meta.has_dict else None,
    )


def unpack_basket(payload: bytes, meta: BasketMeta,
                  dictionary: Optional[bytes] = None, verify: bool = True) -> bytes:
    """Invert :func:`pack_basket`; verifies the checksum unless disabled."""
    cfg = _meta_cfg(meta, dictionary)
    raw = _codec.decompress(payload, meta.orig_len, cfg, stored_len=meta.stored_len)
    if len(raw) != meta.orig_len:
        raise ValueError(f"basket decoded {len(raw)} bytes, expected {meta.orig_len}")
    if verify and adler32_hw(raw) != meta.checksum:
        raise ChecksumError("basket checksum mismatch (corrupt data)")
    return raw


def unpack_basket_into(payload, meta: BasketMeta, out,
                       dictionary: Optional[bytes] = None,
                       verify: bool = True) -> int:
    """Decompress one basket directly into ``out`` (writable buffer).

    ``out`` must be at least ``meta.orig_len`` bytes; exactly that many are
    written (a larger buffer keeps its remaining bytes untouched, so
    misaligned/oversized destination slices are fine).  The checksum is
    verified on the destination bytes.  Returns ``meta.orig_len``."""
    from . import precond as _precond
    dst = _precond._as_out(out)     # validates writability + contiguity
    if dst.size < meta.orig_len:
        raise ValueError(
            f"output buffer too small: {dst.size} < {meta.orig_len}")
    dst = dst[:meta.orig_len]
    cfg = _meta_cfg(meta, dictionary)
    n = _codec.decompress_into(payload, meta.orig_len, cfg, dst,
                               stored_len=meta.stored_len)
    if n != meta.orig_len:
        raise ValueError(f"basket decoded {n} bytes, expected {meta.orig_len}")
    if verify and adler32_hw(dst) != meta.checksum:
        raise ChecksumError("basket checksum mismatch (corrupt data)")
    return n


# ---------------------------------------------------------------------------
# Array <-> baskets
# ---------------------------------------------------------------------------

def split_array(arr: np.ndarray, target_basket_bytes: int = 1 << 20):
    """Split an array along axis 0 into basket-sized row chunks.

    Yields (entry_start, entry_count, buffer).  Row-granular so each basket
    maps to an entry range — the seekable-restart property the data
    pipeline's checkpoint cursor relies on.

    The buffers are zero-copy ``memoryview`` slices of ``arr`` (flattened
    to bytes); they stay valid while the generator is alive.  Consumers
    that outlive the iteration must ``bytes()`` them.
    """
    arr = np.ascontiguousarray(arr)
    if arr.ndim == 0:
        yield 0, 1, memoryview(arr.reshape(1)).cast("B")
        return
    n = arr.shape[0]
    row_bytes = max(1, arr.nbytes // max(n, 1))
    rows_per = max(1, target_basket_bytes // row_bytes)
    for start in range(0, max(n, 1), rows_per):
        stop = min(start + rows_per, n)
        if start >= n:
            break
        yield start, stop - start, memoryview(arr[start:stop]).cast("B")
    if n == 0:
        yield 0, 0, b""


def basket_rows(shape: tuple, itemsize: int,
                target_basket_bytes: int = 1 << 20) -> int:
    """Rows per basket for a (shape, itemsize) branch — exactly the chunk
    boundaries :func:`split_array` produces, computable without the array.
    The streamed checkpoint staging path uses this so device-sliced chunks
    land on identical basket boundaries (byte-determinism invariant)."""
    n = shape[0] if shape else 1
    total = int(itemsize) * int(np.prod(shape, dtype=np.int64)) if shape else int(itemsize)
    row_bytes = max(1, total // max(n, 1))
    return max(1, target_basket_bytes // row_bytes)


def join_baskets(chunks: list, dtype: str, shape: tuple) -> np.ndarray:
    """Assemble decoded chunks into one array with a single allocation
    (kept for API compatibility; the hot read path scatters baskets into
    the destination with :func:`unpack_basket_into` instead)."""
    out = np.empty(shape, dtype=np.dtype(dtype))
    flat = out.reshape(-1).view(np.uint8)
    pos = 0
    for c in chunks:
        b = np.frombuffer(c, dtype=np.uint8) if not isinstance(c, np.ndarray) else c
        flat[pos:pos + b.size] = b
        pos += b.size
    if pos != flat.size:
        raise ValueError(f"chunks total {pos} bytes, expected {flat.size}")
    return out
