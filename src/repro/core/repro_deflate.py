"""From-scratch LZ77 + Huffman codec — the measurable CF-ZLIB / ZSTD testbed.

The paper attributes CF-ZLIB's fast-level speedup to three mechanisms
(§2.1); two of them are *algorithmic* and reproduced here so they can be
measured rather than cited:

* **Triplet vs quadruplet hashing.** Reference zlib hashes 3-byte windows
  (more collisions, longer chains); CF-ZLIB hashes 4-byte windows on levels
  1-5 and computes them with vector instructions.  ``mode="ref"`` uses
  3-byte hashes computed incrementally (scalar, zlib-style); ``mode="cf"``
  uses 4-byte hashes precomputed for the whole buffer in one vectorized
  numpy pass (the SIMD analogue).  ``benchmarks/fig45_cfzlib.py`` measures
  the wall-clock and match-quality difference.
* **The entropy stage.** Both modes finish with a canonical Huffman pass
  (``repro.core.huffman``) over the token stream — ZLIB's second pass.

The same engine also hosts the **ZSTD mechanism ablation** (§2.3): ZSTD's
ratio win comes partly from a 256 KB window (8x zlib's 32 KB).  The
``window_log`` knob makes that single variable measurable:
``repro-deflate`` = 15 (32 KB, zlib-like); ``repro-zstd`` = 18 (256 KB,
zstd-like).  ``benchmarks/fig2_ratio_speed.py`` sweeps both.

Token wire format (before the Huffman pass)::

    [4B orig_len]
    sequence*:  [1B token: litlen(4) | matchlen-4 (4)]
                [litlen ext 255*] [literals]
                [3B LE offset] [matchlen ext 255*]      (offset <= 2^24-1)

It is LZ4's framing with 3-byte offsets so large windows fit; the Huffman
pass then entropy-codes the whole stream.  Dictionaries prime the window
(prefix), matching how zlib's ``zdict`` and LZ4's prefix mode work.
"""

from __future__ import annotations

import numpy as np

from . import huffman
from . import tokexec as _tok

__all__ = ["compress", "decompress", "lz77_tokens"]

_MIN_MATCH = 4
_LAST_LITERALS = 5


def _hash4_all(data: np.ndarray, log2_size: int) -> np.ndarray:
    """CF-style: 4-byte multiplicative hash, whole buffer in one vector pass."""
    n = data.size
    if n < 4:
        return np.zeros(0, dtype=np.uint32)
    w = (
        data[: n - 3].astype(np.uint32)
        | (data[1: n - 2].astype(np.uint32) << 8)
        | (data[2: n - 1].astype(np.uint32) << 16)
        | (data[3:].astype(np.uint32) << 24)
    )
    return ((w * np.uint32(2654435761)) >> np.uint32(32 - log2_size)).astype(np.uint32)


def _hash3_all(data: np.ndarray, log2_size: int) -> np.ndarray:
    """Reference-zlib-style: 3-byte rolling hash ((h<<5) ^ c) per position.

    Computed with the same shift-xor recurrence zlib uses (UPDATE_HASH);
    vectorized here only so the python harness isn't measuring interpreter
    overhead — the *collision behaviour* (what the paper's quadruplet change
    fixes) is identical to scalar zlib.
    """
    n = data.size
    if n < 3:
        return np.zeros(0, dtype=np.uint32)
    d = data.astype(np.uint32)
    h = ((d[: n - 2] << 10) ^ (d[1: n - 1] << 5) ^ d[2:]) & ((1 << log2_size) - 1)
    return h.astype(np.uint32)


def lz77_tokens(data: bytes, level: int = 5, mode: str = "cf",
                window_log: int = 15, dict_prefix: bytes = b"") -> bytes:
    """LZ77 match+emit pass -> token stream (pre-entropy-coding).

    ``mode="cf"``  : quadruplet hashing (CF-ZLIB levels 1-5 mechanism)
    ``mode="ref"`` : triplet hashing (reference zlib)
    ``level``      : chain search depth (1 -> greedy, 9 -> deep)
    ``window_log`` : max match distance = 2^window_log (15=zlib, 18=zstd-ish)
    """
    if not isinstance(data, (bytes, bytearray, memoryview)):
        data = memoryview(data).cast("B")   # buffer-protocol input, zero-copy
    prefix = dict_prefix[-(1 << window_log):] if dict_prefix else b""
    plen = len(prefix)
    # concatenation only materializes when a prefix actually exists
    buf = (prefix + bytes(data)) if plen else data
    src = np.frombuffer(buf, dtype=np.uint8)
    n = src.size
    out = bytearray()
    out += len(data).to_bytes(4, "little")
    if len(data) == 0:
        return bytes(out)

    def emit(lit_start: int, lit_end: int, mlen: int, dist: int):
        litlen = lit_end - lit_start
        t_lit = 15 if litlen >= 15 else litlen
        t_m = 0 if mlen == 0 else (15 if mlen - _MIN_MATCH >= 15 else mlen - _MIN_MATCH)
        out.append((t_lit << 4) | t_m)
        if litlen >= 15:
            rem = litlen - 15
            while rem >= 255:
                out.append(255)
                rem -= 255
            out.append(rem)
        out.extend(buf[lit_start:lit_end])
        if mlen:
            out.extend(int(dist).to_bytes(3, "little"))
            if mlen - _MIN_MATCH >= 15:
                rem = mlen - _MIN_MATCH - 15
                while rem >= 255:
                    out.append(255)
                    rem -= 255
                out.append(rem)

    if len(data) < _MIN_MATCH + _LAST_LITERALS:
        emit(plen, n, 0, 0)
        return bytes(out)

    log2_size = 15 if level <= 5 else 16
    window = 1 << window_log
    hashes = _hash4_all(src, log2_size) if mode == "cf" else _hash3_all(src, log2_size)
    depth = {1: 1, 2: 2, 3: 4, 4: 8, 5: 16, 6: 32, 7: 64, 8: 128, 9: 256}[min(max(level, 1), 9)]
    head = np.full(1 << log2_size, -1, dtype=np.int64)
    prev = np.full(n, -1, dtype=np.int64)
    match_limit = n - _LAST_LITERALS
    scan_limit = n - _MIN_MATCH - _LAST_LITERALS + 1

    # seed the chains with the dictionary prefix
    for j in range(0, min(plen, hashes.size)):
        hj = hashes[j]
        prev[j] = head[hj]
        head[hj] = j

    def match_len(i: int, j: int) -> int:
        lim = match_limit
        total = 0
        step = 64
        while i + total < lim:
            k = min(step, lim - i - total)
            x = src[i + total: i + total + k]
            y = src[j + total: j + total + k]
            neq = np.nonzero(x != y)[0]
            if neq.size:
                return total + int(neq[0])
            total += k
            step = min(step * 4, 1 << 16)
        return lim - i

    anchor = plen
    i = plen
    misses = 0
    while i < scan_limit:
        h = hashes[i]
        cand = head[h]
        best_len, best_dist = 0, 0
        tries = depth
        while cand >= 0 and tries > 0 and i - cand <= window:
            probe = i + best_len
            if probe < match_limit and src[cand + best_len] == src[probe] and \
                    src[cand] == src[i]:
                mlen = match_len(i, cand)
                if mlen > best_len:
                    best_len, best_dist = mlen, i - cand
            cand = prev[cand]
            tries -= 1
        prev[i] = head[h]
        head[h] = i
        if best_len >= _MIN_MATCH:
            emit(anchor, i, best_len, best_dist)
            step_ins = 1 if level >= 6 else 4   # chain insert density
            for j in range(i + 1, min(i + best_len, scan_limit), step_ins):
                hj = hashes[j]
                prev[j] = head[hj]
                head[hj] = j
            i += best_len
            anchor = i
            misses = 0
        else:
            misses += 1
            i += 1 + (misses >> 6)   # acceleration skip on incompressible data
    emit(anchor, n, 0, 0)
    return bytes(out)


def _untokenize(tokens: bytes, dict_prefix: bytes = b"") -> bytes:
    """Two-pass vectorized token decode (repro.core.tokexec): parse all
    sequence headers into numpy arrays in one scan, then place literals and
    replay matches from a cumulative output-position table."""
    orig_len = int.from_bytes(tokens[:4], "little")
    return _tok.decode_token_stream(tokens, dict_prefix, orig_len, base=4,
                                    offset_bytes=3, name="repro_deflate")


def compress(data: bytes, level: int = 5, mode: str = "cf",
             window_log: int = 15, dictionary: bytes | None = None) -> bytes:
    """LZ77 pass + Huffman entropy pass. Header byte records the mode/window."""
    tokens = lz77_tokens(data, level=level, mode=mode, window_log=window_log,
                         dict_prefix=dictionary or b"")
    hdr = bytes([(0 if mode == "cf" else 1) | (window_log << 1)])
    return hdr + huffman.encode(tokens)


def decompress(comp: bytes, orig_len: int, dictionary: bytes | None = None) -> bytes:
    if not comp:
        return b""
    tokens = huffman.decode(comp[1:])
    return _untokenize(tokens, dictionary or b"")
