"""Codec policy: per-branch (algo, level, preconditioner) selection.

The paper's closing argument (§3): production and analysis want different
codecs, and "improvements are needed to the I/O APIs to ease the switch
between compression algorithms and settings for different use cases".  This
module is that API.

Two layers:

* **Profiles** — named operating points matching the paper's use cases:
    - ``production``: ratio-bound, CPU-rich  -> zstd high / lzma
    - ``analysis``:  decompression-speed-bound -> lz4 (+preconditioner)
    - ``checkpoint``: balanced, write-often read-rarely -> zstd mid
    - ``wire``: lowest latency (collectives / RPC) -> zstd-fast
* **Type heuristics** — per-branch preconditioner choice from dtype/shape,
  encoding the paper's Fig. 6 insight:
    - integer monotone-ish columns (offset arrays!) -> delta + shuffle
    - other integer columns -> shuffle
    - float/bfloat columns -> bitshuffle (exponent bits cluster)
    - opaque bytes -> none

``choose(name, arr, profile)`` returns a ready CompressionConfig and is the
single hook the checkpointer and the data pipeline use.
"""

from __future__ import annotations

import numpy as np

from .codec import CompressionConfig, HAVE_ZSTD

__all__ = ["PROFILES", "choose", "precond_for_array"]

_Z = "zstd" if HAVE_ZSTD else "zlib"

PROFILES: dict[str, dict] = {
    # algo/level pairs per the paper's operating points
    "production": {"algo": _Z, "level": 8},
    "analysis": {"algo": "lz4", "level": 1},
    "analysis-hc": {"algo": "lz4", "level": 6},
    "checkpoint": {"algo": _Z, "level": 4},
    "wire": {"algo": ("zstd-fast" if HAVE_ZSTD else "zlib"), "level": 3 if HAVE_ZSTD else 1},
    "archive": {"algo": "lzma", "level": 6},
    "off": {"algo": "none", "level": 0},
}


_OFFSET_WINDOWS = 8
_OFFSET_WINDOW_ELEMS = 512


def _is_offset_like(arr: np.ndarray) -> bool:
    """Detect offset-array-shaped data: integer, 1-D-ish, mostly monotone.

    Sampled over stratified windows spanning the *whole* array, not just
    its head: an array with a monotone prefix but a non-monotone tail
    (appended columns, mixed-phase files) must not be mistaken for an
    offset array — delta coding the shuffled tail would hurt both ratio
    and speed.  Monotonicity is judged within each window (no diff across
    window joins), then averaged.
    """
    if arr.ndim == 0 or arr.size < 16:
        return False
    flat = arr.reshape(-1)
    w = _OFFSET_WINDOW_ELEMS
    if flat.size <= _OFFSET_WINDOWS * w:
        windows = [flat]
    else:
        span = flat.size - w
        starts = [span * i // (_OFFSET_WINDOWS - 1)
                  for i in range(_OFFSET_WINDOWS)]
        windows = [flat[s:s + w] for s in starts]
    fracs = [float((np.diff(win.astype(np.int64)) >= 0).mean())
             for win in windows if win.size >= 2]
    return bool(fracs and np.mean(fracs) > 0.95)


def precond_for_array(arr: np.ndarray) -> str:
    """Paper-Fig.6 heuristic: pick the preconditioner from the dtype."""
    dt = arr.dtype
    if dt.kind in "iu":
        item = min(dt.itemsize, 8)
        if _is_offset_like(arr):
            return f"delta{item}+shuffle{item}"
        return f"shuffle{item}"
    if dt.kind == "f" or dt.name in ("bfloat16",):
        return f"bitshuffle{max(dt.itemsize, 2)}"
    if dt.kind == "V" and dt.itemsize == 2:  # bf16 often views as void16
        return "bitshuffle2"
    return "none"


def choose(name: str, arr: np.ndarray, profile: str = "checkpoint",
           dictionary: bytes | None = None) -> CompressionConfig:
    """The per-branch policy: profile picks (algo, level); dtype picks precond.

    This is the *zero-measurement* path; ``repro.tune.Tuner`` runs the same
    selection from live measurements and falls back here for branches too
    small to sample.
    """
    try:
        p = PROFILES[profile]
    except KeyError:
        raise ValueError(
            f"unknown profile {profile!r}; valid profiles: "
            f"{', '.join(sorted(PROFILES))}") from None
    if p["algo"] == "none":
        return CompressionConfig(algo="none", level=0, precond="none")
    return CompressionConfig(
        algo=p["algo"], level=p["level"],
        precond=precond_for_array(np.asarray(arr)),
        dictionary=dictionary,
    )
